"""Mixed-precision tiled matmul — the paper's compute hot-spot on TPU.

GPU mixed-precision training feeds half-precision operands to tensor
cores that accumulate in float32.  The TPU analogue is the MXU systolic
array: ``bf16×bf16→f32`` (or ``f16`` upcast).  This kernel expresses
that contract in Pallas:

* the grid ``(M/bm, N/bn, K/bk)`` is the HBM↔VMEM schedule — each step
  stages one ``(bm, bk)``×``(bk, bn)`` tile pair into VMEM (the role
  threadblock tiling plays in the paper's CUDA world);
* a float32 VMEM scratch accumulator persists across the K steps
  (revisiting the same output block), so precision never drops below
  float32 until the final store;
* only the final store casts down to the working precision.

Block sizes default to 128×128×128 (8 MiB of f32 scratch + operand
tiles ≪ 16 MiB VMEM) and shrink to divisors for small dimensions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``preferred``.

    Keeps the grid exact (no padding logic in the kernel); ViT
    dimensions (64/256/768/800/3072 …) all have friendly divisors.
    """
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU contract: half-precision operands, float32 accumulation.
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mixed_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """``x @ y`` with float32 accumulation, tiled for VMEM.

    ``x``: (M, K), ``y``: (K, N); result (M, N) in ``out_dtype``
    (defaults to ``x.dtype``).  Operands may be f16/bf16/f32 — the
    accumulator is always float32.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    out_dtype = out_dtype or x.dtype

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def vmem_bytes(block_m: int, block_n: int, block_k: int,
               operand_bytes: int = 2) -> int:
    """VMEM working set of one grid step (operand tiles + f32 scratch).

    Used by DESIGN.md §Perf / the kernel_micro bench to check the
    16 MiB VMEM budget on real TPU hardware.
    """
    return (
        block_m * block_k * operand_bytes
        + block_k * block_n * operand_bytes
        + block_m * block_n * 4
    )
