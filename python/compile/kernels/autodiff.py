"""Differentiable wrappers for the L1 Pallas kernels.

``pallas_call`` has no automatic reverse-mode rule, so each kernel gets
a ``jax.custom_vjp``: the *forward* runs the Pallas kernel, the
*backward* is hand-derived float32 math (itself built from the
mixed-precision matmul kernel where a GEMM appears).  This mirrors how
production kernels (FlashAttention, fused LN) ship: a fused forward
plus a hand-written VJP, never autodiff through the kernel body.

Gradient-correctness tests: ``python/tests/test_kernel_grads.py``
compares every VJP against ``jax.grad`` of the pure-jnp oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.attention import fused_attention
from compile.kernels.layernorm import layernorm_fp32
from compile.kernels.matmul import mixed_matmul
from compile.kernels.ref import softmax_ref
from compile.kernels.softmax import softmax_fp32


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable mixed-precision GEMM (Pallas forward)."""
    return mixed_matmul(x, y)


def _matmul_fwd(x, y):
    return mixed_matmul(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = G Yᵀ, dY = Xᵀ G — each again a mixed-precision GEMM with f32
    # accumulation; cotangents stay in the working precision so the
    # loss-scaling recipe applies unchanged.
    dx = mixed_matmul(g, y.T, out_dtype=x.dtype)
    dy = mixed_matmul(x.T, g, out_dtype=y.dtype)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# softmax
# ---------------------------------------------------------------------------


@jax.custom_vjp
def softmax(x: jax.Array) -> jax.Array:
    """Differentiable f32-internal softmax (Pallas forward)."""
    return softmax_fp32(x)


def _softmax_fwd(x):
    p = softmax_fp32(x)
    return p, (p,)


def _softmax_bwd(res, g):
    (p,) = res
    # dL/dx = p ⊙ (g − Σ_j g_j p_j), computed in f32.
    p32 = p.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    inner = jnp.sum(g32 * p32, axis=-1, keepdims=True)
    return ((p32 * (g32 - inner)).astype(g.dtype),)


softmax.defvjp(_softmax_fwd, _softmax_bwd)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@jax.custom_vjp
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    """Differentiable f32-statistics LayerNorm (Pallas forward)."""
    return layernorm_fp32(x, gamma, beta)


def _layernorm_fwd(x, gamma, beta):
    out = layernorm_fp32(x, gamma, beta)
    return out, (x, gamma)


def _layernorm_bwd(res, g):
    x, gamma = res
    eps = 1e-5
    n = x.shape[-1]
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    gamma32 = gamma.astype(jnp.float32)

    mean = jnp.mean(x32, axis=-1, keepdims=True)
    xc = x32 - mean
    var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xc * inv

    dgamma = jnp.sum(g32 * xhat, axis=tuple(range(g32.ndim - 1)))
    dbeta = jnp.sum(g32, axis=tuple(range(g32.ndim - 1)))

    gh = g32 * gamma32
    # classic LN backward, all in f32:
    dx = inv / n * (
        n * gh
        - jnp.sum(gh, axis=-1, keepdims=True)
        - xhat * jnp.sum(gh * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@jax.custom_vjp
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Differentiable fused attention (Pallas forward)."""
    return fused_attention(q, k, v)


def _attention_fwd(q, k, v):
    return fused_attention(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    q, k, v = res
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    g32 = g.astype(jnp.float32)

    scores = jnp.einsum("hqd,hkd->hqk", q32, k32) * scale
    p = softmax_ref(scores, axis=-1)  # f32

    dv = jnp.einsum("hqk,hqd->hkd", p, g32)
    dp = jnp.einsum("hqd,hkd->hqk", g32, v32)
    # softmax backward on the scores:
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("hqk,hkd->hqd", ds, k32) * scale
    dk = jnp.einsum("hqk,hqd->hkd", ds, q32) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attention.defvjp(_attention_fwd, _attention_bwd)
