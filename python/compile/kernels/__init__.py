"""L1 — Pallas kernels for mixed-precision training hot-spots.

Each kernel expresses the TPU adaptation of the paper's GPU recipe
(DESIGN.md §Hardware-Adaptation): tiles sized for VMEM via
``BlockSpec``, float32 accumulation/statistics inside the kernel (the
MXU contract: half×half→float32), and a final cast back to the working
precision.  Every kernel has a pure-``jnp`` oracle in
:mod:`compile.kernels.ref` and a pytest/hypothesis sweep in
``python/tests/test_kernels.py``.

Kernels run under ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls, and interpret mode lowers the grid into plain HLO so the
Rust runtime can load the result.
"""

from compile.kernels.matmul import mixed_matmul
from compile.kernels.softmax import softmax_fp32
from compile.kernels.layernorm import layernorm_fp32
from compile.kernels.attention import fused_attention
from compile.kernels.scaling import scale_cast, unscale_check

__all__ = [
    "mixed_matmul",
    "softmax_fp32",
    "layernorm_fp32",
    "fused_attention",
    "scale_cast",
    "unscale_check",
]
