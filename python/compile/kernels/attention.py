"""Fused multi-head attention — the ViT hot-spot as one Pallas kernel.

The paper's Example 1 composes attention from separate matmuls plus a
``force_full_precision`` softmax; on a real accelerator that spills the
(seq × seq) score matrix to HBM twice.  This kernel is the fused TPU
form: one grid step per head stages Q/K/V tiles into VMEM, computes
float32 scores on the MXU, applies the float32 softmax *in registers*,
and accumulates PV in float32 — the score matrix never leaves VMEM.

Numerics contract (identical to ``ref.attention_ref``):
  scores  = Q Kᵀ / √d      — f32 accumulate from half operands
  probs   = softmax(scores) — f32 internals
  out     = probs · V       — f32 accumulate, final cast to input dtype
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0]  # (seq, d) — block carries a singleton head axis
    k = k_ref[0]
    v = v_ref[0]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale

    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)

    out = jax.lax.dot_general(
        probs, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = out.astype(o_ref.dtype)


def fused_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Scaled dot-product attention over ``(heads, seq, head_dim)``.

    One grid step per head; the full (seq, d) tiles fit VMEM for ViT
    shapes (seq ≤ 257, d ≤ 64 ⇒ < 200 KiB per operand at bf16).
    """
    h, s, d = q.shape
    if k.shape != (h, s, d) or v.shape != (h, s, d):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
