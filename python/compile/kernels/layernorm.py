"""LayerNorm with float32 statistics — Pallas form of the paper's
``mpx.force_full_precision(layer_norm, ...)`` (Example 1).

Mean and variance are sums over the feature axis: in float16 they both
lose precision (cancellation) and can overflow for large features.
The kernel computes the statistics in float32 in VMEM and casts only
the normalized output back to the working precision.  Gamma/beta ride
along as unblocked (broadcast) operands."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x32 = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out = (x32 - mean) * inv * g_ref[...].astype(jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def layernorm_fp32(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """LayerNorm over the last axis of a 2-D array, f32 statistics."""
    rows, n = x.shape
    br = min(rows, block_rows)
    while rows % br != 0:
        br -= 1
    grid = (rows // br,)

    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)
