"""Pure-jnp oracles for every L1 kernel.

These are the CORE correctness signal: each Pallas kernel must agree
with its oracle to within the tolerance the half-precision format
allows (pytest + hypothesis sweeps in ``python/tests/test_kernels.py``).
The oracles also serve as the XLA-native fallback path the L2 model can
select (``kernels="xla"``) — both paths AOT-lower to artifacts the Rust
runtime executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Mixed-precision GEMM oracle: half×half → float32 accumulate →
    cast back to the input dtype (the MXU/tensor-core contract)."""
    acc = jnp.dot(x, y, preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def softmax_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-safe softmax: float32 internals (max-shift, exp,
    normalize), result cast back — what ``mpx.force_full_precision``
    produces around ``jax.nn.softmax`` (paper Example 1)."""
    x32 = x.astype(jnp.float32)
    x32 = x32 - jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32)
    out = e / jnp.sum(e, axis=axis, keepdims=True)
    return out.astype(x.dtype)


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """LayerNorm oracle with float32 statistics over the last axis."""
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out = (x32 - mean) * inv * gamma.astype(jnp.float32) \
        + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention oracle over (heads, seq, head_dim):
    float32 scores, float32 softmax, float32 PV accumulate."""
    d = q.shape[-1]
    scores = jnp.einsum(
        "hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(d))
    probs = softmax_ref(scores, axis=-1)  # float32 in, float32 out
    out = jnp.einsum(
        "hqk,hkd->hqd", probs, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


def scale_cast_ref(x: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Loss-scaling forward helper oracle: multiply then cast down."""
    return (x.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def unscale_check_ref(g: jax.Array, scale: jax.Array):
    """Gradient post-processing oracle: cast to float32, divide by the
    scale, and report whether every element is finite."""
    g32 = g.astype(jnp.float32) / scale.astype(jnp.float32)
    finite = jnp.all(jnp.isfinite(g32))
    return g32, finite
