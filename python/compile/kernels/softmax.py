"""Row softmax with float32 internals — Pallas form of the paper's
``mpx.force_full_precision(jax.nn.softmax, ...)`` (Example 1).

``exp`` overflows float16 for inputs > ~11.09 (e^11.1 > 65504), so the
kernel upcasts each row block to float32 in VMEM, performs the
max-shift / exp / normalize entirely in float32, and casts only the
final probabilities back to the working precision.  The row dimension
is gridded; each step stages a ``(block_rows, n)`` tile."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x32 = x_ref[...].astype(jnp.float32)
    x32 = x32 - jnp.max(x32, axis=-1, keepdims=True)
    e = jnp.exp(x32)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = probs.astype(o_ref.dtype)


def softmax_fp32(
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Softmax over the last axis of a 2-D array, f32 internals."""
    rows, n = x.shape
    br = min(rows, block_rows)
    while rows % br != 0:
        br -= 1
    grid = (rows // br,)

    return pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x)
