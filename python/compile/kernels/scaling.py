"""Loss-scaling data movement as Pallas kernels.

Two small kernels bracket the backward pass (paper §2.1 steps 2, 4–6):

* :func:`scale_cast` — multiply by the scale factor and cast down, the
  op applied to the loss (and conceptually to every cotangent seed);
* :func:`unscale_check` — the gradient post-pass: upcast to float32,
  divide by the scale, and fold a finite-ness flag across all blocks
  (the flag drives the optimizer-skip and scale adjustment).

``unscale_check`` demonstrates a cross-block reduction in Pallas: every
grid step AND-accumulates its block's finiteness into a single (1, 1)
output that all steps map to."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_cast_kernel(x_ref, s_ref, o_ref):
    x32 = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x32 * s_ref[0]).astype(o_ref.dtype)


def scale_cast(
    x: jax.Array,
    scale: jax.Array,
    dtype,
    *,
    block_rows: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """``(x * scale).astype(dtype)`` with float32 multiply, 2-D x."""
    rows, n = x.shape
    br = min(rows, block_rows)
    while rows % br != 0:
        br -= 1
    scale = jnp.reshape(scale.astype(jnp.float32), (1,))

    return pl.pallas_call(
        _scale_cast_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), dtype),
        interpret=interpret,
    )(x, scale)


def _unscale_kernel(g_ref, s_ref, o_ref, fin_ref, *, n_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        fin_ref[0, 0] = jnp.int32(1)

    g32 = g_ref[...].astype(jnp.float32) / s_ref[0]
    o_ref[...] = g32
    block_finite = jnp.all(jnp.isfinite(g32)).astype(jnp.int32)
    fin_ref[0, 0] = fin_ref[0, 0] * block_finite


def unscale_check(
    g: jax.Array,
    scale: jax.Array,
    *,
    block_rows: int = 512,
    interpret: bool = True,
):
    """Returns ``(g/scale as float32, all_finite flag)`` for 2-D g.

    The finite flag comes back as an int32 scalar (1 = finite) because
    a (1, 1) output block is the natural cross-grid accumulator shape.
    """
    rows, n = g.shape
    br = min(rows, block_rows)
    while rows % br != 0:
        br -= 1
    scale = jnp.reshape(scale.astype(jnp.float32), (1,))
    grid = (rows // br,)

    out, fin = pl.pallas_call(
        functools.partial(_unscale_kernel, n_steps=grid[0]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(g, scale)
    return out, fin[0, 0] == 1
