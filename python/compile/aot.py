"""AOT compiler: lower L2 train steps to HLO text + manifests.

This is the only place Python touches the pipeline — ``make artifacts``
runs it once; the Rust binary is self-contained afterwards.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).  Lowering goes stablehlo → XlaComputation
with ``return_tuple=True``; the Rust side unwraps the tuple.

For every variant two files are written:

* ``artifacts/<name>.hlo.txt``       — the program;
* ``artifacts/<name>.manifest.json`` — ordered input/output leaf
  inventory (name, dtype, shape, group, trainable) plus metadata —
  the contract ``rust/src/runtime/manifest.rs`` parses.

Plus one ``artifacts/index.json`` listing everything built.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import trainstep as ts
from compile.model import PRESETS, make_config, param_count


# ---------------------------------------------------------------------------
# HLO text emission
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {
    "float32": "f32",
    "float16": "f16",
    "bfloat16": "bf16",
    "int32": "s32",
    "uint32": "u32",
    "int8": "s8",
    "uint8": "u8",
    "bool": "pred",
}


def _dtype_name(dtype) -> str:
    name = jnp.dtype(dtype).name
    if name not in _DTYPE_NAMES:
        raise ValueError(f"unsupported artifact dtype {name}")
    return _DTYPE_NAMES[name]


def _leaves(tree, group: str, trainable_from=None):
    """Flatten one top-level argument into manifest leaf entries."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        entry = {
            "name": group + jax.tree_util.keystr(path),
            "dtype": _dtype_name(leaf.dtype),
            "shape": list(leaf.shape),
            "group": group,
        }
        if trainable_from is not None:
            entry["trainable"] = bool(
                jnp.issubdtype(leaf.dtype, jnp.inexact))
        out.append(entry)
    return out


def manifest_for(fn, arg_groups, out_groups, meta):
    """Build the manifest dict for ``fn(*args)``.

    ``arg_groups``  : list of (group_name, example_tree, mark_trainable)
    ``out_groups``  : list of (group_name) matching fn's output tuple
                      positions (the output *is* a tuple).
    """
    args = [t for _, t, _ in arg_groups]
    out_shape = jax.eval_shape(fn, *args)
    if not isinstance(out_shape, tuple):
        out_shape = (out_shape,)
    if len(out_shape) != len(out_groups):
        raise ValueError(
            f"output arity {len(out_shape)} != groups {out_groups}")

    inputs = []
    for group, tree, trainable in arg_groups:
        inputs.extend(_leaves(tree, group,
                              trainable_from=tree if trainable else None))
    outputs = []
    for group, tree in zip(out_groups, out_shape):
        outputs.extend(_leaves(tree, group))
    return {"inputs": inputs, "outputs": outputs, "meta": meta}


# ---------------------------------------------------------------------------
# Variant registry
# ---------------------------------------------------------------------------


def _state_groups(config, precision):
    model, opt_state, scaling = ts.example_state(config, precision)
    return [
        ("params", model, True),
        ("opt_state", opt_state, False),
        ("scaling", scaling, False),
    ]


def _batch_groups(config, batch):
    images, labels = ts.example_batch(config, batch)
    return [("images", images, False), ("labels", labels, False)]


def build_variant(name: str, spec: dict):
    """Returns (fn, example_args, manifest)."""
    kind = spec["kind"]
    if kind in ("init", "step_fused", "grads", "fwd"):
        config = make_config(
            spec["model"],
            kernels=spec.get("kernels", "xla"),
            remat=spec.get("remat", False),
        )
        precision = spec["precision"]
        meta = {
            "name": name,
            "kind": kind,
            "model": spec["model"],
            "model_config": PRESETS[spec["model"]],
            "precision": precision,
            "kernels": spec.get("kernels", "xla"),
            "batch": spec.get("batch"),
            "optimizer": {"kind": "adamw", "lr": ts.LEARNING_RATE,
                          "weight_decay": ts.WEIGHT_DECAY},
            "loss_scaling": {
                "init": 2.0 ** 15 if precision == "mixed_f16" else 1.0,
                "period": 2000 if precision == "mixed_f16" else 2 ** 30,
                "factor": 2.0,
            },
        }

    if kind == "init":
        fn = ts.build_init(config, precision)
        seed = jax.ShapeDtypeStruct((), jnp.int32)
        arg_groups = [("seed", seed, False)]
        out_groups = ["params", "opt_state", "scaling"]
        # init returns a 3-tuple of pytrees; eval_shape keeps that tuple.
        m = manifest_for(fn, arg_groups, out_groups, meta)
        meta["param_count"] = sum(
            int(jnp.prod(jnp.asarray(e["shape"]))) if e["shape"] else 1
            for e in m["outputs"]
            if e["group"] == "params" and e["dtype"] in ("f32", "f16", "bf16"))
        return fn, [seed], m

    if kind == "step_fused":
        fn = ts.build_step_fused(config, precision)
        arg_groups = _state_groups(config, precision) + \
            _batch_groups(config, spec["batch"])
        out_groups = ["params", "opt_state", "scaling", "loss", "finite"]
        m = manifest_for(fn, arg_groups, out_groups, meta)
        return fn, [t for _, t, _ in arg_groups], m

    if kind == "grads":
        fn = ts.build_grads(config, precision)
        model, _, _ = ts.example_state(config, precision)
        scale = jax.ShapeDtypeStruct((), jnp.float32)
        images, labels = ts.example_batch(config, spec["batch"])
        arg_groups = [
            ("params", model, True),
            ("scale", scale, False),
            ("images", images, False),
            ("labels", labels, False),
        ]
        out_groups = ["grads", "loss", "finite"]
        m = manifest_for(fn, arg_groups, out_groups, meta)
        return fn, [t for _, t, _ in arg_groups], m

    if kind == "fwd":
        fn = ts.build_fwd(config, precision)
        model, _, _ = ts.example_state(config, precision)
        images, _ = ts.example_batch(config, spec["batch"])
        arg_groups = [("params", model, True), ("images", images, False)]
        out_groups = ["logits"]
        m = manifest_for(fn, arg_groups, out_groups, meta)
        return fn, [t for _, t, _ in arg_groups], m

    if kind == "kernel":
        return build_kernel_variant(name, spec)

    raise ValueError(f"unknown kind {kind!r}")


def build_kernel_variant(name: str, spec: dict):
    """Micro-bench wrappers around single L1 kernels.

    All I/O is float32 (the Rust literal layer is f32-only by design);
    the half casts happen in-graph — exactly the mixed-precision
    boundary the kernel implements.
    """
    from compile import kernels as K

    op = spec["op"]
    half = jnp.dtype(spec.get("half", "float16"))
    meta = {"name": name, "kind": "kernel", "op": op,
            "half": jnp.dtype(half).name, "shape": spec["shape"]}

    if op == "matmul":
        m, k, n = spec["shape"]
        x = jax.ShapeDtypeStruct((m, k), jnp.float32)
        y = jax.ShapeDtypeStruct((k, n), jnp.float32)

        def fn(x, y):
            out = K.mixed_matmul(
                x.astype(half), y.astype(half), out_dtype=jnp.float32)
            return (out,)

        args = [x, y]
        arg_groups = [("x", x, False), ("y", y, False)]
        out_groups = ["out"]
    elif op == "matmul_ref":
        from compile.kernels import ref as R
        m, k, n = spec["shape"]
        x = jax.ShapeDtypeStruct((m, k), jnp.float32)
        y = jax.ShapeDtypeStruct((k, n), jnp.float32)

        def fn(x, y):
            out = R.matmul_ref(x.astype(half), y.astype(half))
            return (out.astype(jnp.float32),)

        args = [x, y]
        arg_groups = [("x", x, False), ("y", y, False)]
        out_groups = ["out"]
    elif op == "attention":
        h, s, d = spec["shape"]
        q = jax.ShapeDtypeStruct((h, s, d), jnp.float32)

        def fn(q, k, v):
            out = K.fused_attention(
                q.astype(half), k.astype(half), v.astype(half))
            return (out.astype(jnp.float32),)

        args = [q, q, q]
        arg_groups = [("q", q, False), ("k", q, False), ("v", q, False)]
        out_groups = ["out"]
    elif op == "layernorm":
        rows, cols = spec["shape"]
        x = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
        g = jax.ShapeDtypeStruct((cols,), jnp.float32)

        def fn(x, g, b):
            out = K.layernorm_fp32(
                x.astype(half), g.astype(half), b.astype(half))
            return (out.astype(jnp.float32),)

        args = [x, g, g]
        arg_groups = [("x", x, False), ("gamma", g, False), ("beta", g, False)]
        out_groups = ["out"]
    else:
        raise ValueError(f"unknown kernel op {op!r}")

    m = manifest_for(fn, arg_groups, out_groups, meta)
    return fn, args, m


# ---------------------------------------------------------------------------
# Default artifact sets
# ---------------------------------------------------------------------------


def default_variants() -> dict:
    v = {}

    # --- test set (vit_tiny, fast) -------------------------------------
    for prec in ("fp32", "mixed_f16", "mixed_bf16"):
        v[f"init_vit_tiny_{prec}"] = dict(
            kind="init", model="vit_tiny", precision=prec)
        v[f"step_fused_vit_tiny_{prec}_b8"] = dict(
            kind="step_fused", model="vit_tiny", precision=prec, batch=8)
    v["fwd_vit_tiny_mixed_f16_b8"] = dict(
        kind="fwd", model="vit_tiny", precision="mixed_f16", batch=8)
    v["fwd_vit_tiny_fp32_b8"] = dict(
        kind="fwd", model="vit_tiny", precision="fp32", batch=8)
    v["grads_vit_tiny_mixed_f16_b8"] = dict(
        kind="grads", model="vit_tiny", precision="mixed_f16", batch=8)
    v["grads_vit_tiny_fp32_b8"] = dict(
        kind="grads", model="vit_tiny", precision="fp32", batch=8)
    # pallas-kernel path composed end-to-end:
    v["step_fused_vit_tiny_pallas_mixed_f16_b8"] = dict(
        kind="step_fused", model="vit_tiny", precision="mixed_f16",
        batch=8, kernels="pallas")

    # --- Fig. 2 / Fig. 3a: desktop (vit_desktop on CIFAR-100 shapes) ---
    for prec in ("fp32", "mixed_f16"):
        v[f"init_vit_desktop_{prec}"] = dict(
            kind="init", model="vit_desktop", precision=prec)
        for b in (8, 16, 32, 64, 128):
            v[f"step_fused_vit_desktop_{prec}_b{b}"] = dict(
                kind="step_fused", model="vit_desktop", precision=prec,
                batch=b)
        v[f"grads_vit_desktop_{prec}_b16"] = dict(
            kind="grads", model="vit_desktop", precision=prec, batch=16)

    # --- Fig. 3b: cluster (vit_base on ImageNet shapes, 4-shard DDP) ---
    for prec in ("fp32", "mixed_f16"):
        v[f"init_vit_base_{prec}"] = dict(
            kind="init", model="vit_base", precision=prec)
        for b in (1, 2):
            v[f"step_fused_vit_base_{prec}_b{b}"] = dict(
                kind="step_fused", model="vit_base", precision=prec, batch=b)
        v[f"grads_vit_base_{prec}_b1"] = dict(
            kind="grads", model="vit_base", precision=prec, batch=1)

    # --- remat ablation (extension): trade compute for activation
    # memory — compared against the plain b64 artifacts in
    # fig2/ablation benches and EXPERIMENTS.md §ablations.
    for prec in ("fp32", "mixed_f16"):
        v[f"step_fused_vit_desktop_{prec}_b64_remat"] = dict(
            kind="step_fused", model="vit_desktop", precision=prec,
            batch=64, remat=True)

    # --- L1 kernel micro-benches ----------------------------------------
    for half in ("float16", "bfloat16"):
        tag = "f16" if half == "float16" else "bf16"
        v[f"kernel_matmul_{tag}_512"] = dict(
            kind="kernel", op="matmul", half=half, shape=[512, 512, 512])
        v[f"kernel_matmul_ref_{tag}_512"] = dict(
            kind="kernel", op="matmul_ref", half=half, shape=[512, 512, 512])
    v["kernel_attention_f16_vit"] = dict(
        kind="kernel", op="attention", half="float16", shape=[8, 65, 32])
    v["kernel_layernorm_f16_vit"] = dict(
        kind="kernel", op="layernorm", half="float16", shape=[65, 256])

    return v


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def emit(name: str, spec: dict, out_dir: str, force: bool = False) -> dict:
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    spec_digest = hashlib.sha256(
        json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]

    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        try:
            with open(man_path) as f:
                old = json.load(f)
            if old.get("spec_digest") == spec_digest:
                return {"name": name, "skipped": True}
        except (json.JSONDecodeError, OSError):
            pass

    t0 = time.time()
    fn, args, manifest = build_variant(name, spec)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    manifest["spec_digest"] = spec_digest
    manifest["hlo_bytes"] = len(text)

    with open(hlo_path, "w") as f:
        f.write(text)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    dt = time.time() - t0
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO, "
          f"{len(manifest['inputs'])}→{len(manifest['outputs'])} leaves, "
          f"{dt:.1f}s")
    return {"name": name, "skipped": False, "seconds": round(dt, 2)}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", default=None,
                   help="substring filter on variant names")
    p.add_argument("--list", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    variants = default_variants()
    if args.only:
        variants = {k: v for k, v in variants.items() if args.only in k}
    if args.list:
        for k in sorted(variants):
            print(k)
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    print(f"AOT: lowering {len(variants)} variants → {args.out_dir}")
    results = []
    for name in sorted(variants):
        results.append(emit(name, variants[name], args.out_dir, args.force))

    index = {
        "variants": sorted(variants),
        "jax_version": jax.__version__,
    }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    built = sum(1 for r in results if not r.get("skipped"))
    print(f"AOT done: {built} built, {len(results) - built} up-to-date")
    return 0


if __name__ == "__main__":
    sys.exit(main())
