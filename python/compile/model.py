"""L2 — the paper's evaluation model: a Vision Transformer.

Paper §5 trains two ViTs: a desktop one (feature 256, MLP hidden 800,
CIFAR-100) and a ViT-Base-shaped one (feature 768, MLP 3072,
ImageNet-1k).  This module reproduces the architecture of the paper's
Example 1 on top of the mini-Equinox substrate (:mod:`mpx.nn`):

* multi-head self-attention blocks whose softmax and layer-norms run in
  full precision (``mpx.force_full_precision`` — or, with
  ``kernels="pallas"``, the fused L1 kernels whose float32 internals
  realize the same guarantee in one VMEM pass);
* pre-LN residual wiring, GELU MLP, learned position embeddings, a CLS
  token, and a linear classifier head.

The model is built in float32 (master weights); mixed-precision
execution happens when ``mpx.filter_grad`` casts the whole PyTree to
half before the forward pass.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

import mpx
from mpx import nn
from compile.kernels import autodiff as kad


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


class ViTConfig:
    """Architecture hyper-parameters (static, hashable)."""

    def __init__(self, *, image_size: int, patch_size: int, channels: int,
                 num_classes: int, feature_dim: int, mlp_dim: int,
                 num_heads: int, depth: int, kernels: str = "xla",
                 remat: bool = False):
        if image_size % patch_size != 0:
            raise ValueError("image_size must be a multiple of patch_size")
        if feature_dim % num_heads != 0:
            raise ValueError("feature_dim must be a multiple of num_heads")
        if kernels not in ("xla", "pallas"):
            raise ValueError(f"kernels must be 'xla' or 'pallas': {kernels}")
        self.image_size = image_size
        self.patch_size = patch_size
        self.channels = channels
        self.num_classes = num_classes
        self.feature_dim = feature_dim
        self.mlp_dim = mlp_dim
        self.num_heads = num_heads
        self.depth = depth
        self.kernels = kernels
        #: rematerialize block activations in the backward pass —
        #: trades compute for the batch-scaling memory term (an
        #: extension ablation; see EXPERIMENTS.md §ablations).
        self.remat = remat

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def seq_len(self) -> int:
        return self.num_patches + 1  # + CLS token

    def __repr__(self):
        return (f"ViTConfig(img={self.image_size}, patch={self.patch_size}, "
                f"dim={self.feature_dim}, mlp={self.mlp_dim}, "
                f"heads={self.num_heads}, depth={self.depth}, "
                f"classes={self.num_classes}, kernels={self.kernels})")


#: Paper §5 model presets.  ``vit_tiny`` is ours, for fast tests and the
#: quickstart; ``vit_desktop`` matches the paper's RTX4070 experiment
#: ("size 256, residual blocks with one hidden layer of 800 neurons");
#: ``vit_base`` mirrors the CLAIX-2023 H100 experiment (ViT-Base dims).
PRESETS = {
    "vit_tiny": dict(image_size=32, patch_size=8, channels=3, num_classes=10,
                     feature_dim=64, mlp_dim=128, num_heads=4, depth=2),
    "vit_desktop": dict(image_size=32, patch_size=4, channels=3,
                        num_classes=100, feature_dim=256, mlp_dim=800,
                        num_heads=8, depth=6),
    "vit_base": dict(image_size=224, patch_size=16, channels=3,
                     num_classes=1000, feature_dim=768, mlp_dim=3072,
                     num_heads=12, depth=12),
}


def make_config(name: str, kernels: str = "xla",
                remat: bool = False) -> ViTConfig:
    if name not in PRESETS:
        raise KeyError(f"unknown model preset {name!r}; have {sorted(PRESETS)}")
    return ViTConfig(kernels=kernels, remat=remat, **PRESETS[name])


# ---------------------------------------------------------------------------
# Blocks (paper Example 1 structure)
# ---------------------------------------------------------------------------


def _reshape_heads(x: jax.Array, num_heads: int) -> jax.Array:
    """(n, h·f) → (h, n, f) — the einshape of paper Example 1."""
    n, hf = x.shape
    f = hf // num_heads
    return jnp.transpose(x.reshape(n, num_heads, f), (1, 0, 2))


def _merge_heads(x: jax.Array) -> jax.Array:
    """(h, n, f) → (n, h·f)."""
    h, n, f = x.shape
    return jnp.transpose(x, (1, 0, 2)).reshape(n, h * f)


class MultiHeadAttentionBlock(nn.Module):
    """Pre-LN multi-head self-attention with full-precision softmax.

    Follows the paper's Example 1 line by line; with
    ``kernels="pallas"`` the layer-norm and the attention core run as
    fused L1 kernels (float32 internals in VMEM) instead of
    ``mpx.force_full_precision``-wrapped jnp ops.
    """

    def __init__(self, feature_dim: int, num_heads: int, key,
                 kernels: str = "xla"):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        self.dense_qs = nn.Linear(feature_dim, feature_dim, k1)
        self.dense_ks = nn.Linear(feature_dim, feature_dim, k2)
        self.dense_vs = nn.Linear(feature_dim, feature_dim, k3)
        self.dense_o = nn.Linear(feature_dim, feature_dim, k4)
        self.layer_norm = nn.LayerNorm(feature_dim)
        self.num_heads = num_heads
        self.kernels = kernels

    def _attention(self, qs: jax.Array, ks: jax.Array, vs: jax.Array):
        if self.kernels == "pallas":
            return kad.attention(qs, ks, vs)
        d = qs.shape[-1]
        scores = jnp.einsum("hqd,hkd->hqk", qs, ks) / jnp.sqrt(
            jnp.asarray(d, qs.dtype))
        probs = mpx.force_full_precision(jax.nn.softmax, scores.dtype)(
            scores, axis=-1)
        return jnp.einsum("hqk,hkd->hqd", probs, vs)

    def _norm(self, x: jax.Array) -> jax.Array:
        if self.kernels == "pallas":
            return kad.layernorm(x, self.layer_norm.weight,
                                 self.layer_norm.bias)
        return jax.vmap(
            mpx.force_full_precision(self.layer_norm, x.dtype))(x)

    def __call__(self, inputs: jax.Array) -> jax.Array:
        x = self._norm(inputs)
        qs = _reshape_heads(self.dense_qs(x), self.num_heads)
        ks = _reshape_heads(self.dense_ks(x), self.num_heads)
        vs = _reshape_heads(self.dense_vs(x), self.num_heads)
        out = _merge_heads(self._attention(qs, ks, vs))
        return self.dense_o(out) + inputs


class MLPBlock(nn.Module):
    """Pre-LN residual MLP block (one hidden layer, GELU)."""

    def __init__(self, feature_dim: int, mlp_dim: int, key,
                 kernels: str = "xla"):
        k1, k2 = jax.random.split(key)
        self.fc_in = nn.Linear(feature_dim, mlp_dim, k1)
        self.fc_out = nn.Linear(mlp_dim, feature_dim, k2)
        self.layer_norm = nn.LayerNorm(feature_dim)
        self.kernels = kernels

    def __call__(self, inputs: jax.Array) -> jax.Array:
        if self.kernels == "pallas":
            x = kad.layernorm(inputs, self.layer_norm.weight,
                              self.layer_norm.bias)
            h = jax.nn.gelu(kad.matmul(x, self.fc_in.weight.T)
                            + self.fc_in.bias)
            out = kad.matmul(h, self.fc_out.weight.T) + self.fc_out.bias
        else:
            x = jax.vmap(
                mpx.force_full_precision(self.layer_norm, inputs.dtype)
            )(inputs)
            h = jax.nn.gelu(self.fc_in(x))
            out = self.fc_out(h)
        return out + inputs


class VisionTransformer(nn.Module):
    """The full ViT: patchify → embed → blocks → LN → classifier.

    ``__call__`` maps a single image (C, H, W) to logits; batch via
    ``jax.vmap`` (paper Example 1 does the same).
    """

    def __init__(self, config: ViTConfig, key):
        keys = jax.random.split(key, 2 * config.depth + 3)
        patch_dim = config.channels * config.patch_size ** 2

        self.patch_embed = nn.Linear(patch_dim, config.feature_dim, keys[0])
        self.pos_embed = 0.02 * jax.random.normal(
            keys[1], (config.seq_len, config.feature_dim), jnp.float32)
        self.cls_token = jnp.zeros((1, config.feature_dim), jnp.float32)

        blocks = []
        for i in range(config.depth):
            blocks.append(MultiHeadAttentionBlock(
                config.feature_dim, config.num_heads, keys[2 + 2 * i],
                kernels=config.kernels))
            blocks.append(MLPBlock(
                config.feature_dim, config.mlp_dim, keys[3 + 2 * i],
                kernels=config.kernels))
        self.blocks = tuple(blocks)

        self.final_norm = nn.LayerNorm(config.feature_dim)
        self.head = nn.Linear(config.feature_dim, config.num_classes,
                              keys[2 * config.depth + 2])

        self.patch_size = config.patch_size
        self.kernels = config.kernels
        self.remat = config.remat

    def _patchify(self, image: jax.Array) -> jax.Array:
        """(C, H, W) → (num_patches, C·p²)."""
        c, h, w = image.shape
        p = self.patch_size
        x = image.reshape(c, h // p, p, w // p, p)
        x = jnp.transpose(x, (1, 3, 0, 2, 4))  # (h/p, w/p, c, p, p)
        return x.reshape((h // p) * (w // p), c * p * p)

    def __call__(self, image: jax.Array) -> jax.Array:
        x = self.patch_embed(self._patchify(image))
        x = jnp.concatenate(
            [self.cls_token.astype(x.dtype), x], axis=0)
        x = x + self.pos_embed.astype(x.dtype)
        for block in self.blocks:
            if self.remat:
                # recompute this block's activations in the backward
                # pass instead of storing them (jax.checkpoint supports
                # differentiable closure captures — the block's params)
                x = jax.checkpoint(block)(x)
            else:
                x = block(x)
        if self.kernels == "pallas":
            from compile.kernels import autodiff as kad_
            x = kad_.layernorm(x, self.final_norm.weight,
                               self.final_norm.bias)
        else:
            x = jax.vmap(
                mpx.force_full_precision(self.final_norm, x.dtype))(x)
        return self.head(x[0])  # CLS token


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy_loss(model: VisionTransformer, batch) -> jax.Array:
    """Mean softmax cross-entropy; log-softmax forced to full precision
    (a sum-exp reduction — exactly the §3.2 overflow case)."""
    images, labels = batch
    logits = jax.vmap(model)(images)
    logp = mpx.force_full_precision(jax.nn.log_softmax, jnp.float32)(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)
    return jnp.mean(nll)


def accuracy(model: VisionTransformer, batch) -> jax.Array:
    images, labels = batch
    logits = jax.vmap(model)(images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def param_count(model) -> int:
    return sum(
        leaf.size for leaf in jax.tree_util.tree_leaves(model)
        if mpx.is_inexact_array(leaf)
    )
