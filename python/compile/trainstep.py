"""L2 — train-step builders: the functions the Rust coordinator runs.

Three function families, all pure PyTree→PyTree (so they AOT-lower to
a single HLO module each):

* ``init``       : seed → (model, opt_state, scaling) — parameter
  initialization lives in-graph, so Rust never needs to know the
  init distributions.
* ``step_fused`` : (model, opt_state, scaling, images, labels) →
  (model', opt_state', scaling', loss, grads_finite) — the
  single-device fast path; the whole §2.1 recipe (cast, scale,
  grad, unscale, check, adjust, conditional update) is one HLO
  program.
* ``grads``      : (model, scale, images, labels) →
  (grads_f32, loss, grads_finite) — the data-parallel path; the
  Rust coordinator owns all-reduce, scale adjustment and the
  optimizer (mirroring a multi-GPU MPX deployment where the
  update is replicated host logic).
* ``fwd``        : (model, images) → logits — serving/eval.

Precision modes:

* ``fp32``       — baseline: no casting, loss scale pinned to 1.
* ``mixed_f16``  — paper's main mode: float16 + dynamic loss scaling.
* ``mixed_bf16`` — bfloat16; same exponent range as f32, so the
  dynamic scaling is effectively dormant but kept for a uniform
  state layout across artifacts.
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

import mpx
from mpx import optim
from compile.model import (
    ViTConfig,
    VisionTransformer,
    cross_entropy_loss,
    make_config,
)

PRECISIONS = ("fp32", "mixed_f16", "mixed_bf16")

#: Fixed optimizer recipe for all artifacts (recorded in the manifest).
LEARNING_RATE = 3e-4
WEIGHT_DECAY = 1e-4


def make_optimizer() -> optim.GradientTransformation:
    return optim.adamw(LEARNING_RATE, weight_decay=WEIGHT_DECAY)


def initial_scaling(precision: str) -> mpx.DynamicLossScaling:
    """Uniform scaling-state layout across precisions.

    fp32/bf16 pin the scale to 1 with an unreachable growth period —
    bit-identical state shape, mathematically a no-op (scaling by 1.0
    is exact in every binary float format).
    """
    if precision == "mixed_f16":
        return mpx.DynamicLossScaling(2.0 ** 15, period=2000)
    return mpx.DynamicLossScaling(
        1.0, period=2 ** 30, min_loss_scaling=1.0, max_loss_scaling=1.0)


def _half_dtype(precision: str):
    return jnp.bfloat16 if precision == "mixed_bf16" else jnp.float16


def build_init(config: ViTConfig, precision: str) -> Callable:
    """seed:int32 → (model, opt_state, scaling)."""
    optimizer = make_optimizer()

    def init(seed: jax.Array):
        key = jax.random.PRNGKey(seed)
        model = VisionTransformer(config, key)
        opt_state = optimizer.init(
            mpx.filter_arrays(model, mpx.is_inexact_array))
        scaling = initial_scaling(precision)
        return model, opt_state, scaling

    return init


def build_step_fused(config: ViTConfig, precision: str) -> Callable:
    """The fused single-device train step (paper Example 2b inlined)."""
    optimizer = make_optimizer()
    use_mp = precision != "fp32"

    def step(model, opt_state, scaling, images, labels):
        mpx.set_half_dtype(_half_dtype(precision))
        loss, new_scaling, grads_finite, grads = mpx.filter_value_and_grad(
            cross_entropy_loss, scaling, use_mixed_precision=use_mp
        )(model, (images, labels))
        model, opt_state = mpx.optimizer_update(
            model, optimizer, opt_state, grads, grads_finite)
        return model, opt_state, new_scaling, loss, grads_finite

    return step


def build_grads(config: ViTConfig, precision: str) -> Callable:
    """Per-shard gradient computation for the data-parallel mode.

    Takes the raw scale factor (not the full scaling state): the Rust
    coordinator owns the adjust logic because only it sees the global
    (all-shard) finiteness.
    """
    use_mp = precision != "fp32"

    def grads_fn(model, scale: jax.Array, images, labels):
        mpx.set_half_dtype(_half_dtype(precision))
        scaling = mpx.StaticLossScaling(scale)
        loss, _, grads_finite, grads = mpx.filter_value_and_grad(
            cross_entropy_loss, scaling, use_mixed_precision=use_mp
        )(model, (images, labels))
        return grads, loss, grads_finite

    return grads_fn


def build_fwd(config: ViTConfig, precision: str) -> Callable:
    """Batched inference forward (serving/eval path)."""
    use_mp = precision != "fp32"

    def fwd(model, images):
        mpx.set_half_dtype(_half_dtype(precision))
        if use_mp:
            model = mpx.cast_to_half_precision(model)
            images = mpx.cast_to_half_precision(images)
        logits = jax.vmap(model)(images)
        return logits.astype(jnp.float32)

    return fwd


# ---------------------------------------------------------------------------
# Example-argument builders (ShapeDtypeStructs for AOT lowering)
# ---------------------------------------------------------------------------


def example_batch(config: ViTConfig, batch: int):
    images = jax.ShapeDtypeStruct(
        (batch, config.channels, config.image_size, config.image_size),
        jnp.float32)
    labels = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return images, labels


def example_state(config: ViTConfig, precision: str):
    """Abstract (model, opt_state, scaling) via eval_shape of init."""
    init = build_init(config, precision)
    return jax.eval_shape(init, jax.ShapeDtypeStruct((), jnp.int32))


def concrete_state(config: ViTConfig, precision: str, seed: int = 0):
    """Host-side init (for pytest, not for artifacts)."""
    return build_init(config, precision)(jnp.asarray(seed, jnp.int32))
