"""Hand-written kernel VJPs vs autodiff of the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import autodiff as ad
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float16, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def grads_close(got, want, atol=2e-2, rtol=5e-2):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=atol, rtol=rtol)


class TestMatmulVjp:
    def test_grads_match_oracle(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x, y = rand(k1, (32, 48)), rand(k2, (48, 16))

        def f_kernel(x, y):
            return jnp.sum(ad.matmul(x, y).astype(jnp.float32))

        def f_ref(x, y):
            return jnp.sum(ref.matmul_ref(x, y).astype(jnp.float32))

        grads_close(jax.grad(f_kernel, (0, 1))(x, y),
                    jax.grad(f_ref, (0, 1))(x, y))

    def test_grad_dtypes_follow_operands(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        x, y = rand(k1, (16, 16)), rand(k2, (16, 16))
        dx, dy = jax.grad(
            lambda x, y: jnp.sum(ad.matmul(x, y).astype(jnp.float32)),
            (0, 1))(x, y)
        assert dx.dtype == jnp.float16 and dy.dtype == jnp.float16


class TestSoftmaxVjp:
    def test_grads_match_oracle(self):
        x = rand(jax.random.PRNGKey(0), (8, 33), scale=2.0)
        w = rand(jax.random.PRNGKey(1), (8, 33))

        def f_kernel(x):
            return jnp.sum((ad.softmax(x) * w).astype(jnp.float32))

        def f_ref(x):
            return jnp.sum((ref.softmax_ref(x) * w).astype(jnp.float32))

        grads_close(jax.grad(f_kernel)(x), jax.grad(f_ref)(x))

    def test_zero_sum_property(self):
        """Softmax grad rows sum to ~0 (probability simplex tangent)."""
        x = rand(jax.random.PRNGKey(2), (4, 16))
        g = jax.grad(lambda x: float(0) + ad.softmax(x).astype(jnp.float32)[0, 0])(x)
        np.testing.assert_allclose(
            float(jnp.sum(g.astype(jnp.float32)[0])), 0.0, atol=1e-3)


class TestLayernormVjp:
    def test_grads_match_oracle(self):
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
        x = rand(k1, (12, 64), scale=2.0)
        g = rand(k2, (64,))
        b = rand(k3, (64,))
        w = rand(k4, (12, 64))

        def f_kernel(x, g, b):
            return jnp.sum((ad.layernorm(x, g, b) * w).astype(jnp.float32))

        def f_ref(x, g, b):
            return jnp.sum((ref.layernorm_ref(x, g, b) * w).astype(jnp.float32))

        grads_close(jax.grad(f_kernel, (0, 1, 2))(x, g, b),
                    jax.grad(f_ref, (0, 1, 2))(x, g, b))

    def test_dx_orthogonal_to_ones(self):
        """LN output is mean-invariant ⇒ dx rows sum to ~0."""
        x = rand(jax.random.PRNGKey(1), (3, 32))
        gamma = jnp.ones((32,), jnp.float16)
        beta = jnp.zeros((32,), jnp.float16)
        dx = jax.grad(
            lambda x: jnp.sum(ad.layernorm(x, gamma, beta).astype(jnp.float32) ** 2)
        )(x)
        np.testing.assert_allclose(
            np.asarray(jnp.sum(dx.astype(jnp.float32), -1)), 0.0, atol=2e-2)


class TestAttentionVjp:
    def test_grads_match_oracle(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q, k, v = (rand(kk, (2, 17, 8)) for kk in ks[:3])
        w = rand(ks[3], (2, 17, 8))

        def f_kernel(q, k, v):
            return jnp.sum((ad.attention(q, k, v) * w).astype(jnp.float32))

        def f_ref(q, k, v):
            return jnp.sum((ref.attention_ref(q, k, v) * w).astype(jnp.float32))

        grads_close(jax.grad(f_kernel, (0, 1, 2))(q, k, v),
                    jax.grad(f_ref, (0, 1, 2))(q, k, v))

    def test_under_vmap_and_jit(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (rand(kk, (4, 2, 9, 8)) for kk in ks)  # batch of 4

        @jax.jit
        def f(q, k, v):
            out = jax.vmap(ad.attention)(q, k, v)
            return jnp.sum(out.astype(jnp.float32))

        g = jax.grad(f, (0, 1, 2))(q, k, v)
        assert g[0].shape == q.shape
        for leaf in g:
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
