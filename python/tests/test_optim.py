"""Mini-Optax substrate + paper §3.5 optimizer_update."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx
from mpx import nn, optim


def make_params():
    return {"w": jnp.asarray([1.0, 2.0]), "b": jnp.asarray(0.5),
            "step": jnp.asarray(0)}


def grads_like(params, value=1.0):
    return {"w": jnp.full_like(params["w"], value),
            "b": jnp.asarray(value), "step": None}


class TestSGD:
    def test_plain_step(self):
        opt = optim.sgd(0.1)
        params = make_params()
        state = opt.init(mpx.filter_arrays(params, mpx.is_inexact_array))
        updates, state = opt.update(grads_like(params), state)
        out = nn.apply_updates(params, updates)
        np.testing.assert_allclose(np.asarray(out["w"]), [0.9, 1.9], rtol=1e-6)
        assert int(out["step"]) == 0  # int leaf untouched

    def test_momentum_accumulates(self):
        opt = optim.sgd(1.0, momentum=0.5)
        params = make_params()
        state = opt.init(mpx.filter_arrays(params, mpx.is_inexact_array))
        u1, state = opt.update(grads_like(params), state)
        u2, state = opt.update(grads_like(params), state)
        # v1 = g, v2 = 0.5 g + g = 1.5 g
        np.testing.assert_allclose(float(u2["b"]), -1.5)

    def test_quadratic_convergence(self):
        opt = optim.sgd(0.2)
        w = jnp.asarray(5.0)
        state = opt.init(w)
        for _ in range(50):
            g = 2 * w
            u, state = opt.update(g, state)
            w = w + u
        assert abs(float(w)) < 1e-3


class TestAdam:
    def test_bias_correction_first_step(self):
        """First Adam step ≈ -lr * sign(g) regardless of g's scale."""
        opt = optim.adam(0.1)
        g = jnp.asarray(1e-4)
        state = opt.init(jnp.asarray(0.0))
        u, state = opt.update(g, state)
        np.testing.assert_allclose(float(u), -0.1, rtol=1e-3)

    def test_moments_float32_under_half_grads(self):
        opt = optim.adam(0.1)
        g = jnp.asarray(0.5, jnp.float16)
        state = opt.init(jnp.asarray(0.0, jnp.float32))
        u, state = opt.update(g, state)
        assert state["mu"].dtype == jnp.float32
        assert u.dtype == jnp.float32

    def test_rosenbrock_descent(self):
        opt = optim.adam(0.05)

        def f(p):
            x, y = p
            return (1 - x) ** 2 + 100 * (y - x ** 2) ** 2

        p = jnp.asarray([-1.0, 1.0])
        state = opt.init(p)
        f0 = float(f(p))
        for _ in range(300):
            g = jax.grad(f)(p)
            u, state = opt.update(g, state)
            p = p + u
        assert float(f(p)) < f0 * 0.01


class TestAdamW:
    def test_weight_decay_pulls_to_zero(self):
        opt = optim.adamw(0.1, weight_decay=0.1)
        w = jnp.asarray(10.0)
        state = opt.init(w)
        u, state = opt.update(jnp.asarray(0.0), state, w)
        assert float(u) < 0  # decay even with zero gradient

    def test_requires_params(self):
        opt = optim.adamw(0.1, weight_decay=0.1)
        state = opt.init(jnp.asarray(1.0))
        with pytest.raises(ValueError):
            opt.update(jnp.asarray(0.0), state, None)


class TestCombinators:
    def test_clip_by_global_norm(self):
        opt = optim.clip_by_global_norm(1.0)
        g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
        state = opt.init(g)
        u, _ = opt.update(g, state)
        np.testing.assert_allclose(
            np.asarray(u["a"]), [0.6, 0.8], rtol=1e-5)

    def test_clip_noop_below_threshold(self):
        opt = optim.clip_by_global_norm(10.0)
        g = {"a": jnp.asarray([3.0, 4.0])}
        u, _ = opt.update(g, opt.init(g))
        np.testing.assert_allclose(np.asarray(u["a"]), [3.0, 4.0], rtol=1e-5)

    def test_chain(self):
        opt = optim.chain(optim.clip_by_global_norm(1.0), optim.sgd(1.0))
        g = {"a": jnp.asarray([30.0, 40.0])}
        state = opt.init(g)
        u, state = opt.update(g, state)
        np.testing.assert_allclose(
            np.asarray(u["a"]), [-0.6, -0.8], rtol=1e-5)

    def test_schedule_warmup(self):
        sched = optim.warmup_cosine_schedule(1.0, 10, 100)
        assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)

    def test_scale_by_schedule(self):
        sched = optim.warmup_cosine_schedule(0.5, 2, 100)
        opt = optim.scale_by_schedule(optim.sgd(0.5), sched, 0.5)
        g = jnp.asarray(1.0)
        state = opt.init(g)
        u, state = opt.update(g, state)  # step 1: lr = 0.5*(1/2) = 0.25
        np.testing.assert_allclose(float(u), -0.25, rtol=1e-5)


class TestOptimizerUpdate:
    """Paper §3.5: skip updates when gradients are non-finite."""

    def test_finite_applies(self):
        model = make_params()
        opt = optim.sgd(0.1)
        state = opt.init(mpx.filter_arrays(model, mpx.is_inexact_array))
        m2, s2 = mpx.optimizer_update(
            model, opt, state, grads_like(model), jnp.asarray(True))
        np.testing.assert_allclose(np.asarray(m2["w"]), [0.9, 1.9], rtol=1e-6)
        assert int(s2["count"]) == 1

    def test_nonfinite_skips_model_and_state(self):
        model = make_params()
        opt = optim.adam(0.1)
        state = opt.init(mpx.filter_arrays(model, mpx.is_inexact_array))
        bad = {"w": jnp.asarray([jnp.inf, 1.0]), "b": jnp.asarray(1.0),
               "step": None}
        m2, s2 = mpx.optimizer_update(model, opt, state, bad,
                                      jnp.asarray(False))
        np.testing.assert_array_equal(np.asarray(m2["w"]),
                                      np.asarray(model["w"]))
        # Adam moments must not absorb the inf
        assert int(s2["count"]) == 0
        assert bool(jnp.all(jnp.isfinite(s2["mu"]["w"])))

    def test_under_jit(self):
        model = make_params()
        opt = optim.sgd(0.1)
        state = opt.init(mpx.filter_arrays(model, mpx.is_inexact_array))

        @jax.jit
        def run(m, s, g, fin):
            return mpx.optimizer_update(m, opt, s, g, fin)

        m2, s2 = run(model, state, grads_like(model), jnp.asarray(True))
        np.testing.assert_allclose(float(m2["b"]), 0.4, rtol=1e-6)
        m3, s3 = run(model, state, grads_like(model), jnp.asarray(False))
        np.testing.assert_allclose(float(m3["b"]), 0.5, rtol=1e-6)

    def test_full_mixed_pipeline_recovers_from_overflow(self):
        """End-to-end §2.1 recipe: inject one overflow step; training
        continues and the scale halves exactly once."""
        key = jax.random.PRNGKey(0)
        model = nn.MLP(4, 8, key)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
        y = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
        opt = optim.adam(1e-2)
        state = opt.init(mpx.filter_arrays(model, mpx.is_inexact_array))
        scaling = mpx.DynamicLossScaling(2.0 ** 15, period=1000)

        def loss(m, b, boost):
            xb, yb = b
            pred = jax.vmap(m)(xb)
            return mpx.force_full_precision(
                lambda e: jnp.mean(jnp.square(e)), jnp.float32
            )(pred - yb) * boost

        for i in range(5):
            boost = 1e30 if i == 2 else 1.0
            scaling_new, finite, grads = mpx.filter_grad(
                lambda m, b: loss(m, b, boost), scaling)(model, (x, y))
            model, state = mpx.optimizer_update(
                model, opt, state, grads, finite)
            if i == 2:
                assert not bool(finite)
            scaling = scaling_new

        assert float(scaling.loss_scaling) == 2.0 ** 14
        for leaf in jax.tree_util.tree_leaves(model):
            assert bool(jnp.all(jnp.isfinite(leaf)))
