"""Paper §3.4: mixed-precision gradient transformations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx
from mpx import nn


def quadratic_loss(model, batch):
    x, y = batch
    pred = jax.vmap(model)(x)
    err = pred - y
    return mpx.force_full_precision(
        lambda e: jnp.mean(jnp.square(e)), jnp.float32)(err)


@pytest.fixture
def setup():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    model = nn.MLP(8, 16, k1)
    x = jax.random.normal(k2, (32, 8))
    y = jax.random.normal(k3, (32, 8))
    return model, (x, y)


class TestFilterValueAndGrad:
    def test_returns_quadruple(self, setup):
        model, batch = setup
        s = mpx.DynamicLossScaling(1024.0)
        loss, s2, finite, grads = mpx.filter_value_and_grad(
            quadratic_loss, s)(model, batch)
        assert loss.dtype == jnp.float32
        assert bool(finite)
        assert isinstance(s2, mpx.DynamicLossScaling)

    def test_loss_unscaled(self, setup):
        """Returned loss must be the *unscaled* loss."""
        model, batch = setup
        ref = float(quadratic_loss(model, batch))
        s = mpx.DynamicLossScaling(2.0 ** 12)
        loss, *_ = mpx.filter_value_and_grad(quadratic_loss, s)(model, batch)
        np.testing.assert_allclose(float(loss), ref, rtol=2e-2)

    def test_grads_are_float32(self, setup):
        model, batch = setup
        s = mpx.DynamicLossScaling(1024.0)
        _, _, _, grads = mpx.filter_value_and_grad(
            quadratic_loss, s)(model, batch)
        for g in jax.tree_util.tree_leaves(grads):
            assert g.dtype == jnp.float32

    def test_grads_match_fp32_reference(self, setup):
        """Mixed-precision grads ≈ full-precision grads (the paper's
        whole premise: same model quality)."""
        model, batch = setup

        diff, static = mpx.partition(model, mpx.is_inexact_array)
        ref_grads = jax.grad(
            lambda d: quadratic_loss(mpx.combine(d, static), batch))(diff)

        s = mpx.DynamicLossScaling(2.0 ** 12)
        _, _, _, grads = mpx.filter_value_and_grad(
            quadratic_loss, s)(model, batch)

        for g, r in zip(jax.tree_util.tree_leaves(grads),
                        jax.tree_util.tree_leaves(ref_grads)):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), atol=2e-2, rtol=5e-2)

    def test_grad_structure_matches_model(self, setup):
        model, batch = setup
        s = mpx.DynamicLossScaling(1024.0)
        _, _, _, grads = mpx.filter_value_and_grad(
            quadratic_loss, s)(model, batch)
        assert jax.tree_util.tree_structure(grads) == \
            jax.tree_util.tree_structure(model)

    def test_scaling_adjusts_on_overflow(self, setup):
        model, batch = setup

        def exploding_loss(m, b):
            # Huge loss → scaled loss overflows f16 in backward.
            return quadratic_loss(m, b) * 1e30

        s = mpx.DynamicLossScaling(2.0 ** 15)
        _, s2, finite, _ = mpx.filter_value_and_grad(
            exploding_loss, s)(model, batch)
        assert not bool(finite)
        assert float(s2.loss_scaling) == 2.0 ** 14

    def test_forward_runs_in_half(self, setup):
        model, batch = setup
        seen = {}

        def probing_loss(m, b):
            seen["dtype"] = m.fc_in.weight.dtype
            return quadratic_loss(m, b)

        s = mpx.DynamicLossScaling(1024.0)
        mpx.filter_value_and_grad(probing_loss, s)(model, batch)
        assert seen["dtype"] == mpx.get_half_dtype()

    def test_fp32_flag_disables_casting(self, setup):
        model, batch = setup
        seen = {}

        def probing_loss(m, b):
            seen["dtype"] = m.fc_in.weight.dtype
            return quadratic_loss(m, b)

        s = mpx.NoOpLossScaling()
        mpx.filter_value_and_grad(
            probing_loss, s, use_mixed_precision=False)(model, batch)
        assert seen["dtype"] == jnp.float32

    def test_has_aux(self, setup):
        model, batch = setup

        def loss_with_aux(m, b):
            l = quadratic_loss(m, b)
            return l, {"acc": jnp.asarray(0.5)}

        s = mpx.DynamicLossScaling(1024.0)
        (loss, aux), s2, finite, grads = mpx.filter_value_and_grad(
            loss_with_aux, s, has_aux=True)(model, batch)
        assert float(aux["acc"]) == 0.5
        assert bool(finite)

    def test_under_jit(self, setup):
        model, batch = setup
        s = mpx.DynamicLossScaling(1024.0)

        @jax.jit
        def run(m, s, b):
            return mpx.filter_value_and_grad(quadratic_loss, s)(m, b)

        loss, s2, finite, grads = run(model, s, batch)
        assert bool(finite)


class TestFilterGrad:
    def test_paper_signature(self, setup):
        """Paper Example 2b: loss_scaling, grads_finite, grads = ..."""
        model, batch = setup
        s = mpx.DynamicLossScaling(1024.0)
        loss_scaling, grads_finite, grads = mpx.filter_grad(
            quadratic_loss, s)(model, batch)
        assert isinstance(loss_scaling, mpx.DynamicLossScaling)
        assert bool(grads_finite)

    def test_aux_appended(self, setup):
        model, batch = setup

        def loss_with_aux(m, b):
            return quadratic_loss(m, b), jnp.asarray(7.0)

        s = mpx.DynamicLossScaling(1024.0)
        s2, finite, grads, aux = mpx.filter_grad(
            loss_with_aux, s, has_aux=True)(model, batch)
        assert float(aux) == 7.0


class TestUnderflowMotivation:
    def test_tiny_grads_underflow_without_scaling(self):
        """The paper's §2.1 motivation, reproduced: with scale=1 a tiny
        loss produces f16 gradients that round to zero; with dynamic
        scaling they survive."""
        w = {"w": jnp.asarray(1.0, jnp.float32)}

        def tiny_loss(m, x):
            # d/dw = x*x = 1e-8.  The backward chain computes the
            # cotangent product (1 · x) · x in f16: 1e-8 is below f16's
            # smallest subnormal (~5.96e-8) and rounds to zero — unless
            # the chain starts from a scaled cotangent.
            return ((m["w"] * x) * x).astype(jnp.float32)

        x = jnp.asarray(1e-4, jnp.float32)  # itself f16-representable

        s1 = mpx.StaticLossScaling(1.0)
        _, _, _, g1 = mpx.filter_value_and_grad(tiny_loss, s1)(w, x)
        s2 = mpx.StaticLossScaling(2.0 ** 15)
        _, _, _, g2 = mpx.filter_value_and_grad(tiny_loss, s2)(w, x)

        assert float(g1["w"]) == 0.0  # underflowed
        assert float(g2["w"]) != 0.0  # rescued by scaling
        np.testing.assert_allclose(float(g2["w"]), 1e-8, rtol=0.15)
