"""Paper §3.1/§3.2: PyTree and function casting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx


def make_tree(key):
    return {
        "w": jax.random.normal(key, (4, 4), jnp.float32),
        "nested": [
            jnp.ones((3,), jnp.float32),
            {"b": jnp.zeros((2,), jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)},
        ],
        "ints": jnp.arange(5, dtype=jnp.int32),
        "key": jax.random.PRNGKey(0),
        "scalar": 3.5,
        "flag": True,
        "none": None,
    }


class TestCastTree:
    def test_float_leaves_cast(self):
        tree = make_tree(jax.random.PRNGKey(1))
        out = mpx.cast_tree(tree, jnp.float16)
        assert out["w"].dtype == jnp.float16
        assert out["nested"][0].dtype == jnp.float16
        assert out["nested"][1]["b"].dtype == jnp.float16

    def test_integer_leaves_untouched(self):
        """Crucial: PRNG keys and int arrays must never be cast."""
        tree = make_tree(jax.random.PRNGKey(1))
        out = mpx.cast_tree(tree, jnp.float16)
        assert out["ints"].dtype == jnp.int32
        assert (out["key"] == tree["key"]).all()

    def test_python_scalars_untouched(self):
        tree = make_tree(jax.random.PRNGKey(1))
        out = mpx.cast_tree(tree, jnp.float16)
        assert out["scalar"] == 3.5 and isinstance(out["scalar"], float)
        assert out["flag"] is True
        assert out["none"] is None

    def test_values_preserved_within_precision(self):
        x = jnp.linspace(-4.0, 4.0, 33)
        y = mpx.cast_tree(x, jnp.float16)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(x),
                                   rtol=1e-3)

    def test_numpy_leaves_cast(self):
        tree = {"a": np.ones((2, 2), np.float32)}
        out = mpx.cast_tree(tree, jnp.bfloat16)
        assert out["a"].dtype == jnp.bfloat16

    def test_roundtrip_structure(self):
        tree = make_tree(jax.random.PRNGKey(2))
        out = mpx.cast_to_float32(mpx.cast_to_float16(tree))
        assert jax.tree_util.tree_structure(out) == \
            jax.tree_util.tree_structure(tree)


class TestConvenienceCasts:
    def test_cast_to_float16(self):
        assert mpx.cast_to_float16(jnp.ones(3)).dtype == jnp.float16

    def test_cast_to_bfloat16(self):
        assert mpx.cast_to_bfloat16(jnp.ones(3)).dtype == jnp.bfloat16

    def test_cast_to_float32(self):
        assert mpx.cast_to_float32(jnp.ones(3, jnp.float16)).dtype == jnp.float32

    def test_half_policy_default_f16(self):
        assert mpx.get_half_dtype() == jnp.dtype(jnp.float16)
        assert mpx.cast_to_half_precision(jnp.ones(3)).dtype == jnp.float16

    def test_half_policy_switch(self):
        mpx.set_half_dtype(jnp.bfloat16)
        try:
            assert mpx.cast_to_half_precision(jnp.ones(3)).dtype == jnp.bfloat16
        finally:
            mpx.set_half_dtype(jnp.float16)

    def test_half_policy_rejects_f32(self):
        with pytest.raises(ValueError):
            mpx.set_half_dtype(jnp.float32)


class TestCastFunction:
    def test_inputs_cast(self):
        seen = {}

        def fn(x):
            seen["dtype"] = x.dtype
            return x * 2

        out = mpx.cast_function(fn, jnp.float16)(jnp.ones(3, jnp.float32))
        assert seen["dtype"] == jnp.float16
        assert out.dtype == jnp.float16

    def test_return_dtype(self):
        fn = mpx.cast_function(lambda x: x + 1, jnp.float16,
                               return_dtype=jnp.float32)
        assert fn(jnp.ones(3)).dtype == jnp.float32

    def test_kwargs_cast(self):
        def fn(x, *, y):
            return x + y

        out = mpx.cast_function(fn, jnp.float16)(
            jnp.ones(3), y=jnp.ones(3, jnp.float32))
        assert out.dtype == jnp.float16

    def test_pytree_args(self):
        def fn(batch):
            return batch["a"] + batch["b"]

        out = mpx.cast_function(fn, jnp.float16)(
            {"a": jnp.ones(3), "b": jnp.zeros(3)})
        assert out.dtype == jnp.float16


class TestForceFullPrecision:
    def test_computation_in_f32(self):
        seen = {}

        def fn(x):
            seen["dtype"] = x.dtype
            return jnp.sum(x)

        x16 = jnp.ones(10, jnp.float16)
        out = mpx.force_full_precision(fn, x16.dtype)(x16)
        assert seen["dtype"] == jnp.float32
        assert out.dtype == jnp.float16

    def test_prevents_softmax_overflow(self):
        """Softmax over large-magnitude f16 logits: exp overflows in f16
        unless computed in f32 (paper Example 1)."""
        logits = jnp.asarray([60000.0, 0.0, -60000.0], jnp.float16)

        safe = mpx.force_full_precision(jax.nn.softmax, logits.dtype)(logits)
        assert bool(jnp.all(jnp.isfinite(safe)))
        np.testing.assert_allclose(
            np.asarray(safe, np.float32), [1.0, 0.0, 0.0], atol=1e-3)

    def test_prevents_sum_overflow(self):
        """Summing many f16 values overflows f16's 65504 max."""
        x = jnp.full((4096,), 100.0, jnp.float16)  # true sum 409600
        naive = jnp.sum(x)
        assert not bool(jnp.isfinite(naive.astype(jnp.float32))) or \
            naive.dtype != jnp.float16  # xla may accumulate wider; accept either
        safe = mpx.force_full_precision(jnp.sum, jnp.float32)(x)
        np.testing.assert_allclose(float(safe), 409600.0, rtol=1e-3)

    def test_under_jit(self):
        @jax.jit
        def fn(x):
            return mpx.force_full_precision(jnp.mean, x.dtype)(x)

        out = fn(jnp.ones(7, jnp.float16))
        assert out.dtype == jnp.float16
        assert float(out) == 1.0
