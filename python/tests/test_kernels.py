"""L1 Pallas kernels vs pure-jnp oracles (the CORE correctness signal).

Hypothesis sweeps shapes and dtypes; assert_allclose tolerances follow
the output precision (f16 ⇒ ~1e-3 relative, bf16 ⇒ ~1e-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

HALF_DTYPES = [jnp.float16, jnp.bfloat16]
ALL_DTYPES = HALF_DTYPES + [jnp.float32]


def tol(dtype):
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.bfloat16):
        return dict(rtol=3e-2, atol=3e-2)
    if d == jnp.dtype(jnp.float16):
        return dict(rtol=5e-3, atol=5e-3)
    return dict(rtol=1e-5, atol=1e-5)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# mixed_matmul
# ---------------------------------------------------------------------------


class TestMixedMatmul:
    @pytest.mark.parametrize("dtype", ALL_DTYPES)
    def test_square(self, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x, y = rand(k1, (64, 64), dtype), rand(k2, (64, 64), dtype)
        close(kernels.mixed_matmul(x, y), ref.matmul_ref(x, y), dtype)

    @pytest.mark.parametrize("dtype", HALF_DTYPES)
    def test_rectangular_multiblock(self, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        x, y = rand(k1, (256, 192), dtype), rand(k2, (192, 320), dtype)
        out = kernels.mixed_matmul(x, y, block_m=64, block_n=64, block_k=64)
        close(out, ref.matmul_ref(x, y), dtype)

    def test_output_dtype_follows_input(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        x, y = rand(k1, (32, 32), jnp.float16), rand(k2, (32, 32), jnp.float16)
        assert kernels.mixed_matmul(x, y).dtype == jnp.float16

    def test_out_dtype_override(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        x, y = rand(k1, (32, 32), jnp.float16), rand(k2, (32, 32), jnp.float16)
        out = kernels.mixed_matmul(x, y, out_dtype=jnp.float32)
        assert out.dtype == jnp.float32

    def test_f32_accumulation_beats_f16(self):
        """A long contraction of alternating ±x plus tiny residues: f16
        accumulation loses the residues, f32 keeps them."""
        k = 2048
        big = np.tile([1.0, -1.0], k // 2).astype(np.float16)
        x = jnp.asarray(big + np.full(k, 1e-3, np.float16)).reshape(1, k)
        y = jnp.ones((k, 1), jnp.float16)
        out = kernels.mixed_matmul(x, y, out_dtype=jnp.float32)
        # truth: k * 1e-3 ≈ 2.0 (up to f16 rounding of 1e-3)
        expect = float(jnp.sum(x.astype(jnp.float32)))
        np.testing.assert_allclose(float(out[0, 0]), expect, rtol=1e-3)

    def test_nonsquare_odd_blocks(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        # 65 is prime → falls back to full-dim blocks on that axis
        x, y = rand(k1, (65, 48), jnp.float16), rand(k2, (48, 40), jnp.float16)
        close(kernels.mixed_matmul(x, y), ref.matmul_ref(x, y), jnp.float16)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 32, 64, 96]),
        k=st.sampled_from([8, 16, 32, 64, 128]),
        n=st.sampled_from([8, 16, 32, 48]),
        dtype=st.sampled_from([0, 1, 2]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_matches_ref(self, m, k, n, dtype, seed):
        dtype = ALL_DTYPES[dtype]
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x, y = rand(k1, (m, k), dtype), rand(k2, (k, n), dtype)
        out = kernels.mixed_matmul(x, y, block_m=32, block_n=32, block_k=32)
        close(out, ref.matmul_ref(x, y), dtype)

    def test_vmem_budget_vit_base(self):
        """Default blocks stay well inside a 16 MiB VMEM budget."""
        from compile.kernels.matmul import vmem_bytes
        assert vmem_bytes(128, 128, 128) < 16 * 2 ** 20


# ---------------------------------------------------------------------------
# softmax_fp32
# ---------------------------------------------------------------------------


class TestSoftmaxFp32:
    @pytest.mark.parametrize("dtype", ALL_DTYPES)
    def test_matches_ref(self, dtype):
        x = rand(jax.random.PRNGKey(0), (64, 128), dtype, scale=3.0)
        close(kernels.softmax_fp32(x), ref.softmax_ref(x), dtype)

    def test_rows_sum_to_one(self):
        x = rand(jax.random.PRNGKey(1), (32, 100), jnp.float16, scale=5.0)
        s = jnp.sum(kernels.softmax_fp32(x).astype(jnp.float32), axis=-1)
        np.testing.assert_allclose(np.asarray(s), 1.0, atol=5e-3)

    def test_no_overflow_on_large_logits(self):
        """The reason for f32 internals: e^20 > f16 max."""
        x = jnp.full((4, 64), 20.0, jnp.float16)
        out = kernels.softmax_fp32(x)
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), 1.0 / 64, rtol=1e-2)

    def test_multiblock_rows(self):
        x = rand(jax.random.PRNGKey(2), (512, 65), jnp.bfloat16)
        out = kernels.softmax_fp32(x, block_rows=128)
        close(out, ref.softmax_ref(x), jnp.bfloat16)

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.sampled_from([1, 3, 16, 65, 128]),
        cols=st.sampled_from([2, 17, 64, 257]),
        scale=st.sampled_from([0.1, 1.0, 8.0]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_matches_ref(self, rows, cols, scale, seed):
        x = rand(jax.random.PRNGKey(seed), (rows, cols), jnp.float16, scale)
        close(kernels.softmax_fp32(x), ref.softmax_ref(x), jnp.float16)


# ---------------------------------------------------------------------------
# layernorm_fp32
# ---------------------------------------------------------------------------


class TestLayernormFp32:
    @pytest.mark.parametrize("dtype", ALL_DTYPES)
    def test_matches_ref(self, dtype):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        x = rand(k1, (64, 256), dtype, scale=2.0)
        g = rand(k2, (256,), dtype)
        b = rand(k3, (256,), dtype)
        close(kernels.layernorm_fp32(x, g, b),
              ref.layernorm_ref(x, g, b), dtype)

    def test_normalizes(self):
        x = rand(jax.random.PRNGKey(1), (8, 512), jnp.float16, scale=10.0)
        g = jnp.ones((512,), jnp.float16)
        b = jnp.zeros((512,), jnp.float16)
        out = kernels.layernorm_fp32(x, g, b).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(jnp.mean(out, -1)), 0.0,
                                   atol=5e-3)
        np.testing.assert_allclose(np.asarray(jnp.std(out, -1)), 1.0,
                                   atol=2e-2)

    def test_large_mean_no_overflow(self):
        """Inputs with mean ~60000: the f16 sum would overflow."""
        x = jnp.full((4, 4096), 60000.0, jnp.float16)
        g = jnp.ones((4096,), jnp.float16)
        b = jnp.zeros((4096,), jnp.float16)
        out = kernels.layernorm_fp32(x, g, b)
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.sampled_from([1, 7, 65, 256]),
        cols=st.sampled_from([8, 64, 256, 800]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_matches_ref(self, rows, cols, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = rand(k1, (rows, cols), jnp.float16, 3.0)
        g = rand(k2, (cols,), jnp.float16)
        b = rand(k3, (cols,), jnp.float16)
        close(kernels.layernorm_fp32(x, g, b),
              ref.layernorm_ref(x, g, b), jnp.float16)


# ---------------------------------------------------------------------------
# fused_attention
# ---------------------------------------------------------------------------


class TestFusedAttention:
    @pytest.mark.parametrize("dtype", ALL_DTYPES)
    def test_matches_ref(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (rand(kk, (4, 65, 32), dtype) for kk in ks)
        close(kernels.fused_attention(q, k, v),
              ref.attention_ref(q, k, v), dtype)

    def test_single_head(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (rand(kk, (1, 16, 8), jnp.float16) for kk in ks)
        close(kernels.fused_attention(q, k, v),
              ref.attention_ref(q, k, v), jnp.float16)

    def test_uniform_scores_average_values(self):
        """q=0 ⇒ uniform attention ⇒ output = mean(v)."""
        h, s, d = 2, 10, 4
        q = jnp.zeros((h, s, d), jnp.float16)
        k = rand(jax.random.PRNGKey(2), (h, s, d), jnp.float16)
        v = rand(jax.random.PRNGKey(3), (h, s, d), jnp.float16)
        out = kernels.fused_attention(q, k, v).astype(jnp.float32)
        expect = jnp.mean(v.astype(jnp.float32), axis=1, keepdims=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(jnp.broadcast_to(expect, out.shape)),
            atol=1e-2)

    def test_large_logits_stable(self):
        """Big q·k products overflow f16 exp without f32 internals."""
        h, s, d = 1, 8, 16
        q = jnp.full((h, s, d), 16.0, jnp.float16)
        k = jnp.full((h, s, d), 16.0, jnp.float16)
        v = rand(jax.random.PRNGKey(4), (h, s, d), jnp.float16)
        out = kernels.fused_attention(q, k, v)
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))

    def test_shape_mismatch_raises(self):
        q = jnp.zeros((2, 8, 4), jnp.float16)
        v = jnp.zeros((2, 9, 4), jnp.float16)
        with pytest.raises(ValueError):
            kernels.fused_attention(q, q, v)

    @settings(max_examples=10, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 8]),
        s=st.sampled_from([4, 17, 65]),
        d=st.sampled_from([8, 32, 64]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_matches_ref(self, h, s, d, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (rand(kk, (h, s, d), jnp.bfloat16) for kk in ks)
        close(kernels.fused_attention(q, k, v),
              ref.attention_ref(q, k, v), jnp.bfloat16)


# ---------------------------------------------------------------------------
# scaling kernels
# ---------------------------------------------------------------------------


class TestScaleCast:
    def test_matches_ref(self):
        x = rand(jax.random.PRNGKey(0), (64, 32), jnp.float32)
        s = jnp.asarray(1024.0)
        out = kernels.scale_cast(x, s, jnp.float16)
        close(out, ref.scale_cast_ref(x, s, jnp.float16), jnp.float16)

    def test_dtype(self):
        x = jnp.ones((8, 8), jnp.float32)
        assert kernels.scale_cast(x, jnp.asarray(2.0), jnp.bfloat16).dtype \
            == jnp.bfloat16


class TestUnscaleCheck:
    def test_finite_path(self):
        g = rand(jax.random.PRNGKey(0), (128, 16), jnp.float16, 100.0)
        s = jnp.asarray(64.0)
        out, finite = kernels.unscale_check(g, s)
        rout, rfin = ref.unscale_check_ref(g, s)
        assert bool(finite) and bool(rfin)
        close(out, rout, jnp.float32)
        assert out.dtype == jnp.float32

    def test_inf_detected(self):
        g = np.zeros((64, 8), np.float16)
        g[37, 3] = np.inf
        out, finite = kernels.unscale_check(jnp.asarray(g), jnp.asarray(2.0))
        assert not bool(finite)

    def test_nan_detected_any_block(self):
        g = np.zeros((512, 4), np.float16)
        g[500, 0] = np.nan  # lands in the last grid block
        out, finite = kernels.unscale_check(
            jnp.asarray(g), jnp.asarray(2.0), block_rows=64)
        assert not bool(finite)

    @settings(max_examples=10, deadline=None)
    @given(
        rows=st.sampled_from([4, 64, 200]),
        cols=st.sampled_from([1, 16, 33]),
        scale=st.sampled_from([1.0, 128.0, 2.0 ** 15]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_matches_ref(self, rows, cols, scale, seed):
        g = rand(jax.random.PRNGKey(seed), (rows, cols), jnp.float16, 10.0)
        s = jnp.asarray(scale)
        out, finite = kernels.unscale_check(g, s)
        rout, rfin = ref.unscale_check_ref(g, s)
        close(out, rout, jnp.float32)
        assert bool(finite) == bool(rfin)
