"""L2 train-step builders + AOT manifest contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx
from compile import aot, trainstep as ts
from compile.model import make_config


CFG = make_config("vit_tiny")


def batch(b=8, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(k1, (b, 3, 32, 32)),
        jax.random.randint(k2, (b,), 0, 10),
    )


class TestInit:
    def test_shapes_and_groups(self):
        model, opt_state, scaling = ts.concrete_state(CFG, "mixed_f16")
        assert isinstance(scaling, mpx.DynamicLossScaling)
        assert float(scaling.loss_scaling) == 2.0 ** 15
        assert int(opt_state["count"]) == 0

    def test_fp32_scaling_pinned(self):
        _, _, scaling = ts.concrete_state(CFG, "fp32")
        assert float(scaling.loss_scaling) == 1.0
        # pinned: growth unreachable, clamped at 1
        s = scaling.adjust(jnp.asarray(True))
        assert float(s.loss_scaling) == 1.0

    def test_deterministic_in_seed(self):
        m1, _, _ = ts.concrete_state(CFG, "fp32", seed=4)
        m2, _, _ = ts.concrete_state(CFG, "fp32", seed=4)
        m3, _, _ = ts.concrete_state(CFG, "fp32", seed=5)
        # compare a weight leaf (the first tree leaf can be a zeros
        # bias, identical across seeds by construction)
        a, b, c = (m.patch_embed.weight for m in (m1, m2, m3))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))


class TestFusedStep:
    def test_loss_decreases(self):
        state = ts.concrete_state(CFG, "mixed_f16")
        step = jax.jit(ts.build_step_fused(CFG, "mixed_f16"))
        model, opt_state, scaling = state
        imgs, labels = batch()
        losses = []
        for _ in range(12):
            model, opt_state, scaling, loss, finite = step(
                model, opt_state, scaling, imgs, labels)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_fp32_never_overflows(self):
        model, opt_state, scaling = ts.concrete_state(CFG, "fp32")
        step = jax.jit(ts.build_step_fused(CFG, "fp32"))
        imgs, labels = batch()
        for _ in range(5):
            model, opt_state, scaling, loss, finite = step(
                model, opt_state, scaling, imgs, labels)
            assert bool(finite)
        assert float(scaling.loss_scaling) == 1.0

    def test_master_params_stay_f32(self):
        model, opt_state, scaling = ts.concrete_state(CFG, "mixed_f16")
        step = jax.jit(ts.build_step_fused(CFG, "mixed_f16"))
        imgs, labels = batch()
        model, *_ = step(model, opt_state, scaling, imgs, labels)
        for leaf in jax.tree_util.tree_leaves(model):
            if mpx.is_inexact_array(leaf):
                assert leaf.dtype == jnp.float32


class TestGradsStep:
    def test_returns_unscaled_f32_grads(self):
        model, _, _ = ts.concrete_state(CFG, "mixed_f16")
        grads_fn = jax.jit(ts.build_grads(CFG, "mixed_f16"))
        imgs, labels = batch()
        grads, loss, finite = grads_fn(
            model, jnp.asarray(1024.0), imgs, labels)
        assert bool(finite)
        leaves = [g for g in jax.tree_util.tree_leaves(grads)]
        assert leaves and all(g.dtype == jnp.float32 for g in leaves)

    def test_scale_invariance(self):
        """Unscaled grads must be (nearly) independent of the scale —
        the whole point of the §2.1 recipe."""
        model, _, _ = ts.concrete_state(CFG, "mixed_f16")
        grads_fn = jax.jit(ts.build_grads(CFG, "mixed_f16"))
        imgs, labels = batch()
        g1, *_ = grads_fn(model, jnp.asarray(256.0), imgs, labels)
        g2, *_ = grads_fn(model, jnp.asarray(4096.0), imgs, labels)
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g2)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-3, rtol=5e-2)


class TestFwd:
    def test_logits_f32(self):
        model, _, _ = ts.concrete_state(CFG, "mixed_f16")
        fwd = jax.jit(ts.build_fwd(CFG, "mixed_f16"))
        imgs, _ = batch()
        logits = fwd(model, imgs)
        assert logits.shape == (8, 10)
        assert logits.dtype == jnp.float32


class TestAotEmission:
    def test_emit_and_manifest(self, tmp_path):
        spec = dict(kind="step_fused", model="vit_tiny",
                    precision="mixed_f16", batch=4)
        aot.emit("t_step", spec, str(tmp_path))
        hlo = (tmp_path / "t_step.hlo.txt").read_text()
        assert hlo.startswith("HloModule")
        man = json.loads((tmp_path / "t_step.manifest.json").read_text())
        groups = [e["group"] for e in man["inputs"]]
        # groups are contiguous and ordered params→opt→scaling→batch
        order = []
        for g in groups:
            if not order or order[-1] != g:
                order.append(g)
        assert order == ["params", "opt_state", "scaling", "images", "labels"]
        out_groups = {e["group"] for e in man["outputs"]}
        assert out_groups == {"params", "opt_state", "scaling", "loss",
                              "finite"}
        # state contract: init-able (same leaf count in and out)
        n_state = sum(1 for e in man["inputs"]
                      if e["group"] in ("params", "opt_state", "scaling"))
        n_out = sum(1 for e in man["outputs"]
                    if e["group"] in ("params", "opt_state", "scaling"))
        assert n_state == n_out

    def test_emit_skips_when_up_to_date(self, tmp_path):
        spec = dict(kind="init", model="vit_tiny", precision="fp32")
        r1 = aot.emit("t_init", spec, str(tmp_path))
        r2 = aot.emit("t_init", spec, str(tmp_path))
        assert not r1.get("skipped")
        assert r2.get("skipped")

    def test_trainable_marks_float_leaves_only(self, tmp_path):
        spec = dict(kind="grads", model="vit_tiny",
                    precision="mixed_f16", batch=4)
        aot.emit("t_grads", spec, str(tmp_path))
        man = json.loads((tmp_path / "t_grads.manifest.json").read_text())
        params = [e for e in man["inputs"] if e["group"] == "params"]
        assert all(e["trainable"] == (e["dtype"] in ("f32", "f16", "bf16"))
                   for e in params)
        n_grads = sum(1 for e in man["outputs"] if e["group"] == "grads")
        n_trainable = sum(1 for e in params if e["trainable"])
        assert n_grads == n_trainable

    def test_dtype_names(self):
        assert aot._dtype_name(jnp.float16) == "f16"
        assert aot._dtype_name(jnp.bfloat16) == "bf16"
        assert aot._dtype_name(jnp.int32) == "s32"
        assert aot._dtype_name(jnp.bool_) == "pred"
        with pytest.raises(ValueError):
            aot._dtype_name(jnp.float64)
