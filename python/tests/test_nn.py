"""Mini-Equinox substrate: modules as PyTrees, filtered transforms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx
from mpx import nn


class TestModulePytree:
    def test_linear_flattens_to_arrays(self):
        lin = nn.Linear(4, 8, jax.random.PRNGKey(0))
        leaves = jax.tree_util.tree_leaves(lin)
        assert len(leaves) == 2  # weight + bias
        assert all(mpx.is_array(l) for l in leaves)

    def test_static_fields_survive_roundtrip(self):
        lin = nn.Linear(4, 8, jax.random.PRNGKey(0))
        leaves, treedef = jax.tree_util.tree_flatten(lin)
        lin2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert lin2.in_features == 4 and lin2.out_features == 8
        np.testing.assert_array_equal(lin2.weight, lin.weight)

    def test_no_bias_structure_stable(self):
        lin = nn.Linear(4, 8, jax.random.PRNGKey(0), use_bias=False)
        leaves, treedef = jax.tree_util.tree_flatten(lin)
        assert len(leaves) == 1
        lin2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert lin2.bias is None

    def test_nested_modules_recurse(self):
        mlp = nn.MLP(4, 16, jax.random.PRNGKey(0))
        leaves = jax.tree_util.tree_leaves(mlp)
        assert len(leaves) == 4  # two Linears × (w, b)

    def test_module_under_jit(self):
        mlp = nn.MLP(4, 16, jax.random.PRNGKey(0))

        @jax.jit
        def fwd(m, x):
            return m(x)

        out = fwd(mlp, jnp.ones(4))
        assert out.shape == (4,)

    def test_flatten_deterministic_order(self):
        """Sorted-attribute flattening — the AOT manifest relies on it."""
        lin = nn.Linear(2, 2, jax.random.PRNGKey(0))
        paths = [
            jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(lin)[0]
        ]
        assert paths == sorted(paths)

    def test_float_hyperparams_static(self):
        ln = nn.LayerNorm(8, eps=1e-3)
        leaves = jax.tree_util.tree_leaves(ln)
        assert len(leaves) == 2  # weight, bias — eps is static
        _, treedef = jax.tree_util.tree_flatten(ln)
        ln2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert ln2.eps == 1e-3


class TestPartitionCombine:
    def test_partition_roundtrip(self):
        mlp = nn.MLP(4, 16, jax.random.PRNGKey(0))
        diff, static = mpx.partition(mlp, mpx.is_inexact_array)
        back = mpx.combine(diff, static)
        assert jax.tree_util.tree_structure(back) == \
            jax.tree_util.tree_structure(mlp)
        np.testing.assert_array_equal(back.fc_in.weight, mlp.fc_in.weight)

    def test_partition_excludes_ints(self):
        tree = {"w": jnp.ones(3), "step": jnp.asarray(5)}
        diff, static = mpx.partition(tree, mpx.is_inexact_array)
        assert diff["step"] is None
        assert static["w"] is None
        assert int(static["step"]) == 5

    def test_grad_through_partition(self):
        tree = {"w": jnp.asarray(3.0), "n": jnp.asarray(7)}
        diff, static = mpx.partition(tree, mpx.is_inexact_array)

        def f(d):
            t = mpx.combine(d, static)
            return t["w"] ** 2

        g = jax.grad(f)(diff)
        assert float(g["w"]) == 6.0
        assert g["n"] is None


class TestApplyUpdates:
    def test_updates_applied(self):
        lin = nn.Linear(2, 2, jax.random.PRNGKey(0))
        updates, _ = mpx.partition(lin, mpx.is_inexact_array)
        updates = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), updates)
        out = nn.apply_updates(lin, updates)
        np.testing.assert_allclose(
            np.asarray(out.weight), np.asarray(lin.weight) + 1.0)

    def test_none_updates_skip(self):
        tree = {"w": jnp.ones(2), "step": jnp.asarray(3)}
        out = nn.apply_updates(tree, {"w": jnp.ones(2), "step": None})
        assert int(out["step"]) == 3
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)


class TestLayers:
    def test_linear_matches_manual(self):
        lin = nn.Linear(3, 5, jax.random.PRNGKey(1))
        x = jnp.arange(3.0)
        np.testing.assert_allclose(
            np.asarray(lin(x)),
            np.asarray(x @ lin.weight.T + lin.bias), rtol=1e-6)

    def test_linear_batched_last_axis(self):
        lin = nn.Linear(3, 5, jax.random.PRNGKey(1))
        x = jnp.ones((7, 3))
        assert lin(x).shape == (7, 5)

    def test_layernorm_normalizes(self):
        ln = nn.LayerNorm(16)
        x = jax.random.normal(jax.random.PRNGKey(2), (16,)) * 10 + 3
        y = ln(x)
        assert abs(float(jnp.mean(y))) < 1e-4
        np.testing.assert_allclose(float(jnp.std(y)), 1.0, atol=1e-2)

    def test_layernorm_dtype_follows_input(self):
        ln = mpx.cast_to_float16(nn.LayerNorm(8))
        y = ln(jnp.ones(8, jnp.float16))
        assert y.dtype == jnp.float16

    def test_embedding_lookup(self):
        emb = nn.Embedding(10, 4, jax.random.PRNGKey(3))
        out = emb(jnp.asarray([1, 1, 2]))
        assert out.shape == (3, 4)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))

    def test_dropout_inference_identity(self):
        x = jnp.ones(100)
        assert (nn.Dropout(0.5)(x) == x).all()

    def test_dropout_training_masks(self):
        x = jnp.ones(10000)
        y = nn.Dropout(0.5)(x, key=jax.random.PRNGKey(0))
        frac = float(jnp.mean(y == 0))
        assert 0.45 < frac < 0.55
        # E[y] preserved
        np.testing.assert_allclose(float(jnp.mean(y)), 1.0, atol=0.05)

    def test_mlp_shapes(self):
        mlp = nn.MLP(8, 32, jax.random.PRNGKey(0))
        assert mlp(jnp.ones(8)).shape == (8,)

    def test_sequential(self):
        seq = nn.Sequential([
            nn.Linear(4, 8, jax.random.PRNGKey(0)),
            jax.nn.relu,
            nn.Linear(8, 2, jax.random.PRNGKey(1)),
        ])
        assert seq(jnp.ones(4)).shape == (2,)

    def test_casting_whole_model(self):
        """Paper §4.1: casting the model is one cast_tree call."""
        mlp = mpx.cast_to_float16(nn.MLP(4, 8, jax.random.PRNGKey(0)))
        assert mlp.fc_in.weight.dtype == jnp.float16
        out = mlp(jnp.ones(4, jnp.float16))
        assert out.dtype == jnp.float16
