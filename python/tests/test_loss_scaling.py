"""Paper §2.1/§3.3: dynamic loss scaling state machine."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx


class TestScaleUnscale:
    def test_scale_multiplies_float_leaves(self):
        s = mpx.DynamicLossScaling(1024.0)
        tree = {"a": jnp.ones(3, jnp.float16), "i": jnp.arange(3)}
        out = s.scale(tree)
        np.testing.assert_allclose(np.asarray(out["a"], np.float32), 1024.0)
        assert out["a"].dtype == jnp.float16  # scaling preserves dtype
        assert (out["i"] == tree["i"]).all()

    def test_unscale_divides_and_casts_f32(self):
        s = mpx.DynamicLossScaling(1024.0)
        tree = {"g": jnp.full((3,), 2048.0, jnp.float16)}
        out = s.unscale(tree)
        assert out["g"].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out["g"]), 2.0)

    def test_scale_unscale_roundtrip(self):
        s = mpx.DynamicLossScaling(2.0 ** 10)
        x = jnp.linspace(-2.0, 2.0, 17, dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(s.unscale(s.scale(x))), np.asarray(x), rtol=1e-6)

    def test_unscale_casts_before_divide(self):
        """An f16-inf gradient must stay inf after unscale (not become a
        finite garbage value) so the finite-check can catch it."""
        s = mpx.DynamicLossScaling(2.0)
        g = jnp.asarray([jnp.inf], jnp.float16)
        out = s.unscale(g)
        assert not bool(jnp.isfinite(out[0]))


class TestAdjust:
    def test_overflow_halves(self):
        s = mpx.DynamicLossScaling(1024.0, period=2000)
        s2 = s.adjust(jnp.asarray(False))
        assert float(s2.loss_scaling) == 512.0
        assert int(s2.counter) == 0

    def test_growth_after_period(self):
        s = mpx.DynamicLossScaling(1024.0, period=3)
        for _ in range(3):
            s = s.adjust(jnp.asarray(True))
        assert float(s.loss_scaling) == 2048.0
        assert int(s.counter) == 0

    def test_counter_increments(self):
        s = mpx.DynamicLossScaling(1024.0, period=100)
        s = s.adjust(jnp.asarray(True))
        assert int(s.counter) == 1
        assert float(s.loss_scaling) == 1024.0

    def test_min_clamp(self):
        s = mpx.DynamicLossScaling(1.0, period=10, min_loss_scaling=1.0)
        s = s.adjust(jnp.asarray(False))
        assert float(s.loss_scaling) == 1.0

    def test_max_clamp(self):
        s = mpx.DynamicLossScaling(2.0 ** 24, period=1,
                                   max_loss_scaling=2.0 ** 24)
        s = s.adjust(jnp.asarray(True))
        assert float(s.loss_scaling) == 2.0 ** 24

    def test_overflow_resets_counter(self):
        s = mpx.DynamicLossScaling(1024.0, period=5)
        s = s.adjust(jnp.asarray(True))
        s = s.adjust(jnp.asarray(True))
        assert int(s.counter) == 2
        s = s.adjust(jnp.asarray(False))
        assert int(s.counter) == 0

    def test_jit_compatible(self):
        """The scaling object is a PyTree → jits as carry state."""

        @jax.jit
        def roll(s, finite):
            return s.adjust(finite)

        s = mpx.DynamicLossScaling(4.0, period=2)
        s = roll(s, jnp.asarray(True))
        s = roll(s, jnp.asarray(True))
        assert float(s.loss_scaling) == 8.0

    def test_sequence_matches_reference_simulation(self):
        """Replay a mixed trace and compare to a hand-rolled simulator."""
        rng = np.random.RandomState(7)
        finites = rng.rand(500) > 0.05
        s = mpx.DynamicLossScaling(2.0 ** 15, period=20)
        scale, counter = 2.0 ** 15, 0
        for f in finites:
            s = s.adjust(jnp.asarray(bool(f)))
            if f:
                if counter >= 19:
                    scale = min(scale * 2.0, 2.0 ** 24)
                    counter = 0
                else:
                    counter += 1
            else:
                scale = max(scale / 2.0, 1.0)
                counter = 0
            assert float(s.loss_scaling) == scale, f
            assert int(s.counter) == counter


class TestVariants:
    def test_noop_identity(self):
        s = mpx.NoOpLossScaling()
        x = jnp.ones(3, jnp.float16)
        assert s.scale(x) is x
        assert s.adjust(jnp.asarray(False)) is s
        assert s.unscale(x).dtype == jnp.float32

    def test_static_constant(self):
        s = mpx.StaticLossScaling(64.0)
        assert float(s.scale(jnp.ones(()))) == 64.0
        s2 = s.adjust(jnp.asarray(False))
        assert float(s2.loss_scaling) == 64.0


class TestParityTrace:
    """Generate the shared trace fixture the Rust controller replays.

    ``rust/tests/scaling_parity.rs`` reads this JSON and asserts its
    state machine produces identical (scale, counter) sequences.
    """

    def test_write_trace(self, tmp_path):
        out_dir = os.environ.get("MPX_TRACE_DIR")
        rng = np.random.RandomState(1234)
        finites = [bool(b) for b in (rng.rand(300) > 0.07)]
        s = mpx.DynamicLossScaling(2.0 ** 15, period=16)
        states = []
        for f in finites:
            s = s.adjust(jnp.asarray(f))
            states.append(
                {"scale": float(s.loss_scaling), "counter": int(s.counter)})
        trace = {
            "init_scale": 2.0 ** 15, "period": 16, "factor": 2.0,
            "min_scale": 1.0, "max_scale": 2.0 ** 24,
            "finites": finites, "states": states,
        }
        path = (out_dir or str(tmp_path)) + "/scaling_trace.json"
        with open(path, "w") as f:
            json.dump(trace, f)
        assert os.path.exists(path)
