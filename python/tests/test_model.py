"""L2 model: the paper's evaluation ViT (shapes, precision, presets)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mpx
from compile.model import (
    PRESETS,
    ViTConfig,
    VisionTransformer,
    accuracy,
    cross_entropy_loss,
    make_config,
    param_count,
)


@pytest.fixture(scope="module")
def tiny_model():
    return VisionTransformer(make_config("vit_tiny"), jax.random.PRNGKey(0))


class TestConfig:
    def test_presets_match_paper(self):
        # §5: desktop "size 256 ... hidden layer of 800 neurons".
        assert PRESETS["vit_desktop"]["feature_dim"] == 256
        assert PRESETS["vit_desktop"]["mlp_dim"] == 800
        assert PRESETS["vit_desktop"]["num_classes"] == 100
        # cluster "mirrors ViT-Base dimensions": 768 / 3072.
        assert PRESETS["vit_base"]["feature_dim"] == 768
        assert PRESETS["vit_base"]["mlp_dim"] == 3072
        assert PRESETS["vit_base"]["num_classes"] == 1000

    def test_seq_len(self):
        assert make_config("vit_tiny").seq_len == 17
        assert make_config("vit_desktop").seq_len == 65
        assert make_config("vit_base").seq_len == 197

    def test_invalid_configs_rejected(self):
        with pytest.raises(ValueError):
            ViTConfig(image_size=33, patch_size=8, channels=3,
                      num_classes=10, feature_dim=64, mlp_dim=128,
                      num_heads=4, depth=2)
        with pytest.raises(ValueError):
            ViTConfig(image_size=32, patch_size=8, channels=3,
                      num_classes=10, feature_dim=65, mlp_dim=128,
                      num_heads=4, depth=2)
        with pytest.raises(ValueError):
            make_config("vit_tiny", kernels="cuda")
        with pytest.raises(KeyError):
            make_config("vit_huge")


class TestForward:
    def test_single_image_logits(self, tiny_model):
        img = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 32))
        logits = tiny_model(img)
        assert logits.shape == (10,)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_batched_via_vmap(self, tiny_model):
        imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32))
        logits = jax.vmap(tiny_model)(imgs)
        assert logits.shape == (4, 10)

    def test_patchify_preserves_pixels(self, tiny_model):
        img = jnp.arange(3 * 32 * 32, dtype=jnp.float32).reshape(3, 32, 32)
        patches = tiny_model._patchify(img)
        assert patches.shape == (16, 3 * 8 * 8)
        # first patch contains the image's top-left 8x8 of each channel
        np.testing.assert_array_equal(
            np.asarray(patches[0].reshape(3, 8, 8)),
            np.asarray(img[:, :8, :8]))

    def test_half_precision_forward(self, tiny_model):
        model16 = mpx.cast_to_float16(tiny_model)
        img = jnp.ones((3, 32, 32), jnp.float16)
        logits = model16(img)
        assert logits.dtype == jnp.float16
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_permutation_of_patches_changes_logits(self, tiny_model):
        """Position embeddings must make patch order matter."""
        img = jax.random.normal(jax.random.PRNGKey(2), (3, 32, 32))
        flipped = img[:, ::-1, :]
        a = tiny_model(img)
        b = tiny_model(flipped)
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_pallas_variant_matches_xla(self):
        key = jax.random.PRNGKey(3)
        xla_model = VisionTransformer(make_config("vit_tiny"), key)
        pal_model = VisionTransformer(
            make_config("vit_tiny", kernels="pallas"), key)
        img = jax.random.normal(jax.random.PRNGKey(4), (3, 32, 32))
        a = xla_model(img)
        b = pal_model(img)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)


class TestLoss:
    def test_cross_entropy_range(self, tiny_model):
        imgs = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 32, 32))
        labels = jnp.zeros((8,), jnp.int32)
        loss = cross_entropy_loss(tiny_model, (imgs, labels))
        assert loss.dtype == jnp.float32
        # fresh model ≈ uniform predictions → loss ≈ ln(10)
        assert 1.5 < float(loss) < 4.0

    def test_loss_in_half_precision_model(self, tiny_model):
        model16 = mpx.cast_to_float16(tiny_model)
        imgs = jnp.ones((4, 3, 32, 32), jnp.float16)
        labels = jnp.zeros((4,), jnp.int32)
        loss = cross_entropy_loss(model16, (imgs, labels))
        assert loss.dtype == jnp.float32  # forced full precision
        assert bool(jnp.isfinite(loss))

    def test_accuracy_bounds(self, tiny_model):
        imgs = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 32, 32))
        labels = jnp.zeros((8,), jnp.int32)
        acc = accuracy(tiny_model, (imgs, labels))
        assert 0.0 <= float(acc) <= 1.0


class TestParams:
    def test_param_count_vit_tiny(self, tiny_model):
        # cross-language regression: rust memmodel asserts this number
        assert param_count(tiny_model) == 81226

    def test_trainable_structure(self, tiny_model):
        diff, static = mpx.partition(tiny_model, mpx.is_inexact_array)
        n = sum(x.size for x in jax.tree_util.tree_leaves(diff))
        assert n == param_count(tiny_model)
