"""Mixed-precision gradient transformations (paper §3.4).

:func:`filter_grad` and :func:`filter_value_and_grad` are drop-in
replacements for the Equinox equivalents that additionally perform the
full mixed-precision recipe of paper §3.4 / Figure 1:

1. cast all input arguments (model and data) to half precision;
2. run the original function (forward pass + loss);
3. scale the loss by the dynamic scaling factor;
4. differentiate the scaled loss w.r.t. the model's inexact leaves;
5. unscale: cast gradients to float32, divide by the factor;
6. check gradient finiteness;
7. adjust the scaling state;
8. return ``(new_scaling, grads_finite, grads[, aux])``.

Full-precision master weights stay with the caller: the gradients come
back float32 with the same tree structure as the model, ready for
:func:`mpx.optimizer_update`.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax

from mpx.casting import cast_to_half_precision
from mpx.loss_scaling import LossScaling
from mpx.tree_util import all_finite, combine, is_inexact_array, partition


def filter_value_and_grad(
    func: Callable,
    scaling: LossScaling,
    has_aux: bool = False,
    use_mixed_precision: bool = True,
) -> Callable:
    """Mixed-precision ``value_and_grad`` over arbitrary PyTrees.

    ``func(model, *args, **kwargs)`` must return a scalar loss (or
    ``(loss, aux)`` when ``has_aux``).  The returned callable yields

    ``(loss, new_scaling, grads_finite, grads)`` — or
    ``((loss, aux), new_scaling, grads_finite, grads)`` with aux.

    The loss is returned *unscaled* in float32.  With
    ``use_mixed_precision=False`` the wrapper degenerates to a plain
    filtered value_and_grad (the scaling still runs, so pipelines can
    switch precision with a single flag — this is the fp32 baseline in
    the paper's evaluation).
    """

    @functools.wraps(func)
    def wrapper(model: Any, *args, **kwargs):
        if use_mixed_precision:
            # Step 1: inputs → half.  Integer leaves (PRNG keys, label
            # arrays) pass through untouched.
            model_in = cast_to_half_precision(model)
            args_in = cast_to_half_precision(args)
            kwargs_in = cast_to_half_precision(kwargs)
        else:
            model_in, args_in, kwargs_in = model, args, kwargs

        diff, static = partition(model_in, is_inexact_array)

        def scaled_loss_fn(diff_part, *a, **kw):
            m = combine(diff_part, static)
            out = func(m, *a, **kw)
            if has_aux:
                loss, aux = out
            else:
                loss, aux = out, None
            # Steps 2–3: loss computed in working precision, then
            # scaled so the backward pass stays above float16's
            # underflow threshold.
            return scaling.scale(loss), aux

        (scaled_loss, aux), scaled_grads = jax.value_and_grad(
            scaled_loss_fn, has_aux=True
        )(diff, *args_in, **kwargs_in)

        # Steps 4–5: float32 first, then divide — the division cannot
        # overflow once in full precision.
        grads = scaling.unscale(scaled_grads)
        loss = scaling.unscale(scaled_loss)

        # Steps 6–7: finiteness gate + scaling adaptation.
        grads_finite = all_finite(grads)
        new_scaling = scaling.adjust(grads_finite)

        value = (loss, aux) if has_aux else loss
        return value, new_scaling, grads_finite, grads

    return wrapper


def filter_grad(
    func: Callable,
    scaling: LossScaling,
    has_aux: bool = False,
    use_mixed_precision: bool = True,
) -> Callable:
    """Gradient-only variant (paper Example 2b)::

        loss_scaling, grads_finite, grads = \\
            mpx.filter_grad(loss, loss_scaling)(model, batch)
    """

    vag = filter_value_and_grad(
        func, scaling, has_aux=has_aux, use_mixed_precision=use_mixed_precision
    )

    @functools.wraps(func)
    def wrapper(model: Any, *args, **kwargs):
        value, new_scaling, grads_finite, grads = vag(model, *args, **kwargs)
        if has_aux:
            _, aux = value
            return new_scaling, grads_finite, grads, aux
        return new_scaling, grads_finite, grads

    return wrapper
