"""Optimizer wrapper with non-finite-gradient skipping (paper §3.5).

Loss scaling deliberately lets gradients overflow once in a while (that
is how the dynamic heuristic probes the representable range), so the
optimizer step must be *conditional*: apply only when every gradient is
finite, otherwise keep model and optimizer state bit-identical.
:func:`optimizer_update` packages that logic so a training pipeline
replaces::

    updates, opt_state = optimizer.update(grads, opt_state, params)
    model = apply_updates(model, updates)

with the single call (paper Example 2b)::

    model, opt_state = mpx.optimizer_update(
        model, optimizer, opt_state, grads, grads_finite)
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from mpx.nn import apply_updates
from mpx.optim import GradientTransformation
from mpx.tree_util import filter_arrays, is_array, is_inexact_array


def tree_select(pred: jax.Array, on_true: Any, on_false: Any) -> Any:
    """Leaf-wise ``jnp.where(pred, a, b)`` over two same-structure trees.

    Non-array leaves must be equal in both trees and pass through; this
    keeps the select jit-compatible without a ``lax.cond`` (both sides
    are already computed — the optimizer math is cheap relative to the
    backward pass, and XLA fuses the selects).
    """

    def _sel(a, b):
        if is_array(a) or is_array(b):
            return jnp.where(pred, a, b)
        return a

    return jax.tree_util.tree_map(_sel, on_true, on_false)


def optimizer_update(
    model: Any,
    optimizer: GradientTransformation,
    optimizer_state: Any,
    grads: Any,
    grads_finite: jax.Array,
) -> Tuple[Any, Any]:
    """Apply one optimizer step iff ``grads_finite``.

    Returns ``(new_model, new_optimizer_state)``.  When gradients are
    non-finite the model *and* the optimizer state are returned
    unchanged (paper §2.1 step 6a: "reduce the scaling and skip
    updating model parameters") — Adam moments must not absorb inf/nan.

    Gradients may contain ``None`` holes (from the filtered partition);
    only the corresponding float leaves of ``model`` are updated.
    """
    params = filter_arrays(model, is_inexact_array)
    updates, new_opt_state = optimizer.update(
        grads, optimizer_state, params
    )
    new_model = apply_updates(model, updates)

    model_out = tree_select(grads_finite, new_model, model)
    opt_out = tree_select(grads_finite, new_opt_state, optimizer_state)
    return model_out, opt_out
