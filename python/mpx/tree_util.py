"""PyTree predicates and filtered partition/combine.

These are the minimal Equinox-style primitives MPX relies on (paper
§3.4 states that ``mpx.filter_grad`` acts as a drop-in replacement for
``eqx.filter_grad``): a model is *any* PyTree; transforms differentiate
or cast only the leaves a predicate selects, leaving the rest intact.

Filtered-out leaves are replaced by ``None`` — an *empty subtree* for
JAX — exactly as Equinox does, so ``jax.grad`` over a partition only
ever sees the selected (inexact array) leaves.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def is_array(x: Any) -> bool:
    """True for JAX and NumPy arrays (the leaves a model "owns")."""
    return isinstance(x, (jax.Array, np.ndarray))


def is_inexact_array(x: Any) -> bool:
    """True for floating-point (or complex) JAX/NumPy arrays.

    This is the differentiability predicate: integer arrays (e.g. PRNG
    keys, step counters) must never be cast (paper §3.1) nor
    differentiated.
    """
    return is_array(x) and jnp.issubdtype(x.dtype, jnp.inexact)


def is_floating_array(x: Any) -> bool:
    """True for real floating-point JAX/NumPy arrays."""
    return is_array(x) and jnp.issubdtype(x.dtype, jnp.floating)


def _is_none(x: Any) -> bool:
    return x is None


def partition(tree: Any, predicate: Callable[[Any], bool] = is_inexact_array):
    """Split ``tree`` into ``(selected, rest)``.

    Leaves failing ``predicate`` become ``None`` in ``selected`` and
    vice versa; :func:`combine` is the exact inverse.  ``None`` values
    already present in ``tree`` are empty subtrees and land in neither
    partition (they are restored structurally by :func:`combine`).
    """
    selected = jax.tree_util.tree_map(
        lambda x: x if predicate(x) else None, tree
    )
    rest = jax.tree_util.tree_map(
        lambda x: None if predicate(x) else x, tree
    )
    return selected, rest


def combine(*trees: Any) -> Any:
    """Merge partitions: the first non-``None`` leaf wins."""

    def _merge(*leaves):
        for leaf in leaves:
            if leaf is not None:
                return leaf
        return None

    return jax.tree_util.tree_map(_merge, *trees, is_leaf=_is_none)


def filter_arrays(tree: Any, predicate: Callable[[Any], bool] = is_array):
    """Keep only leaves passing ``predicate`` (others → ``None``)."""
    return jax.tree_util.tree_map(
        lambda x: x if predicate(x) else None, tree
    )


def tree_cast(tree: Any, dtype: Any, predicate=is_floating_array) -> Any:
    """Cast every leaf passing ``predicate`` to ``dtype``; others intact."""
    dtype = jnp.dtype(dtype)

    def _cast(x):
        if predicate(x):
            return jnp.asarray(x).astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def all_finite(tree: Any) -> jax.Array:
    """Scalar bool: every element of every inexact leaf is finite.

    This is step 6 of the paper's §2.1 recipe — the signal that decides
    whether the optimizer update is applied and how the loss scaling
    adjusts.  An empty tree is vacuously finite.
    """
    leaves = [x for x in jax.tree_util.tree_leaves(tree) if is_inexact_array(x)]
    if not leaves:
        return jnp.asarray(True)
    finite = [jnp.all(jnp.isfinite(x)) for x in leaves]
    out = finite[0]
    for f in finite[1:]:
        out = jnp.logical_and(out, f)
    return out


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (host-side bookkeeping helper)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if is_array(leaf):
            total += leaf.size * leaf.dtype.itemsize
    return total
