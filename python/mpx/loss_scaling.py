"""Dynamic loss scaling (paper §2.1 / §3.3).

Float16 resolves ~5.96e-8 at best; gradients below that underflow to
zero.  Loss scaling multiplies the loss by ``S`` before the backward
pass, shifting the whole gradient distribution up into representable
range, and divides by ``S`` afterwards.  *Dynamic* loss scaling adapts
``S`` at runtime with the classic heuristic of Micikevicius et al.
(2017): halve on overflow, double after ``period`` consecutive finite
steps.

The scaling objects are :class:`mpx.nn.Module` subclasses and hence
PyTrees: they can be passed through ``jax.jit``, carried in the train
state the Rust coordinator owns, and sharded (replicated) for
multi-device training.  The Rust data-parallel mode re-implements the
same state machine (``rust/src/scaling/``); the two are parity-tested
against shared traces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from mpx.casting import cast_to_float32
from mpx.nn import Module
from mpx.tree_util import is_floating_array

#: Defaults follow Micikevicius et al. (2017) and NVIDIA AMP.
DEFAULT_INITIAL_SCALE = 2.0 ** 15
DEFAULT_PERIOD = 2000
DEFAULT_FACTOR = 2.0
DEFAULT_MIN_SCALE = 1.0
DEFAULT_MAX_SCALE = 2.0 ** 24


class LossScaling(Module):
    """Interface: ``scale``, ``unscale``, ``adjust``."""

    def scale(self, tree):
        raise NotImplementedError

    def unscale(self, tree):
        raise NotImplementedError

    def adjust(self, grads_finite: jax.Array) -> "LossScaling":
        raise NotImplementedError


class NoOpLossScaling(LossScaling):
    """Identity scaling — used by full-precision and bfloat16 pipelines.

    bfloat16 shares float32's exponent range, so gradients rarely
    under/overflow and scaling is unnecessary; this object keeps the
    train-step code shape identical across precisions.
    """

    def scale(self, tree):
        return tree

    def unscale(self, tree):
        return cast_to_float32(tree)

    def adjust(self, grads_finite):
        del grads_finite
        return self


class StaticLossScaling(LossScaling):
    """Constant scale factor (paper §2.1 discusses why this is fragile)."""

    loss_scaling: jax.Array

    def __init__(self, loss_scaling: float):
        self.loss_scaling = jnp.asarray(loss_scaling, jnp.float32)

    def scale(self, tree):
        return _tree_scale(tree, self.loss_scaling)

    def unscale(self, tree):
        inv = 1.0 / self.loss_scaling
        return _tree_scale(cast_to_float32(tree), inv)

    def adjust(self, grads_finite):
        del grads_finite
        return self


class DynamicLossScaling(LossScaling):
    """Adaptive loss scaling (paper §3.3, extends ``jmp``'s version).

    State (dynamic leaves, so the object jits and shards):

    * ``loss_scaling`` — current scale ``S`` (float32 scalar).
    * ``counter`` — consecutive finite steps since the last change
      (int32 scalar).

    Hyper-parameters (static aux data): ``period``, ``factor``,
    ``min_loss_scaling``, ``max_loss_scaling``.

    ``adjust(grads_finite)`` implements:

    * overflow: ``S ← max(S / factor, min)``, counter reset — and the
      caller must skip the optimizer step (:func:`mpx.optimizer_update`
      does);
    * ``period`` consecutive finite steps: ``S ← min(S · factor, max)``,
      counter reset;
    * otherwise: counter += 1.
    """

    loss_scaling: jax.Array
    counter: jax.Array

    def __init__(
        self,
        loss_scaling: float = DEFAULT_INITIAL_SCALE,
        *,
        counter: int = 0,
        period: int = DEFAULT_PERIOD,
        factor: float = DEFAULT_FACTOR,
        min_loss_scaling: float = DEFAULT_MIN_SCALE,
        max_loss_scaling: float = DEFAULT_MAX_SCALE,
    ):
        self.loss_scaling = jnp.asarray(loss_scaling, jnp.float32)
        self.counter = jnp.asarray(counter, jnp.int32)
        self.period = int(period)
        # floats are static by Module's type rules — hyper-parameters.
        self.factor = float(factor)
        self.min_loss_scaling = float(min_loss_scaling)
        self.max_loss_scaling = float(max_loss_scaling)

    # -- paper §3.3 API ----------------------------------------------------

    def scale(self, tree):
        """Multiply every float leaf by ``S`` (used on the loss)."""
        return _tree_scale(tree, self.loss_scaling.astype(jnp.float32))

    def unscale(self, tree):
        """Divide every float leaf by ``S`` *and* cast to float32.

        Order matters: cast first, then divide, so the division cannot
        overflow in half precision (paper §2.1 steps 4–5).
        """
        inv = (1.0 / self.loss_scaling).astype(jnp.float32)
        return _tree_scale(cast_to_float32(tree), inv)

    def adjust(self, grads_finite: jax.Array) -> "DynamicLossScaling":
        """Next scaling state given this step's gradient finiteness."""
        grads_finite = jnp.asarray(grads_finite)
        factor = jnp.float32(self.factor)

        grew = self.counter >= (self.period - 1)
        scale_if_finite = jnp.where(
            grew,
            jnp.minimum(
                self.loss_scaling * factor,
                jnp.float32(self.max_loss_scaling),
            ),
            self.loss_scaling,
        )
        counter_if_finite = jnp.where(
            grew, jnp.int32(0), self.counter + jnp.int32(1)
        )

        scale_if_inf = jnp.maximum(
            self.loss_scaling / factor, jnp.float32(self.min_loss_scaling)
        )

        new_scale = jnp.where(grads_finite, scale_if_finite, scale_if_inf)
        new_counter = jnp.where(grads_finite, counter_if_finite, jnp.int32(0))
        return DynamicLossScaling(
            new_scale,
            counter=new_counter,
            period=self.period,
            factor=self.factor,
            min_loss_scaling=self.min_loss_scaling,
            max_loss_scaling=self.max_loss_scaling,
        )


def _tree_scale(tree, factor):
    """Multiply float leaves by a scalar, preserving each leaf's dtype.

    The multiply happens in the leaf's own dtype (the scalar is cast
    down), matching the paper's "scale the half-precision loss" step.
    """

    def _mul(x):
        if is_floating_array(x):
            return x * factor.astype(x.dtype)
        return x

    return jax.tree_util.tree_map(_mul, tree)
