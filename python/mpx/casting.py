"""PyTree and function casting (paper §3.1 / §3.2).

MPX's design leverages JAX's type-promotion lattice: once the *inputs*
of a function have been cast to a given precision, every operation
inside executes in that precision, provided constants sit on the weak
side of the lattice.  Casting is therefore applied at function
boundaries only:

* :func:`cast_tree` and friends cast the floating-point leaves of an
  arbitrary PyTree (integer leaves — PRNG keys, counters — are never
  touched).
* :func:`cast_function` wraps a function so its inputs (and optionally
  outputs) are cast.
* :func:`force_full_precision` is the inverse safety hatch: it runs an
  overflow-prone sub-computation (softmax, sum, mean, layernorm
  statistics) in float32 regardless of the surrounding precision, then
  casts the result back.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax.numpy as jnp

from mpx.tree_util import is_floating_array, tree_cast

_HALF_DTYPE = jnp.float16


class HalfPrecisionPolicy:
    """Process-wide choice of the half-precision format.

    The paper supports both IEEE float16 (needs loss scaling, larger
    mantissa) and bfloat16 (same exponent range as float32, usually no
    scaling needed).  The policy only affects
    :func:`cast_to_half_precision`; the explicit casts are unaffected.
    """

    def __init__(self, dtype: Any = jnp.float16):
        dtype = jnp.dtype(dtype)
        if dtype not in (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16)):
            raise ValueError(
                f"half-precision policy must be float16 or bfloat16, got {dtype}"
            )
        self.dtype = dtype

    def __repr__(self) -> str:
        return f"HalfPrecisionPolicy({self.dtype.name})"


def set_half_dtype(dtype: Any) -> None:
    """Set the dtype used by :func:`cast_to_half_precision` globally."""
    global _HALF_DTYPE
    _HALF_DTYPE = HalfPrecisionPolicy(dtype).dtype


def get_half_dtype():
    """The dtype :func:`cast_to_half_precision` currently targets."""
    return _HALF_DTYPE


def cast_tree(tree: Any, dtype: Any) -> Any:
    """Cast every floating-point array leaf of ``tree`` to ``dtype``.

    Non-float leaves (integer arrays — crucially PRNG keys — bools,
    Python scalars, ``None``) pass through unchanged (paper §3.1).
    """
    return tree_cast(tree, dtype, predicate=is_floating_array)


def cast_to_half_precision(tree: Any) -> Any:
    """Cast float leaves to the current half-precision policy dtype."""
    return cast_tree(tree, _HALF_DTYPE)


def cast_to_float16(tree: Any) -> Any:
    """Cast float leaves to IEEE binary16."""
    return cast_tree(tree, jnp.float16)


def cast_to_bfloat16(tree: Any) -> Any:
    """Cast float leaves to bfloat16."""
    return cast_tree(tree, jnp.bfloat16)


def cast_to_float32(tree: Any) -> Any:
    """Cast float leaves to float32 (full precision)."""
    return cast_tree(tree, jnp.float32)


def cast_function(
    func: Callable,
    dtype: Any,
    return_dtype: Optional[Any] = None,
) -> Callable:
    """Return ``func`` with inputs cast to ``dtype`` (outputs optional).

    Paper §3.2.  The returned function first applies
    :func:`cast_tree` to ``(args, kwargs)``, calls ``func``, and — when
    ``return_dtype`` is given — casts the outputs as well.
    """

    @functools.wraps(func)
    def wrapper(*args, **kwargs):
        args = cast_tree(args, dtype)
        kwargs = cast_tree(kwargs, dtype)
        out = func(*args, **kwargs)
        if return_dtype is not None:
            out = cast_tree(out, return_dtype)
        return out

    return wrapper


def force_full_precision(
    func: Callable,
    return_dtype: Optional[Any] = None,
) -> Callable:
    """Run ``func`` in float32 regardless of the surrounding precision.

    Paper §3.2: essential for reductions prone to overflow in float16
    (sum, mean, softmax, layer-norm statistics).  ``return_dtype``
    usually receives the dtype of the *surrounding* computation so that
    the full-precision island does not leak float32 into the
    half-precision graph::

        attn = mpx.force_full_precision(jax.nn.softmax, scores.dtype)(scores)
    """
    return cast_function(func, jnp.float32, return_dtype=return_dtype)
