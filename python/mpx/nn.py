"""Mini-Equinox: neural networks as callable PyTrees.

The paper integrates with Equinox/Flax, neither of which is installed
in this environment, so this module provides the substrate from
scratch: a :class:`Module` base class whose instances are registered
PyTrees (array-valued attributes become children; hyper-parameters
become static aux data), plus the handful of layers the evaluation
model (a Vision Transformer, paper §5) needs.

Design contract (all that MPX itself relies on, paper §3.4):

* a model is a PyTree whose differentiable state is its inexact array
  leaves;
* ``apply_updates(model, updates)`` adds an update tree (same
  structure, possibly with FILTERED holes) onto the model;
* modules are callable: ``model(x)`` runs the forward pass.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from mpx.tree_util import combine, is_array

# ---------------------------------------------------------------------------
# Module base
# ---------------------------------------------------------------------------

#: Values of these types are always static (hashable aux data): they
#: parameterize the computation's *structure*, never its data flow.
_STATIC_TYPES = (int, bool, str, bytes, jnp.dtype, np.dtype, type)


def static_field(value: Any) -> Any:
    """Identity marker used for documentation; static-ness is by type."""
    return value


def _is_static_value(v: Any) -> bool:
    if v is None:
        # None is an *empty subtree* for JAX — keep it dynamic so that
        # filtered partitions (which replace array leaves by None) do
        # not change the module's static structure.
        return False
    if isinstance(v, (jax.Array, np.ndarray)):
        return False
    if isinstance(v, Module):
        return False
    if isinstance(v, float):
        # Python floats are hyper-parameters (eps, dropout rate) — keep
        # them out of the differentiable tree AND out of traced leaves.
        return True
    if isinstance(v, _STATIC_TYPES):
        return True
    if callable(v) and not isinstance(v, Module):
        return True
    if isinstance(v, (list, tuple, dict)):
        return False  # containers recurse as pytrees
    return False


class Module:
    """Base class making subclasses PyTrees with type-based filtering.

    Attributes holding arrays, sub-modules or containers become PyTree
    children; ints/bools/strings/floats/callables become static aux
    data (so ``num_heads`` survives ``jax.jit`` as a Python int).  The
    attribute *order* in aux data is sorted, making flattening
    deterministic — the Rust manifest relies on this.
    """

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        jax.tree_util.register_pytree_with_keys(
            cls,
            flatten_with_keys=_flatten_module_with_keys,
            flatten_func=_flatten_module,
            unflatten_func=lambda aux, children: _unflatten_module(
                cls, aux, children
            ),
        )

    # Subclasses assign attributes freely inside __init__; flattening is
    # over __dict__, so no dataclass machinery is needed.

    def __repr__(self) -> str:
        fields = ", ".join(
            f"{k}={_short(v)}" for k, v in sorted(self.__dict__.items())
        )
        return f"{type(self).__name__}({fields})"


def _short(v: Any) -> str:
    if isinstance(v, (jax.Array, np.ndarray)):
        return f"{v.dtype}{list(v.shape)}"
    return repr(v)


def _split_dict(mod: Module):
    dyn_keys, dyn_vals, static = [], [], []
    for k in sorted(mod.__dict__):
        v = mod.__dict__[k]
        if _is_static_value(v):
            static.append((k, v))
        else:
            dyn_keys.append(k)
            dyn_vals.append(v)
    return dyn_keys, dyn_vals, tuple(static)


def _flatten_module(mod: Module):
    dyn_keys, dyn_vals, static = _split_dict(mod)
    return dyn_vals, (tuple(dyn_keys), static)


def _flatten_module_with_keys(mod: Module):
    dyn_keys, dyn_vals, static = _split_dict(mod)
    keyed = [
        (jax.tree_util.GetAttrKey(k), v) for k, v in zip(dyn_keys, dyn_vals)
    ]
    return keyed, (tuple(dyn_keys), static)


def _unflatten_module(cls, aux, children):
    dyn_keys, static = aux
    mod = object.__new__(cls)
    for k, v in zip(dyn_keys, children):
        object.__setattr__(mod, k, v)
    for k, v in static:
        object.__setattr__(mod, k, v)
    return mod


def apply_updates(model: Any, updates: Any) -> Any:
    """``model + updates`` leaf-wise; ``None`` updates are skipped.

    Mirrors ``eqx.apply_updates``: the updates tree comes from an
    optimizer and only covers the differentiable leaves.
    """

    def _apply(u, p):
        if u is None:
            return p
        return p + u

    return jax.tree_util.tree_map(
        _apply, updates, model, is_leaf=lambda x: x is None
    )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _lecun_normal(key, shape, in_dim, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(1.0 / in_dim)


def _glorot_uniform(key, shape, fan_in, fan_out, dtype=jnp.float32):
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def _trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


class Linear(Module):
    """Dense layer ``y = W x + b`` over the last axis.

    Stored full-precision; mixed-precision execution happens because
    MPX casts the *model* (all float leaves) to half before the forward
    pass — JAX type promotion then keeps every matmul in half.
    """

    weight: jax.Array
    bias: Optional[jax.Array]

    def __init__(self, in_features: int, out_features: int, key,
                 use_bias: bool = True, dtype=jnp.float32):
        wkey, _ = jax.random.split(key)
        self.weight = _glorot_uniform(
            wkey, (out_features, in_features), in_features, out_features, dtype
        )
        self.bias = jnp.zeros((out_features,), dtype) if use_bias else None
        self.in_features = in_features
        self.out_features = out_features

    def __call__(self, x: jax.Array) -> jax.Array:
        y = x @ self.weight.T
        if self.bias is not None:
            y = y + self.bias
        return y


class LayerNorm(Module):
    """Layer normalization over the last axis.

    The statistics (mean/variance) are overflow-prone in float16; the
    ViT model therefore wraps calls in ``mpx.force_full_precision``
    (paper §4.1, Example 1) — the layer itself is precision-agnostic.
    """

    weight: jax.Array
    bias: jax.Array

    def __init__(self, dim: int, eps: float = 1e-5, dtype=jnp.float32):
        self.weight = jnp.ones((dim,), dtype)
        self.bias = jnp.zeros((dim,), dtype)
        self.eps = eps
        self.dim = dim

    def __call__(self, x: jax.Array) -> jax.Array:
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + jnp.asarray(self.eps, x.dtype))
        return (x - mean) * inv * self.weight + self.bias


class Embedding(Module):
    """Token/position embedding table."""

    weight: jax.Array

    def __init__(self, num_embeddings: int, dim: int, key, dtype=jnp.float32):
        self.weight = _trunc_normal(key, (num_embeddings, dim), 0.02, dtype)
        self.num_embeddings = num_embeddings
        self.dim = dim

    def __call__(self, idx: jax.Array) -> jax.Array:
        return self.weight[idx]


class Dropout(Module):
    """Dropout; a no-op unless a key is supplied (training mode)."""

    def __init__(self, rate: float = 0.0):
        self.rate = rate

    def __call__(self, x: jax.Array, *, key=None) -> jax.Array:
        if key is None or self.rate == 0.0:
            return x
        keep = 1.0 - self.rate
        mask = jax.random.bernoulli(key, keep, x.shape)
        return jnp.where(mask, x / jnp.asarray(keep, x.dtype),
                         jnp.zeros((), x.dtype))


class Sequential(Module):
    """Apply sub-modules in order."""

    layers: Tuple

    def __init__(self, layers: Sequence[Callable]):
        self.layers = tuple(layers)

    def __call__(self, x, **kwargs):
        for layer in self.layers:
            x = layer(x)
        return x


class MLP(Module):
    """Two-layer perceptron with GELU, the ViT residual-block body."""

    fc_in: Linear
    fc_out: Linear

    def __init__(self, dim: int, hidden: int, key, dtype=jnp.float32):
        k1, k2 = jax.random.split(key)
        self.fc_in = Linear(dim, hidden, k1, dtype=dtype)
        self.fc_out = Linear(hidden, dim, k2, dtype=dtype)
        self.dim = dim
        self.hidden = hidden

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fc_out(jax.nn.gelu(self.fc_in(x)))
