"""MPX — Mixed Precision Training for JAX (reproduction).

This package reproduces the library contribution of

    Gräfe & Trimpe, "MPX: Mixed Precision Training for JAX", 2025.

It provides, from scratch (neither Equinox, Optax nor JMP are available
in this environment — see DESIGN.md for the substitution table):

* PyTree casting utilities (paper §3.1): :func:`cast_tree`,
  :func:`cast_to_half_precision`, :func:`cast_to_float16`,
  :func:`cast_to_bfloat16`, :func:`cast_to_float32`.
* Function casting (paper §3.2): :func:`cast_function`,
  :func:`force_full_precision`.
* Dynamic loss scaling (paper §3.3): :class:`DynamicLossScaling`,
  :class:`StaticLossScaling`, :class:`NoOpLossScaling`.
* Mixed-precision gradient transforms (paper §3.4):
  :func:`filter_grad`, :func:`filter_value_and_grad`.
* The optimizer wrapper (paper §3.5): :func:`optimizer_update`.
* The substrates the paper builds on: a mini-Equinox module system
  (:mod:`mpx.nn` — callable PyTrees + filtered transforms) and a
  mini-Optax (:mod:`mpx.optim` — sgd/adam/adamw/clip/chain).

The whole package is build-time only in this repository: models and
train steps written against it are AOT-lowered to HLO text by
``python/compile/aot.py`` and executed from the Rust coordinator.
"""

from mpx.casting import (
    HalfPrecisionPolicy,
    cast_function,
    cast_to_bfloat16,
    cast_to_float16,
    cast_to_float32,
    cast_to_half_precision,
    cast_tree,
    force_full_precision,
    get_half_dtype,
    set_half_dtype,
)
from mpx.grad import filter_grad, filter_value_and_grad
from mpx.loss_scaling import (
    DynamicLossScaling,
    LossScaling,
    NoOpLossScaling,
    StaticLossScaling,
)
from mpx.train import optimizer_update, tree_select
from mpx.tree_util import (
    all_finite,
    combine,
    filter_arrays,
    is_array,
    is_inexact_array,
    partition,
    tree_cast,
)

__version__ = "0.1.0"

__all__ = [
    "HalfPrecisionPolicy",
    "cast_function",
    "cast_to_bfloat16",
    "cast_to_float16",
    "cast_to_float32",
    "cast_to_half_precision",
    "cast_tree",
    "force_full_precision",
    "get_half_dtype",
    "set_half_dtype",
    "filter_grad",
    "filter_value_and_grad",
    "DynamicLossScaling",
    "LossScaling",
    "NoOpLossScaling",
    "StaticLossScaling",
    "optimizer_update",
    "tree_select",
    "all_finite",
    "combine",
    "filter_arrays",
    "is_array",
    "is_inexact_array",
    "partition",
    "tree_cast",
]
