"""Mini-Optax: gradient-transformation optimizers.

Optax is not installed in this environment, so this module provides the
substrate from scratch with the identical interface MPX's
:func:`mpx.optimizer_update` (paper §3.5) relies on::

    optimizer = adamw(3e-4, weight_decay=1e-4)
    state     = optimizer.init(filter_arrays(model))
    updates, state = optimizer.update(grads, state, params)

States are plain PyTrees (dicts/tuples), so they flow through
``jax.jit``, the AOT manifest and the Rust coordinator unchanged.  All
optimizer arithmetic is float32: gradients arrive unscaled float32 from
:func:`mpx.filter_grad` and the master parameters stay float32 — the
standard mixed-precision master-weights recipe.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from mpx.tree_util import is_inexact_array


class GradientTransformation(NamedTuple):
    """The (init, update) pair — Optax's core abstraction."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Optional[Any]], Any]


def _tree_map_grads(fn, *trees):
    """tree_map over gradient trees, passing ``None`` holes through."""

    def _fn(*leaves):
        if leaves[0] is None:
            return None
        return fn(*leaves)

    return jax.tree_util.tree_map(
        _fn, *trees, is_leaf=lambda x: x is None
    )


def _zeros_like_grads(tree):
    return _tree_map_grads(
        lambda g: jnp.zeros_like(g, dtype=jnp.float32)
        if is_inexact_array(g)
        else None,
        tree,
    )


# ---------------------------------------------------------------------------
# Basic transforms
# ---------------------------------------------------------------------------


def sgd(learning_rate: float, momentum: float = 0.0) -> GradientTransformation:
    """Stochastic gradient descent, optionally with heavy-ball momentum."""

    def init(params):
        if momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {
            "count": jnp.zeros((), jnp.int32),
            "velocity": _zeros_like_grads(params),
        }

    def update(grads, state, params=None):
        del params
        lr = jnp.float32(learning_rate)
        if momentum == 0.0:
            updates = _tree_map_grads(lambda g: -lr * g.astype(jnp.float32),
                                      grads)
            return updates, {"count": state["count"] + 1}
        mu = jnp.float32(momentum)
        velocity = _tree_map_grads(
            lambda g, v: mu * v + g.astype(jnp.float32),
            grads, state["velocity"],
        )
        updates = _tree_map_grads(lambda v: -lr * v, velocity)
        return updates, {"count": state["count"] + 1, "velocity": velocity}

    return GradientTransformation(init, update)


def adam(
    learning_rate: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> GradientTransformation:
    """Adam (Kingma & Ba) with bias correction; float32 moments."""
    return _adam_impl(learning_rate, b1, b2, eps, weight_decay=0.0)


def adamw(
    learning_rate: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 1e-4,
) -> GradientTransformation:
    """AdamW: Adam with decoupled weight decay (needs ``params`` arg)."""
    return _adam_impl(learning_rate, b1, b2, eps, weight_decay=weight_decay)


def _adam_impl(learning_rate, b1, b2, eps, weight_decay):
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": _zeros_like_grads(params),
            "nu": _zeros_like_grads(params),
        }

    def update(grads, state, params=None):
        if weight_decay != 0.0 and params is None:
            raise ValueError("adamw.update requires params for weight decay")
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        b1_, b2_ = jnp.float32(b1), jnp.float32(b2)
        lr = jnp.float32(learning_rate)
        bc1 = 1.0 - jnp.power(b1_, cf)
        bc2 = 1.0 - jnp.power(b2_, cf)

        mu = _tree_map_grads(
            lambda g, m: b1_ * m + (1.0 - b1_) * g.astype(jnp.float32),
            grads, state["mu"],
        )
        nu = _tree_map_grads(
            lambda g, v: b2_ * v
            + (1.0 - b2_) * jnp.square(g.astype(jnp.float32)),
            grads, state["nu"],
        )

        def _upd(m, v, *maybe_p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + jnp.float32(eps))
            if weight_decay != 0.0:
                (p,) = maybe_p
                step = step + jnp.float32(weight_decay) * p.astype(jnp.float32)
            return -lr * step

        if weight_decay != 0.0:
            updates = _tree_map_grads(_upd, mu, nu, params)
        else:
            updates = _tree_map_grads(_upd, mu, nu)
        return updates, {"count": count, "mu": mu, "nu": nu}

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Combinators
# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    """Scale the whole gradient tree so its global L2 norm ≤ max_norm."""

    def init(params):
        del params
        return {}

    def update(grads, state, params=None):
        del params
        sq = [
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)
            if is_inexact_array(g)
        ]
        norm = jnp.sqrt(sum(sq)) if sq else jnp.float32(0.0)
        scale = jnp.minimum(1.0, jnp.float32(max_norm) / (norm + 1e-12))
        return _tree_map_grads(
            lambda g: g.astype(jnp.float32) * scale, grads
        ), state

    return GradientTransformation(init, update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transforms left-to-right (Optax semantics)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int,
    final_scale: float = 0.0,
) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup to ``peak_lr`` then cosine decay (ViT recipe)."""

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = jnp.float32(max(warmup_steps, 1))
        total = jnp.float32(max(total_steps, 1))
        warm_lr = peak_lr * step / warm
        progress = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0),
                            0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
        decay_lr = peak_lr * (final_scale + (1.0 - final_scale) * cos)
        return jnp.where(step < warm, warm_lr, decay_lr)

    return schedule


def scale_by_schedule(
    inner: GradientTransformation,
    schedule: Callable[[jax.Array], jax.Array],
    base_lr: float,
) -> GradientTransformation:
    """Rescale ``inner``'s updates by ``schedule(step)/base_lr``."""

    def init(params):
        return {"count": jnp.zeros((), jnp.int32), "inner": inner.init(params)}

    def update(grads, state, params=None):
        updates, inner_state = inner.update(grads, state["inner"], params)
        count = state["count"] + 1
        factor = schedule(count) / jnp.float32(base_lr)
        updates = _tree_map_grads(lambda u: u * factor, updates)
        return updates, {"count": count, "inner": inner_state}

    return GradientTransformation(init, update)
