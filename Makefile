# MPX build entry points.  `make artifacts` is the one Python touch
# in the pipeline (python/compile/aot.py → artifacts/*.hlo.txt +
# *.manifest.json); everything else is cargo.

PYTHON ?= python3
OUT ?= artifacts

.PHONY: artifacts artifacts-tiny artifacts-desktop test build

# Full artifact set: every (model, precision, batch) variant the
# benches and examples reference.  Needs a JAX-capable Python env.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(OUT)

# Tiny-model subset (vit_tiny only): everything the artifact-dependent
# integration test suites need, at a fraction of the lowering time —
# this is the config CI builds and caches.
artifacts-tiny:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(OUT) --only vit_tiny

# Desktop mixed-f16 subset — the variant the L3 runtime-overhead
# bench drives.  CI layers this into the same artifact cache as the
# tiny set (`make artifacts-tiny artifacts-desktop`).
artifacts-desktop:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(OUT) --only vit_desktop_mixed_f16

build:
	cargo build --release

test:
	cargo test -q
