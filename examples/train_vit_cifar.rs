//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Reproduces the paper's desktop experiment end to end on this
//! testbed: train the §5 desktop ViT (feature 256, MLP 800,
//! CIFAR-100-shaped synthetic data) for a few hundred steps in BOTH
//! full precision and MPX mixed precision, and verify that
//!
//! 1. both losses converge,
//! 2. the curves track each other (mixed precision does not change
//!    model quality — the paper's core promise),
//! 3. dynamic loss scaling stays active and finite in the f16 run,
//! 4. the mixed step is not slower than fp32 (on this memory-bound
//!    CPU it should be faster).
//!
//! ```bash
//! cargo run --release --example train_vit_cifar -- [steps] [batch]
//! ```

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::trainer::FusedTrainer;
use mpx::util::human_duration;

fn run_one(
    store: &mut ArtifactStore,
    precision: Precision,
    steps: u64,
    batch: usize,
) -> anyhow::Result<RunMetrics> {
    let config = TrainConfig {
        model: "vit_desktop".into(),
        precision,
        batch,
        steps,
        log_every: 25,
        seed: 7,
        ..Default::default()
    };
    let preset = model_preset(&config.model)?;
    let dataset = SyntheticDataset::new(&preset, config.seed);
    let mut trainer = FusedTrainer::new(store, config.clone())?;
    let mut metrics = RunMetrics::with_csv(&format!(
        "bench_out/e2e_vit_desktop_{}.csv",
        precision.tag()
    ))?;
    eprintln!("--- {} run ---", precision.tag());
    trainer.run(&dataset, steps, &mut metrics)?;
    Ok(metrics)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32);

    let mut store = ArtifactStore::open_default()?;
    let fp32 = run_one(&mut store, Precision::Fp32, steps, batch)?;
    let mixed = run_one(&mut store, Precision::MixedF16, steps, batch)?;

    // --- loss-curve comparison -----------------------------------------
    println!("\nE2E report: vit_desktop, {steps} steps, batch {batch}");
    println!("{:>6} {:>12} {:>12} {:>8}", "step", "fp32_loss", "f16_loss", "Δ");
    let checkpoints = [0usize, 24, 49, 99, 199, steps as usize - 1];
    for &i in checkpoints.iter().filter(|&&i| i < fp32.records.len()) {
        let a = fp32.records[i].loss;
        let b = mixed.records[i].loss;
        println!("{:>6} {a:>12.4} {b:>12.4} {:>8.4}", i + 1, (a - b).abs());
    }

    let f_first = fp32.records[0].loss;
    let f_last = fp32.recent_loss(20).unwrap();
    let m_first = mixed.records[0].loss;
    let m_last = mixed.recent_loss(20).unwrap();
    let t_fp32 = fp32.mean_step_time(3).unwrap();
    let t_mixed = mixed.mean_step_time(3).unwrap();

    println!("\nconvergence : fp32 {f_first:.3} → {f_last:.3} | mixed {m_first:.3} → {m_last:.3}");
    println!(
        "step time   : fp32 {} | mixed {} | speedup {:.2}x",
        human_duration(t_fp32),
        human_duration(t_mixed),
        t_fp32.as_secs_f64() / t_mixed.as_secs_f64()
    );
    println!(
        "loss scaling: {} overflow-skipped steps in the mixed run",
        mixed.skipped_steps()
    );

    anyhow::ensure!(f_last < f_first * 0.5, "fp32 did not converge");
    anyhow::ensure!(m_last < m_first * 0.5, "mixed did not converge");
    anyhow::ensure!(
        (f_last - m_last).abs() < 0.25 * f_first,
        "mixed and fp32 curves diverged: {f_last} vs {m_last}"
    );
    println!("\nOK — mixed precision matches fp32 quality on this run.");
    Ok(())
}
