//! Fig. 2 reproduction as a standalone report: memory vs batch for
//! full and mixed precision, from BOTH estimators (analytic model and
//! HLO census of the actual artifacts), plus the headline ratio.
//!
//! ```bash
//! cargo run --release --example memory_report
//! ```

use mpx::config::{Precision, VIT_DESKTOP};
use mpx::hlo::HloModule;
use mpx::memmodel::ActivationModel;
use mpx::runtime::ArtifactStore;
use mpx::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let am = ActivationModel::new(VIT_DESKTOP);
    println!(
        "vit_desktop: {} params ({} fp32)",
        am.param_count(),
        human_bytes(4 * am.param_count())
    );

    println!("\nanalytic model (paper Fig. 2 axes):");
    println!(
        "{:>7} {:>14} {:>14} {:>7}",
        "batch", "fp32", "mixed_f16", "ratio"
    );
    for b in [8, 16, 32, 64, 128, 256] {
        let full = am.estimate(Precision::Fp32, b).total_bytes();
        let mixed = am.estimate(Precision::MixedF16, b).total_bytes();
        println!(
            "{b:>7} {:>14} {:>14} {:>6.2}x",
            human_bytes(full),
            human_bytes(mixed),
            full as f64 / mixed as f64
        );
    }
    println!(
        "paper headline: 1.8x at the largest batch; model: {:.2}x at 256",
        am.reduction_ratio(256)
    );

    // HLO census cross-check on the artifacts that exist.
    let store = ArtifactStore::open_default()?;
    println!("\nHLO census of the compiled step artifacts (workspace bytes by dtype):");
    println!(
        "{:>7} {:>12} {:>12} {:>12} {:>12} {:>7}",
        "batch", "fp32:f32", "f16:f32", "f16:f16", "f16 total", "ratio"
    );
    for b in [8, 16, 32, 64, 128] {
        let full_name = format!("step_fused_vit_desktop_fp32_b{b}");
        let mixed_name = format!("step_fused_vit_desktop_mixed_f16_b{b}");
        let (Ok(ft), Ok(mt)) =
            (store.hlo_text(&full_name), store.hlo_text(&mixed_name))
        else {
            continue;
        };
        let fh = HloModule::parse(&ft)?;
        let mh = HloModule::parse(&mt)?;
        let f_ws: u64 = fh.workspace_bytes_by_dtype().values().sum();
        let m_by = mh.workspace_bytes_by_dtype();
        let m_ws: u64 = m_by.values().sum();
        println!(
            "{b:>7} {:>12} {:>12} {:>12} {:>12} {:>6.2}x",
            human_bytes(f_ws),
            human_bytes(*m_by.get("f32").unwrap_or(&0)),
            human_bytes(*m_by.get("f16").unwrap_or(&0)),
            human_bytes(m_ws),
            f_ws as f64 / m_ws as f64,
        );
    }
    println!("\n(census counts every instruction output before XLA buffer reuse,");
    println!(" so absolute numbers overestimate; the fp32/mixed RATIO is the signal.)");
    Ok(())
}
