//! Dynamic loss scaling in action (paper §2.1 / §3.3).
//!
//! Three demonstrations:
//!
//! 1. **Real training trace** — train the tiny ViT in f16 and plot the
//!    loss-scale trajectory: the initial 2^15 probes too high, halves
//!    on the first overflows, then re-grows every `period` steps.
//! 2. **State-machine simulation** — the Rust controller replayed with
//!    injected overflows (deterministic), showing halve/grow/clamp.
//! 3. **Why scaling matters** — host-side f16 quantization of a
//!    synthetic gradient distribution, showing the underflow fraction
//!    with and without scaling (the paper's Figure-1 motivation).

use mpx::config::{Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::numerics::{underflow_fraction, FloatFormat};
use mpx::runtime::ArtifactStore;
use mpx::scaling::{LossScaler, OverflowInjector, ScalingConfig};
use mpx::trainer::FusedTrainer;
use mpx::util::rng::Rng;

fn ascii_plot(label: &str, values: &[f32]) {
    let max = values.iter().cloned().fold(f32::MIN, f32::max);
    println!("\n{label} (max {max:.0}):");
    let buckets = 60.min(values.len());
    let stride = values.len().div_ceil(buckets);
    for (i, chunk) in values.chunks(stride).enumerate() {
        let v = chunk[0];
        let width = ((v.log2() / max.log2()) * 50.0).max(0.0) as usize;
        println!("{:>5} | {:<50} 2^{:.0}", i * stride, "#".repeat(width), v.log2());
    }
}

fn main() -> anyhow::Result<()> {
    // -- 1. real training trace -----------------------------------------
    let config = TrainConfig {
        model: "vit_tiny".into(),
        precision: Precision::MixedF16,
        batch: 8,
        steps: 120,
        log_every: 1000,
        ..Default::default()
    };
    let mut store = ArtifactStore::open_default()?;
    let preset = mpx::config::model_preset(&config.model)?;
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = FusedTrainer::new(&mut store, config.clone())?;
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, config.steps, &mut metrics)?;
    let trace: Vec<f32> = metrics.records.iter().map(|r| r.loss_scale).collect();
    ascii_plot("real f16 training: loss scale over steps", &trace);
    println!(
        "overflow-skipped: {} of {} steps",
        metrics.skipped_steps(),
        metrics.records.len()
    );

    // -- 2. controller simulation with injected overflows ----------------
    let mut scaler = LossScaler::new(ScalingConfig {
        init_scale: 2.0_f32.powi(15),
        period: 20,
        ..Default::default()
    });
    let mut injector = OverflowInjector::AtSteps(vec![5, 6, 50]);
    let mut sim = Vec::new();
    for step in 0..120 {
        scaler.adjust(!injector.fires(step));
        sim.push(scaler.scale());
    }
    ascii_plot(
        "simulated controller: overflows at steps 5,6,50; period 20",
        &sim,
    );

    // -- 3. underflow motivation -----------------------------------------
    println!("\nunderflow motivation (1M synthetic gradients ~ lognormal):");
    let mut rng = Rng::new(3);
    let grads: Vec<f32> = (0..1_000_000)
        .map(|_| {
            // magnitudes centered near 1e-6 — typical late-training
            let log10 = rng.normal_f32(-6.0, 1.0);
            10f32.powf(log10)
        })
        .collect();
    for scale in [1.0f32, 128.0, 32768.0] {
        let scaled: Vec<f32> = grads.iter().map(|g| g * scale).collect();
        let lost = underflow_fraction(&scaled, FloatFormat::F16);
        println!(
            "  scale {scale:>8.0}: {:>6.2}% of gradients flush to zero in f16",
            lost * 100.0
        );
    }
    println!("  (bfloat16 at scale 1: {:.4}% — f32 exponent range)",
        underflow_fraction(&grads, FloatFormat::Bf16) * 100.0);
    Ok(())
}
