//! Batched inference serving on the AOT forward artifacts — a thin
//! driver over the continuous-batching [`mpx::serve`] engine.
//!
//! Simulates a small online-serving deployment: the fp32 and
//! mixed_f16 forwards run as two *lanes of one engine* (shared worker
//! pool, weighted-deficit scheduling, per-request streamed
//! completions), so the precision comparison happens under identical
//! contention instead of in two separate runs.  Each lane carries its
//! own SLO (`LaneConfig`), so the latency-aware bucket planner picks
//! the batch sizes and flush timeout per lane before the engine
//! starts — the plan is printed first, then measured against the real
//! run.  Per-request latency quantiles come from the shared
//! rank-interpolated [`LatencyHistogram`](mpx::metrics::LatencyHistogram)
//! — inference is where mixed precision has no loss-scaling caveats
//! at all.
//!
//! ```bash
//! cargo run --release --example serve_inference -- [requests]
//! ```

use mpx::config::{LaneConfig, Precision, ServeConfig};
use mpx::runtime::ArtifactStore;
use mpx::serve;
use mpx::util::human_duration;

fn main() -> anyhow::Result<()> {
    let total: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut store = ArtifactStore::open_default()?;

    // Two lanes with their own SLOs: both offered back-to-back
    // (closed loop) to measure service capacity under contention, but
    // with a tighter deadline on the mixed lane — the planner plans
    // each lane's buckets against its own budget.
    let cfg = ServeConfig {
        lanes: vec![
            LaneConfig {
                deadline_ms: 250,
                ..LaneConfig::named("full_fp32", Precision::Fp32)
            },
            LaneConfig {
                deadline_ms: 120,
                ..LaneConfig::named("mixed_f16", Precision::MixedF16)
            },
        ],
        requests: total,
        workers: 2,
        open_loop: false,
        // Always-on span tracing: the run doubles as a calibration
        // source (service_samples.json lands next to the artifacts).
        trace: mpx::trace::TraceConfig {
            enabled: true,
            ..Default::default()
        },
        ..ServeConfig::default()
    };

    // What the planner wants to run (and AOT-compile) for this load.
    let plan = serve::plan_for_config(&cfg)?;
    plan.print();

    println!(
        "\nserving {total} requests over 2 lanes (batch ≤ {}, {}, {} \
         workers, continuous batching):\n",
        cfg.max_batch, cfg.model, cfg.workers
    );
    let report = serve::run_with_artifacts(&mut store, &cfg)?;

    println!(
        "{:>20} {:>10} {:>10} {:>10} {:>12}",
        "lane", "p50", "p90", "p99", "completed"
    );
    let mut p50s = Vec::new();
    for lane in &report.lanes {
        let q = lane
            .latency
            .quantiles(&[0.5, 0.9, 0.99])
            .expect("no completed requests in lane");
        println!(
            "{:>20} {:>10} {:>10} {:>10} {:>12}",
            lane.name,
            human_duration(q[0]),
            human_duration(q[1]),
            human_duration(q[2]),
            lane.completed(),
        );
        p50s.push(q[0]);
    }
    println!(
        "\noverall: {:.0} req/s, {} batches, {:.1}% padding",
        report.throughput_rps(),
        report.batches(),
        report.padding_fraction() * 100.0,
    );
    // lanes[0] is fp32, lanes[1] is mixed: >1 means mixed is faster.
    println!(
        "full/mixed p50 speedup under shared contention: {:.2}x",
        p50s[0].as_secs_f64() / p50s[1].as_secs_f64()
    );

    // The span record behind those numbers: per-batch execute spans
    // become the planner's calibration samples.
    let samples = mpx::trace::service_samples(&report.spans);
    println!(
        "trace: {} spans ({} dropped), {} execute samples for the planner",
        report.spans.len(),
        report.trace_dropped,
        samples.len(),
    );
    Ok(())
}
