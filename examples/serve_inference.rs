//! Batched inference serving on the AOT forward artifact.
//!
//! Simulates a small online-serving deployment: Poisson-ish request
//! arrivals are queued, batched up to the artifact's batch size
//! (padding with repeats when the queue runs short), executed on the
//! mixed-precision forward, and per-request latency percentiles are
//! reported for fp32 vs f16 — inference is where mixed precision has
//! no loss-scaling caveats at all.
//!
//! ```bash
//! cargo run --release --example serve_inference -- [requests]
//! ```

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use mpx::config::{model_preset, Precision};
use mpx::data::SyntheticDataset;
use mpx::runtime::{lit_f32, ArtifactStore};
use mpx::util::{human_duration, rng::Rng};

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
}

fn percentile(sorted: &[Duration], q: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

fn serve(
    store: &mut ArtifactStore,
    precision: Precision,
    total_requests: usize,
) -> anyhow::Result<(Vec<Duration>, f64)> {
    let batch = 8usize;
    let name = format!("fwd_vit_tiny_{}_b{batch}", precision.tag());
    let fwd = store.load(&name)?;
    let init = store.load(&format!("init_vit_tiny_{}", precision.tag()))?;
    let state = init.execute(&[mpx::runtime::lit_scalar_i32(0)])?;
    let prange = init.manifest.output_group("params");
    let img_spec = fwd.manifest.inputs[fwd
        .manifest
        .input_group("images")
        .next_back()
        .unwrap()]
    .clone();

    let preset = model_preset("vit_tiny")?;
    let dataset = SyntheticDataset::new(&preset, 0);
    let image_elems = dataset.image_elems();
    let mut rng = Rng::new(42);

    // Pre-generate the request stream.
    let source = dataset.batch(0, total_requests, 9);
    let mut pending: VecDeque<Request> = VecDeque::new();
    let mut latencies = Vec::with_capacity(total_requests);
    let mut issued = 0usize;
    let t_start = Instant::now();

    while latencies.len() < total_requests {
        // arrivals: 1..=4 new requests per tick
        let arrivals = (1 + rng.below(4) as usize)
            .min(total_requests - issued);
        for k in 0..arrivals {
            let i = issued + k;
            pending.push_back(Request {
                image: source.images
                    [i * image_elems..(i + 1) * image_elems]
                    .to_vec(),
                enqueued: Instant::now(),
            });
        }
        issued += arrivals;
        if pending.is_empty() {
            continue;
        }

        // form one batch (pad by repeating the last request's image)
        let take = pending.len().min(batch);
        let mut flat = Vec::with_capacity(batch * image_elems);
        let mut stamps = Vec::with_capacity(take);
        for _ in 0..take {
            let r = pending.pop_front().unwrap();
            flat.extend_from_slice(&r.image);
            stamps.push(r.enqueued);
        }
        while flat.len() < batch * image_elems {
            let start = flat.len() - image_elems;
            let pad: Vec<f32> = flat[start..].to_vec();
            flat.extend_from_slice(&pad);
        }

        let images = lit_f32(&img_spec.shape, &flat)?;
        let mut inputs: Vec<&xla::Literal> =
            state[prange.clone()].iter().collect();
        inputs.push(&images);
        fwd.execute(&inputs)?;
        let done = Instant::now();
        for s in stamps {
            latencies.push(done - s);
        }
    }
    let throughput = total_requests as f64 / t_start.elapsed().as_secs_f64();
    latencies.sort();
    Ok((latencies, throughput))
}

fn main() -> anyhow::Result<()> {
    let total: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut store = ArtifactStore::open_default()?;

    println!("serving {total} requests (batch ≤ 8, vit_tiny):\n");
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>12}",
        "precision", "p50", "p90", "p99", "req/s"
    );
    let mut p50s = Vec::new();
    for precision in [Precision::Fp32, Precision::MixedF16] {
        let (lat, thr) = serve(&mut store, precision, total)?;
        println!(
            "{:>10} {:>10} {:>10} {:>10} {:>12.0}",
            precision.tag(),
            human_duration(percentile(&lat, 0.5)),
            human_duration(percentile(&lat, 0.9)),
            human_duration(percentile(&lat, 0.99)),
            thr
        );
        p50s.push(percentile(&lat, 0.5));
    }
    println!(
        "\nmixed/full p50 ratio: {:.2}x",
        p50s[0].as_secs_f64() / p50s[1].as_secs_f64()
    );
    Ok(())
}
