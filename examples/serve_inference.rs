//! Batched inference serving on the AOT forward artifacts — now a
//! thin driver over the [`mpx::serve`] engine.
//!
//! Simulates a small online-serving deployment per precision mode:
//! deterministic Poisson-ish arrivals are queued, dynamically batched
//! (size buckets, padding, flush-on-timeout), executed by a worker
//! pool sharing the compiled forward, and per-request latency
//! quantiles come from the shared rank-interpolated
//! [`LatencyHistogram`](mpx::metrics::LatencyHistogram) — inference
//! is where mixed precision has no loss-scaling caveats at all.
//!
//! ```bash
//! cargo run --release --example serve_inference -- [requests]
//! ```

use mpx::config::{Precision, ServeConfig};
use mpx::runtime::ArtifactStore;
use mpx::serve;
use mpx::util::human_duration;

fn main() -> anyhow::Result<()> {
    let total: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut store = ArtifactStore::open_default()?;

    println!("serving {total} requests (batch ≤ 8, vit_tiny, 2 workers):\n");
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>12}",
        "precision", "p50", "p90", "p99", "req/s"
    );
    let mut p50s = Vec::new();
    for precision in [Precision::Fp32, Precision::MixedF16] {
        let cfg = ServeConfig {
            precision,
            requests: total,
            workers: 2,
            // closed loop, back-to-back: measure service capacity
            arrival_rate: 0.0,
            open_loop: false,
            ..ServeConfig::default()
        };
        let report = serve::run_with_artifacts(&mut store, &cfg)?;
        let q = report
            .latency
            .quantiles(&[0.5, 0.9, 0.99])
            .expect("no completed requests");
        println!(
            "{:>10} {:>10} {:>10} {:>10} {:>12.0}",
            precision.tag(),
            human_duration(q[0]),
            human_duration(q[1]),
            human_duration(q[2]),
            report.throughput_rps(),
        );
        p50s.push(q[0]);
    }
    // p50s[0] is fp32, p50s[1] is mixed: >1 means mixed is faster.
    println!(
        "\nfull/mixed p50 speedup: {:.2}x",
        p50s[0].as_secs_f64() / p50s[1].as_secs_f64()
    );
    Ok(())
}
