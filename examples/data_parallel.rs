//! Simulated multi-device data parallelism (paper's cluster setup).
//!
//! Two demonstrations:
//!
//! 1. **Equivalence** — the decomposed path (per-shard grads →
//!    all-reduce → Rust AdamW → Rust loss scaler) with 1 shard must
//!    track the fused in-graph path on the same data: same recipe,
//!    two implementations.
//! 2. **Scaling** — 1/2/4 shards on the shared executable; per-step
//!    wall time and loss, paper-style "divide each batch equally
//!    across GPUs".
//!
//! ```bash
//! cargo run --release --example data_parallel
//! ```

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::trainer::{DataParallelTrainer, FusedTrainer};
use mpx::util::human_duration;

fn main() -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let preset = model_preset("vit_tiny")?;
    let steps = 25u64;

    // -- 1. equivalence: fused vs decomposed, identical data -------------
    let base = TrainConfig {
        model: "vit_tiny".into(),
        precision: Precision::MixedF16,
        batch: 8,
        shards: 1,
        steps,
        seed: 3,
        log_every: 1000,
        ..Default::default()
    };
    let dataset = SyntheticDataset::new(&preset, base.seed);

    let mut fused = FusedTrainer::new(&mut store, base.clone())?;
    let mut m_fused = RunMetrics::new();
    fused.run(&dataset, steps, &mut m_fused)?;

    let mut ddp = DataParallelTrainer::new(&mut store, base.clone())?;
    let mut m_ddp = RunMetrics::new();
    ddp.run(&dataset, steps, &mut m_ddp)?;

    println!("equivalence (fused in-graph vs decomposed Rust path):");
    println!("{:>5} {:>12} {:>12} {:>9}", "step", "fused", "decomposed", "Δ");
    let mut max_delta = 0f32;
    for i in (0..steps as usize).step_by(4) {
        let a = m_fused.records[i].loss;
        let b = m_ddp.records[i].loss;
        max_delta = max_delta.max((a - b).abs());
        println!("{:>5} {a:>12.4} {b:>12.4} {:>9.5}", i + 1, (a - b).abs());
    }
    println!("max |Δloss| over trajectory: {max_delta:.5}");
    anyhow::ensure!(
        max_delta < 0.15,
        "fused and decomposed training diverged"
    );

    // -- 2. scaling: shards × per-shard batch ----------------------------
    println!("\nscaling (per-shard batch 8, like the paper's per-GPU split):");
    println!(
        "{:>7} {:>13} {:>13} {:>12}",
        "shards", "global batch", "step time", "final loss"
    );
    for shards in [1usize, 2, 4] {
        let cfg = TrainConfig { shards, ..base.clone() };
        let mut t = DataParallelTrainer::new(&mut store, cfg)?;
        let mut m = RunMetrics::new();
        t.run(&dataset, steps, &mut m)?;
        println!(
            "{shards:>7} {:>13} {:>13} {:>12.4}",
            8 * shards,
            human_duration(m.mean_step_time(3).unwrap()),
            m.recent_loss(5).unwrap()
        );
    }
    println!("\nOK — decomposed data-parallel path matches and scales.");
    Ok(())
}
