//! Quickstart: train a tiny ViT in mixed precision from Rust.
//!
//! ```bash
//! make artifacts          # once: AOT-compile the train steps
//! cargo run --release --example quickstart
//! ```
//!
//! This is the paper's Example 2(b) pipeline end-to-end: the fused
//! step artifact contains `mpx.filter_value_and_grad` (cast → scale →
//! grad → unscale → finite-check → adjust) plus
//! `mpx.optimizer_update` (skip-on-overflow AdamW), and Rust drives it
//! with synthetic CIFAR-like batches.

use mpx::config::{Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::trainer::FusedTrainer;

fn main() -> anyhow::Result<()> {
    let config = TrainConfig {
        model: "vit_tiny".into(),
        precision: Precision::MixedF16,
        batch: 8,
        steps: 60,
        log_every: 10,
        ..Default::default()
    };

    let mut store = ArtifactStore::open_default()?;
    let preset = mpx::config::model_preset(&config.model)?;
    let dataset = SyntheticDataset::new(&preset, config.seed);

    let mut trainer = FusedTrainer::new(&mut store, config.clone())?;
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, config.steps, &mut metrics)?;

    let first = metrics.records.first().unwrap();
    let last_loss = metrics.recent_loss(5).unwrap();
    println!("\nquickstart summary");
    println!("  initial loss : {:.4}", first.loss);
    println!("  final loss   : {last_loss:.4}");
    println!("  loss scale   : {:.0}", trainer.loss_scale()?);
    println!(
        "  overflow-skipped steps: {} (dynamic loss scaling recovered)",
        metrics.skipped_steps()
    );
    anyhow::ensure!(last_loss < first.loss * 0.5, "training did not converge");
    println!("OK — mixed-precision training converges from Rust.");
    Ok(())
}
