//! serve_http — the serve network transport end to end, no PJRT
//! required: a stub executor stands in for the compiled forward so
//! the whole loop (HTTP server → lane queues → continuous-batching
//! scheduler → streamed chunked responses → Prometheus metrics) runs
//! on any host.
//!
//! Two modes:
//!
//! ```text
//! cargo run --example serve_http --no-default-features
//!     # self-driving demo: binds an ephemeral port, fires a Poisson
//!     # load through transport::client::drive, prints the reports.
//!
//! cargo run --example serve_http --no-default-features -- --listen 127.0.0.1:7878
//!     # stays up for curl until Ctrl-C (graceful drain):
//!     #   curl -N -d '{"lane":"chat","image":[1,2,3,4]}' \
//!     #        http://127.0.0.1:7878/v1/infer
//!     #   curl http://127.0.0.1:7878/metrics
//!     #   curl http://127.0.0.1:7878/debug/trace
//! ```
//!
//! The real-artifact variant of exactly this server is
//! `mpx serve --listen ADDR` (needs `make artifacts`; runs on either
//! runtime backend — PJRT or the pure-Rust host interpreter).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use mpx::cli::Args;
use mpx::config::TransportConfig;
use mpx::serve::transport::{client, Server};
use mpx::serve::{BatchExecutor, BatcherConfig, LaneSpec, SchedPolicy};
use mpx::util::human_duration;

/// Flattened demo "image" length (stands in for C×H×W).
const ELEMS: usize = 4;
const WORKERS: usize = 2;

/// Stub forward: logits = inputs × lane scale, with a deliberate
/// overflow when an input is huge — so the per-response `finite`
/// flag and the `mpx_serve_nonfinite_total` counter have something
/// real to report.
struct DemoExecutor {
    scale: f32,
}

impl BatchExecutor for DemoExecutor {
    fn execute(&mut self, images: &[f32], _batch: usize) -> Result<Vec<f32>> {
        Ok(images
            .iter()
            .map(|v| {
                let y = v * self.scale;
                if v.abs() > 1e30 {
                    f32::INFINITY // simulated half-precision overflow
                } else {
                    y
                }
            })
            .collect())
    }
}

fn lanes() -> Vec<LaneSpec> {
    let mk = |name: &str, flush_ms: u64| LaneSpec {
        name: name.into(),
        weight: 1,
        batcher: BatcherConfig::new(
            vec![1, 2, 4, 8],
            Duration::from_millis(flush_ms),
        )
        .expect("static buckets are valid"),
        queue_capacity: 64,
        deadline: Duration::from_millis(100),
    };
    vec![mk("demo/chat", 2), mk("demo/bulk", 10)]
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let listen = args.get_str("listen").map(str::to_string);
    args.finish()?;

    let tcfg = TransportConfig {
        addr: listen.clone().unwrap_or_else(|| "127.0.0.1:0".into()),
        ..TransportConfig::default()
    };
    let mut server = Server::bind(&tcfg)?;
    // Tracing on: the demo exercises the whole observability surface,
    // /debug/trace included.
    server.set_trace(mpx::trace::TraceConfig {
        enabled: true,
        ..Default::default()
    });
    let addr = server.local_addr();
    let handle = server.handle();
    eprintln!("[serve_http] listening on http://{addr}");
    eprintln!("[serve_http]   curl -N -d '{{\"lane\":\"chat\",\"image\":[1,2,3,4]}}' http://{addr}/v1/infer");
    eprintln!("[serve_http]   curl http://{addr}/healthz");
    eprintln!("[serve_http]   curl http://{addr}/metrics");
    eprintln!("[serve_http]   curl http://{addr}/debug/trace   # Chrome trace JSON (load in Perfetto)");

    let forever = listen.is_some();
    if forever {
        mpx::serve::transport::install_sigint();
        eprintln!("[serve_http] Ctrl-C drains and exits");
    }

    let server_thread = std::thread::spawn(move || {
        server.run(
            lanes(),
            WORKERS,
            SchedPolicy::Continuous,
            ELEMS,
            |_worker, lane| Ok(DemoExecutor { scale: (lane + 2) as f32 }),
        )
    });

    if !forever {
        // Self-driving demo: Poisson load through the std-only client
        // — the same deterministic generator the engine benches use.
        let image = Arc::new(
            (0..ELEMS).map(|i| i as f32 + 1.0).collect::<Vec<f32>>(),
        );
        let img = image.clone();
        let drive = client::drive(
            &addr.to_string(),
            "chat",
            200,
            500.0,
            7,
            8,
            move |_i| img.as_ref().clone(),
        );
        println!(
            "[serve_http] drive: {} offered, {} completed, {} rejected, \
             {} errors, {} non-finite",
            drive.offered,
            drive.completed,
            drive.rejected,
            drive.errors,
            drive.nonfinite,
        );
        if let Some(s) = drive.latency.summary() {
            println!(
                "[serve_http] client RTT p50 {}  p95 {}  p99 {}",
                human_duration(s.p50),
                human_duration(s.p95),
                human_duration(s.p99),
            );
        }
        // One request that overflows, to exercise the accounting.
        let c = client::Client::new(addr.to_string());
        let reply = c.infer("chat", &[1e38, 2.0, 3.0, 4.0])?;
        println!(
            "[serve_http] overflow probe: finite = {} (logits[0] = {:?})",
            reply.finite,
            reply.logits.first(),
        );
        let metrics = c.metrics()?;
        for line in metrics.lines().filter(|l| {
            l.starts_with("mpx_serve_completed_total")
                || l.starts_with("mpx_serve_nonfinite_total")
                || l.starts_with("mpx_transport_")
        }) {
            println!("[serve_http] metrics: {line}");
        }
        // The span dump over the wire: a Chrome trace document whose
        // otherData carries the live span/drop counters.
        let trace = c.debug_trace()?;
        let doc = mpx::util::json::Json::parse(&trace)
            .expect("/debug/trace must return valid JSON");
        println!(
            "[serve_http] /debug/trace: {} spans buffered, {} events",
            doc.get("otherData")
                .and_then(|o| o.get("spans"))
                .and_then(mpx::util::json::Json::as_i64)
                .unwrap_or(0),
            doc.get("traceEvents")
                .and_then(mpx::util::json::Json::as_arr)
                .map_or(0, |events| events.len()),
        );
        handle.shutdown();
    }

    let report = server_thread
        .join()
        .expect("server thread panicked")?;
    report.print();
    Ok(())
}
