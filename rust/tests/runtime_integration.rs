//! Integration tests over the real runtime + AOT artifacts, on
//! whichever backend the build defaults to: PJRT with the `xla`
//! feature, the pure-Rust host interpreter under
//! `--no-default-features` — the whole suite *runs* in both builds.
//!
//! These need `make artifacts` to have run (the repo ships with the
//! artifacts built); every test compiles the tiny-model artifacts so
//! the suite stays fast.

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::{lit_scalar_i32, read_f32, Value};
use mpx::trainer::{checkpoint, FusedTrainer};

mod common;
use common::store;

fn tiny_config(precision: Precision) -> TrainConfig {
    TrainConfig {
        model: "vit_tiny".into(),
        precision,
        batch: 8,
        log_every: 10_000,
        ..Default::default()
    }
}

#[test]
fn fused_training_converges_mixed_f16() {
    let Some(mut store) = store() else { return };
    let cfg = tiny_config(Precision::MixedF16);
    let preset = model_preset(&cfg.model).unwrap();
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = FusedTrainer::new(&mut store, cfg).unwrap();
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, 40, &mut metrics).unwrap();

    let first = metrics.records[0].loss;
    let last = metrics.recent_loss(5).unwrap();
    assert!(last < first * 0.5, "no convergence: {first} → {last}");
    assert!(last.is_finite());
    // dynamic scaling must have been exercised (starts at 2^15)
    assert!(trainer.loss_scale().unwrap() >= 1.0);
}

#[test]
fn fused_training_converges_fp32_baseline() {
    let Some(mut store) = store() else { return };
    let cfg = tiny_config(Precision::Fp32);
    let preset = model_preset(&cfg.model).unwrap();
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = FusedTrainer::new(&mut store, cfg).unwrap();
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, 40, &mut metrics).unwrap();
    let last = metrics.recent_loss(5).unwrap();
    assert!(last < metrics.records[0].loss * 0.5);
    // fp32 scale is pinned to 1 and never overflows
    assert_eq!(trainer.loss_scale().unwrap(), 1.0);
    assert_eq!(metrics.skipped_steps(), 0);
}

#[test]
fn mixed_matches_fp32_quality() {
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 5);

    let mut run = |precision| {
        let mut cfg = tiny_config(precision);
        cfg.seed = 5;
        let mut t = FusedTrainer::new(&mut store, cfg).unwrap();
        let mut m = RunMetrics::new();
        t.run(&dataset, 30, &mut m).unwrap();
        m.recent_loss(5).unwrap()
    };
    let full = run(Precision::Fp32);
    let mixed = run(Precision::MixedF16);
    // the paper's core promise: same model quality
    assert!(
        (full - mixed).abs() < 0.3,
        "quality gap too large: fp32 {full} vs mixed {mixed}"
    );
}

#[test]
fn bf16_runs_without_loss_scaling_overflows() {
    let Some(mut store) = store() else { return };
    let cfg = tiny_config(Precision::MixedBf16);
    let preset = model_preset(&cfg.model).unwrap();
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = FusedTrainer::new(&mut store, cfg).unwrap();
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, 25, &mut metrics).unwrap();
    // bf16 shares f32's exponent range: pinned scale, no skips
    assert_eq!(metrics.skipped_steps(), 0);
    assert!(metrics.recent_loss(5).unwrap() < metrics.records[0].loss);
}

#[test]
fn pallas_kernel_step_matches_xla_step() {
    // The Pallas-kernel ViT variant (fused attention / layernorm /
    // matmul kernels with custom VJPs) must train like the XLA-op one.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 1);

    let xla_art = store.load("step_fused_vit_tiny_mixed_f16_b8").unwrap();
    let pal_art =
        store.load("step_fused_vit_tiny_pallas_mixed_f16_b8").unwrap();
    let init = store.load("init_vit_tiny_mixed_f16").unwrap();
    let state0 = init.execute(&[lit_scalar_i32(1)]).unwrap();

    let run = |art: &std::sync::Arc<mpx::runtime::Artifact>| {
        let mut state: Vec<Value> =
            state0.iter().map(Clone::clone).collect();
        let mut losses = Vec::new();
        for i in 0..5u64 {
            let b = dataset.batch(i, 8, 1);
            let images = mpx::runtime::lit_f32(
                &art.manifest.inputs
                    [art.manifest.input_group("images").next_back().unwrap()]
                .shape,
                &b.images,
            )
            .unwrap();
            let labels =
                mpx::runtime::lit_i32(&[8], &b.labels).unwrap();
            let mut inputs: Vec<&Value> = state.iter().collect();
            inputs.push(&images);
            inputs.push(&labels);
            let mut out = art.execute(inputs).unwrap();
            let loss_idx =
                art.manifest.output_group("loss").next_back().unwrap();
            losses.push(
                mpx::runtime::read_scalar_f32(&out[loss_idx]).unwrap(),
            );
            out.truncate(state.len());
            state = out;
        }
        losses
    };

    let xla_losses = run(&xla_art);
    let pal_losses = run(&pal_art);
    for (i, (a, b)) in xla_losses.iter().zip(&pal_losses).enumerate() {
        assert!(
            (a - b).abs() < 0.05 * a.abs().max(1.0),
            "step {i}: xla {a} vs pallas {b}"
        );
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(mut store) = store() else { return };
    let cfg = tiny_config(Precision::MixedF16);
    let preset = model_preset(&cfg.model).unwrap();
    let dataset = SyntheticDataset::new(&preset, 2);

    let mut trainer = FusedTrainer::new(&mut store, cfg.clone()).unwrap();
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, 10, &mut metrics).unwrap();

    let dir = std::env::temp_dir().join("mpx_ckpt_test");
    let path = dir.join("t.ckpt");
    let path = path.to_str().unwrap().to_string();
    let specs =
        trainer.manifest().inputs[..trainer.state().len()].to_vec();
    checkpoint::save(&path, trainer.step_index, &specs, trainer.state(), &[])
        .unwrap();

    // continue original
    let mut m1 = RunMetrics::new();
    trainer.run(&dataset, 3, &mut m1).unwrap();

    // restore into a fresh trainer and continue — identical losses
    let mut trainer2 = FusedTrainer::new(&mut store, cfg).unwrap();
    let (step, leaves, _scaler) = checkpoint::load(&path, &specs).unwrap();
    trainer2.set_state(leaves).unwrap();
    trainer2.step_index = step;
    let mut m2 = RunMetrics::new();
    trainer2.run(&dataset, 3, &mut m2).unwrap();

    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(),
                   "resume diverged at step {}", a.step);
    }
}

#[test]
fn checkpoint_rejects_wrong_manifest() {
    let Some(mut store) = store() else { return };
    let cfg = tiny_config(Precision::MixedF16);
    let mut trainer = FusedTrainer::new(&mut store, cfg).unwrap();
    let specs =
        trainer.manifest().inputs[..trainer.state().len()].to_vec();
    let dir = std::env::temp_dir().join("mpx_ckpt_test2");
    let path = dir.join("t.ckpt");
    let path = path.to_str().unwrap().to_string();
    checkpoint::save(&path, 1, &specs, trainer.state(), &[]).unwrap();

    let mut wrong = specs.clone();
    wrong[0].shape = vec![99, 99];
    assert!(checkpoint::load(&path, &wrong).is_err());
    let _ = trainer.step(&SyntheticDataset::new(
        &model_preset("vit_tiny").unwrap(), 0).batch(0, 8, 0));
}

#[test]
fn forward_is_deterministic() {
    let Some(mut store) = store() else { return };
    let fwd = store.load("fwd_vit_tiny_mixed_f16_b8").unwrap();
    let init = store.load("init_vit_tiny_mixed_f16").unwrap();
    let state = init.execute(&[lit_scalar_i32(0)]).unwrap();
    let prange = init.manifest.output_group("params");

    let preset = model_preset("vit_tiny").unwrap();
    let b = SyntheticDataset::new(&preset, 0).batch(0, 8, 0);
    let img_spec = &fwd.manifest.inputs
        [fwd.manifest.input_group("images").next_back().unwrap()];
    let run = || {
        let images = mpx::runtime::lit_f32(&img_spec.shape, &b.images).unwrap();
        let mut inputs: Vec<&Value> =
            state[prange.clone()].iter().collect();
        inputs.push(&images);
        read_f32(&fwd.execute(inputs).unwrap()[0]).unwrap()
    };
    let a = run();
    let c = run();
    assert_eq!(a, c);
    assert_eq!(a.len(), 8 * 10); // batch × classes
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn init_is_seed_dependent_and_deterministic() {
    let Some(mut store) = store() else { return };
    let init = store.load("init_vit_tiny_mixed_f16").unwrap();
    let a = init.execute(&[lit_scalar_i32(0)]).unwrap();
    let b = init.execute(&[lit_scalar_i32(0)]).unwrap();
    let c = init.execute(&[lit_scalar_i32(1)]).unwrap();
    let pa = read_f32(&a[1]).unwrap();
    let pb = read_f32(&b[1]).unwrap();
    let pc = read_f32(&c[1]).unwrap();
    assert_eq!(pa, pb, "same seed must give identical params");
    assert_ne!(pa, pc, "different seeds must differ");
}

#[test]
fn manifest_state_contract_holds_for_all_step_artifacts() {
    // Every step_fused artifact: init outputs == step state inputs.
    let Some(store) = store() else { return };
    for name in store.list().unwrap() {
        if !name.starts_with("step_fused_vit_tiny") {
            continue;
        }
        let m = store.manifest(&name).unwrap();
        let n_state = ["params", "opt_state", "scaling"]
            .iter()
            .map(|g| m.input_group(g).len())
            .sum::<usize>();
        let n_out_state = ["params", "opt_state", "scaling"]
            .iter()
            .map(|g| m.output_group(g).len())
            .sum::<usize>();
        assert_eq!(n_state, n_out_state, "{name}: state arity mismatch");
        for (i, o) in m.inputs[..n_state]
            .iter()
            .zip(&m.outputs[..n_out_state])
        {
            assert_eq!(i.dtype, o.dtype, "{name}: {}", i.name);
            assert_eq!(i.shape, o.shape, "{name}: {}", i.name);
        }
    }
}
