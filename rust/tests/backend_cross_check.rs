//! Backend cross-check: the pure-Rust host interpreter and the PJRT
//! backend must agree on the same artifact with identical inputs.
//!
//! Oracle, per output dtype:
//!
//! * integer / pred leaves — **bit-exact**.  The threefry path and
//!   every comparison are order-deterministic on both backends.
//! * f32 — tolerance `|a−b| ≤ 1e-5 + 1e-3·max(|a|,|b|)`: dot and
//!   reduce accumulate in different orders (the interpreter folds
//!   sequentially, XLA vectorizes/FMA-contracts), so the last few
//!   ulps legitimately differ.
//! * f16 / bf16 — the same shape of bound, widened to the 16-bit
//!   format's resolution (the divergent f32 accumulation is rounded
//!   once on either side).
//!
//! Identical inputs are guaranteed by materialising all state on the
//! *host* backend and feeding the same [`Value`]s to both executables.
//! Without the `xla` feature the cross-backend tests degrade to a
//! note (the host-determinism test still runs), so the suite is
//! meaningful under `--no-default-features` too.

use mpx::config::model_preset;
use mpx::data::SyntheticDataset;
use mpx::numerics::{Bf16, F16};
use mpx::pytree::DType;
use mpx::runtime::{
    lit_f32, lit_i32, lit_scalar_i32, ArtifactStore, BackendKind, Value,
};

/// Open the artifact store on `kind`, or `None` (skip with a note)
/// when the artifacts have not been built.
fn open(kind: BackendKind) -> Option<ArtifactStore> {
    match ArtifactStore::open_default_with(kind) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}

fn decode_f32s(v: &Value) -> Vec<f32> {
    match v.dtype() {
        DType::F32 => mpx::runtime::read_f32(v).unwrap(),
        DType::F16 => v
            .bytes()
            .chunks_exact(2)
            .map(|c| F16(u16::from_le_bytes([c[0], c[1]])).to_f32())
            .collect(),
        DType::Bf16 => v
            .bytes()
            .chunks_exact(2)
            .map(|c| Bf16(u16::from_le_bytes([c[0], c[1]])).to_f32())
            .collect(),
        other => panic!("decode_f32s on {other:?}"),
    }
}

/// Pinned per-dtype agreement: `None` means bit-exact.
fn tolerance(dt: DType) -> Option<(f32, f32)> {
    match dt {
        DType::F32 => Some((1e-3, 1e-5)),
        DType::F16 => Some((1e-2, 1e-3)),
        DType::Bf16 => Some((4e-2, 4e-3)),
        _ => None,
    }
}

fn assert_agree(name: &str, host: &Value, xla: &Value) {
    assert_eq!(host.dtype(), xla.dtype(), "{name}: dtype");
    assert_eq!(host.shape(), xla.shape(), "{name}: shape");
    match tolerance(host.dtype()) {
        None => assert_eq!(
            host.bytes(),
            xla.bytes(),
            "{name}: {:?} leaves must be bit-exact across backends",
            host.dtype()
        ),
        Some((rtol, atol)) => {
            let a = decode_f32s(host);
            let b = decode_f32s(xla);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if x.is_nan() && y.is_nan() {
                    continue;
                }
                let bound = atol + rtol * x.abs().max(y.abs());
                assert!(
                    (x - y).abs() <= bound,
                    "{name}[{i}]: host {x} vs xla {y} (bound {bound})"
                );
            }
        }
    }
}

/// `(state, images, labels)` for one tiny-model step, all built on
/// the host backend so both executables see identical bytes.
fn step_inputs(
    host: &mut ArtifactStore,
    init_name: &str,
    step_name: &str,
) -> (Vec<Value>, Value, Value) {
    let init = host.load(init_name).unwrap();
    let state = init.execute(&[lit_scalar_i32(3)]).unwrap();
    let step = host.load(step_name).unwrap();
    let img_spec = &step.manifest.inputs
        [step.manifest.input_group("images").next_back().unwrap()];
    let preset = model_preset("vit_tiny").unwrap();
    let b = SyntheticDataset::new(&preset, 3).batch(0, 8, 0);
    let images = lit_f32(&img_spec.shape, &b.images).unwrap();
    let labels = lit_i32(&[8], &b.labels).unwrap();
    (state, images, labels)
}

fn run_step(
    store: &mut ArtifactStore,
    step_name: &str,
    state: &[Value],
    images: &Value,
    labels: &Value,
) -> Vec<Value> {
    let step = store.load(step_name).unwrap();
    let mut inputs: Vec<&Value> = state.iter().collect();
    inputs.push(images);
    inputs.push(labels);
    step.execute(inputs).unwrap()
}

/// Always runs (any build): the interpreter itself must be bitwise
/// deterministic run-to-run, including its threaded dot path.
#[test]
fn host_backend_is_bit_deterministic() {
    let Some(mut host) = open(BackendKind::Host) else { return };
    let (state, images, labels) = step_inputs(
        &mut host,
        "init_vit_tiny_mixed_f16",
        "step_fused_vit_tiny_mixed_f16_b8",
    );
    let a = run_step(
        &mut host,
        "step_fused_vit_tiny_mixed_f16_b8",
        &state,
        &images,
        &labels,
    );
    let b = run_step(
        &mut host,
        "step_fused_vit_tiny_mixed_f16_b8",
        &state,
        &images,
        &labels,
    );
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(
            x.bytes(),
            y.bytes(),
            "host output {i} not deterministic"
        );
    }
}

#[test]
fn init_agrees_across_backends() {
    if !BackendKind::Xla.available() {
        eprintln!("note: xla not compiled in — host-only build, no cross-check");
        return;
    }
    let Some(mut host) = open(BackendKind::Host) else { return };
    let Some(mut xla) = open(BackendKind::Xla) else { return };
    let seed = [lit_scalar_i32(7)];
    let h = host.load("init_vit_tiny_mixed_f16").unwrap();
    let x = xla.load("init_vit_tiny_mixed_f16").unwrap();
    let ho = h.execute(&seed).unwrap();
    let xo = x.execute(&seed).unwrap();
    assert_eq!(ho.len(), xo.len());
    for (spec, (a, b)) in h.manifest.outputs.iter().zip(ho.iter().zip(&xo)) {
        assert_agree(&spec.name, a, b);
    }
}

#[test]
fn fp32_step_agrees_across_backends() {
    if !BackendKind::Xla.available() {
        eprintln!("note: xla not compiled in — host-only build, no cross-check");
        return;
    }
    let Some(mut host) = open(BackendKind::Host) else { return };
    let Some(mut xla) = open(BackendKind::Xla) else { return };
    let (state, images, labels) = step_inputs(
        &mut host,
        "init_vit_tiny_fp32",
        "step_fused_vit_tiny_fp32_b8",
    );
    let ho = run_step(
        &mut host,
        "step_fused_vit_tiny_fp32_b8",
        &state,
        &images,
        &labels,
    );
    let xo = run_step(
        &mut xla,
        "step_fused_vit_tiny_fp32_b8",
        &state,
        &images,
        &labels,
    );
    let manifest = host.load("step_fused_vit_tiny_fp32_b8").unwrap();
    assert_eq!(ho.len(), xo.len());
    for (spec, (a, b)) in
        manifest.manifest.outputs.iter().zip(ho.iter().zip(&xo))
    {
        assert_agree(&spec.name, a, b);
    }
}

#[test]
fn f16_forward_agrees_across_backends() {
    if !BackendKind::Xla.available() {
        eprintln!("note: xla not compiled in — host-only build, no cross-check");
        return;
    }
    let Some(mut host) = open(BackendKind::Host) else { return };
    let Some(mut xla) = open(BackendKind::Xla) else { return };
    let init = host.load("init_vit_tiny_mixed_f16").unwrap();
    let state = init.execute(&[lit_scalar_i32(0)]).unwrap();
    let prange = init.manifest.output_group("params");

    let fwd_name = "fwd_vit_tiny_mixed_f16_b8";
    let hf = host.load(fwd_name).unwrap();
    let xf = xla.load(fwd_name).unwrap();
    let img_spec = &hf.manifest.inputs
        [hf.manifest.input_group("images").next_back().unwrap()];
    let preset = model_preset("vit_tiny").unwrap();
    let b = SyntheticDataset::new(&preset, 0).batch(0, 8, 0);
    let images = lit_f32(&img_spec.shape, &b.images).unwrap();

    let run = |art: &mpx::runtime::Artifact| {
        let mut inputs: Vec<&Value> = state[prange.clone()].iter().collect();
        inputs.push(&images);
        art.execute(inputs).unwrap()
    };
    let ho = run(&hf);
    let xo = run(&xf);
    assert_eq!(ho.len(), xo.len());
    for (spec, (a, b)) in hf.manifest.outputs.iter().zip(ho.iter().zip(&xo))
    {
        assert_agree(&spec.name, a, b);
    }
}
