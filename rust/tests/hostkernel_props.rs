//! Property tests for the vectorized host-kernel layer.
//!
//! The contract under test (see `hostkernel`'s module docs):
//!
//! * batch casts are **bit-identical** to the scalar `F16`/`Bf16`
//!   round-to-nearest-even implementations — across every exponent,
//!   NaN payloads (quiet and signaling), ±inf, subnormals, and both
//!   rounding-tie directions;
//! * the fused gradient scan matches `unscale-then-tensor_stats`
//!   exactly (bitwise, including the f64-accumulated mean);
//! * chunk-parallel add/scale and the tree all-reduce are bitwise
//!   deterministic across thread counts and identical to the
//!   sequential originals;
//! * the batch-kernel-backed under/overflow diagnostics equal the
//!   per-element `quantize` definition.

use mpx::collective::{
    all_reduce_finite, all_reduce_mean, sequential_all_reduce_reference,
};
use mpx::hostkernel::{cast, reduce, scan, BufferPool};
use mpx::numerics::{
    overflow_count, tensor_stats, underflow_fraction, Bf16, FloatFormat, F16,
    TensorStats,
};
use mpx::util::proptest::forall;
use mpx::util::rng::Rng;

/// Directed down-cast inputs: every special the rounding logic
/// branches on.
fn directed_f32s() -> Vec<f32> {
    let mut xs = vec![
        0.0f32,
        -0.0,
        1.0,
        -1.0,
        0.5,
        2.0,
        // f16 overflow boundary: max finite, below/at/above the
        // rounding tie at 65520, first value that is exactly inf
        65504.0,
        65519.0,
        65520.0,
        65521.0,
        65536.0,
        -65520.0,
        1e9,
        -1e9,
        f32::MAX,
        -f32::MAX,
        // f16 subnormal range and the underflow ties
        2f32.powi(-14),
        2f32.powi(-24),
        2f32.powi(-25),     // tie with zero → even (zero)
        2.9802322e-8,       // half the smallest subnormal
        3.1e-8,             // just above → smallest subnormal
        5.9604645e-8,
        -5.9604645e-8,
        1e-40,              // f32 subnormal itself
        -1e-40,
        f32::MIN_POSITIVE,
        // rounding ties in the normal range, both directions
        1.0 + 2f32.powi(-11),          // tie → down (even)
        1.0 + 3.0 * 2f32.powi(-11),    // tie → up (even)
        1.0 + 2f32.powi(-11) + 1e-7,   // above tie → up
        // bf16 ties
        1.0 + 2f32.powi(-8),
        1.0 + 3.0 * 2f32.powi(-8),
        1.0 + 2f32.powi(-8) + 1e-6,
        // infinities
        f32::INFINITY,
        f32::NEG_INFINITY,
    ];
    // NaNs: quiet/signaling, varied payloads, both signs
    for payload in [1u32, 0x1FFF, 0x2000, 0x2001, 0x200000, 0x3FFFFF, 0x7FFFFF]
    {
        xs.push(f32::from_bits(0x7F80_0000 | payload)); // signaling-ish
        xs.push(f32::from_bits(0xFF80_0000 | payload));
        xs.push(f32::from_bits(0x7FC0_0000 | payload)); // quiet
    }
    xs
}

fn assert_f16_batch_matches_scalar(xs: &[f32]) {
    let mut got = vec![0u16; xs.len()];
    cast::f32_to_f16_slice(xs, &mut got);
    for (x, g) in xs.iter().zip(&got) {
        let want = F16::from_f32(*x).0;
        assert_eq!(
            *g, want,
            "f32→f16 mismatch for {x} ({:#010x}): got {g:#06x} want {want:#06x}",
            x.to_bits()
        );
    }
}

fn assert_bf16_batch_matches_scalar(xs: &[f32]) {
    let mut got = vec![0u16; xs.len()];
    cast::f32_to_bf16_slice(xs, &mut got);
    for (x, g) in xs.iter().zip(&got) {
        let want = Bf16::from_f32(*x).0;
        assert_eq!(
            *g, want,
            "f32→bf16 mismatch for {x} ({:#010x}): got {g:#06x} want {want:#06x}",
            x.to_bits()
        );
    }
}

#[test]
fn downcasts_match_scalar_on_directed_specials() {
    let xs = directed_f32s();
    assert_f16_batch_matches_scalar(&xs);
    assert_bf16_batch_matches_scalar(&xs);
}

#[test]
fn downcasts_match_scalar_across_every_exponent() {
    // Structured sweep: for each of the 256 f32 exponents, both
    // signs, boundary mantissas (incl. the RTNE tie patterns) plus
    // random ones — the partition the branchless select is built on.
    let mut rng = Rng::new(0xCA57);
    let mut xs = Vec::new();
    for exp in 0u32..=255 {
        for sign in [0u32, 0x8000_0000] {
            for man in
                [0u32, 1, 0x0FFF, 0x1000, 0x1001, 0x1FFF, 0x2000, 0x400000,
                 0x7FFFFF]
            {
                xs.push(f32::from_bits(sign | (exp << 23) | man));
            }
            for _ in 0..40 {
                let man = (rng.next_u64() as u32) & 0x7FFFFF;
                xs.push(f32::from_bits(sign | (exp << 23) | man));
            }
        }
    }
    assert_f16_batch_matches_scalar(&xs);
    assert_bf16_batch_matches_scalar(&xs);
}

#[test]
fn upcasts_match_scalar_exhaustively() {
    let halves: Vec<u16> = (0u16..=u16::MAX).collect();
    let mut f16s = vec![0f32; halves.len()];
    let mut bf16s = vec![0f32; halves.len()];
    cast::f16_to_f32_slice(&halves, &mut f16s);
    cast::bf16_to_f32_slice(&halves, &mut bf16s);
    for (h, (a, b)) in halves.iter().zip(f16s.iter().zip(&bf16s)) {
        assert_eq!(
            a.to_bits(),
            F16(*h).to_f32().to_bits(),
            "f16→f32 mismatch at {h:#06x}"
        );
        assert_eq!(
            b.to_bits(),
            Bf16(*h).to_f32().to_bits(),
            "bf16→f32 mismatch at {h:#06x}"
        );
    }
}

#[test]
fn large_buffer_engages_threads_and_stays_bit_exact() {
    // Above hostkernel::PAR_MIN_ELEMS the slice kernels fan out over
    // threads; the result must not change by a bit.
    let n = mpx::hostkernel::PAR_MIN_ELEMS + 4321;
    let mut rng = Rng::new(9);
    let xs: Vec<f32> = (0..n)
        .map(|_| {
            let log10 = rng.normal_f32(-4.0, 3.0);
            let m = 10f32.powf(log10);
            if rng.below(2) == 0 { m } else { -m }
        })
        .collect();
    assert_f16_batch_matches_scalar(&xs);
    assert_bf16_batch_matches_scalar(&xs);
}

#[test]
fn property_random_downcasts_match_scalar() {
    forall(
        300,
        |r: &mut Rng| {
            (0..64).map(|_| r.normal_f32(0.0, 1e4)).collect::<Vec<f32>>()
        },
        |xs| {
            let mut got16 = vec![0u16; xs.len()];
            let mut gotbf = vec![0u16; xs.len()];
            cast::f32_to_f16_slice(xs, &mut got16);
            cast::f32_to_bf16_slice(xs, &mut gotbf);
            for (x, (a, b)) in xs.iter().zip(got16.iter().zip(&gotbf)) {
                if *a != F16::from_f32(*x).0 {
                    return Err(format!("f16 mismatch at {x}"));
                }
                if *b != Bf16::from_f32(*x).0 {
                    return Err(format!("bf16 mismatch at {x}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quantize_slices_match_scalar_quantize() {
    let mut a = directed_f32s();
    let mut b = a.clone();
    let reference16: Vec<u32> = a
        .iter()
        .map(|x| FloatFormat::F16.quantize(*x).to_bits())
        .collect();
    let referencebf: Vec<u32> = a
        .iter()
        .map(|x| FloatFormat::Bf16.quantize(*x).to_bits())
        .collect();
    cast::quantize_f16_slice(&mut a);
    cast::quantize_bf16_slice(&mut b);
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), reference16[i], "f16 quantize elem {i}");
        assert_eq!(b[i].to_bits(), referencebf[i], "bf16 quantize elem {i}");
    }
}

// ---------------------------------------------------------------------------
// fused gradient scan
// ---------------------------------------------------------------------------

fn assert_stats_bit_eq(got: &TensorStats, want: &TensorStats) {
    assert_eq!(got.count, want.count);
    assert_eq!(got.finite, want.finite);
    assert_eq!(got.min_abs_nonzero.to_bits(), want.min_abs_nonzero.to_bits());
    assert_eq!(got.max_abs.to_bits(), want.max_abs.to_bits());
    assert_eq!(got.mean_abs.to_bits(), want.mean_abs.to_bits());
    assert_eq!(got.zeros, want.zeros);
    assert_eq!(got.infs, want.infs);
    assert_eq!(got.nans, want.nans);
}

#[test]
fn property_fused_scan_matches_double_walk() {
    forall(
        300,
        |r: &mut Rng| {
            let mut xs: Vec<f32> = (0..(1 + r.below(200) as usize))
                .map(|_| {
                    let log10 = r.normal_f32(-6.0, 4.0);
                    let m = 10f32.powf(log10);
                    if r.below(2) == 0 { m } else { -m }
                })
                .collect();
            // sprinkle specials
            for _ in 0..r.below(4) {
                let i = r.below(xs.len() as u64) as usize;
                xs[i] = match r.below(4) {
                    0 => f32::INFINITY,
                    1 => f32::NEG_INFINITY,
                    2 => f32::NAN,
                    _ => 0.0,
                };
            }
            let inv = 2f32.powi(r.below(31) as i32 - 15);
            (xs, inv)
        },
        |(xs, inv)| {
            let mut fused_buf = xs.clone();
            let mut ref_buf = xs.clone();
            let got = scan::fused_unscale_stats(&mut fused_buf, *inv);
            for x in ref_buf.iter_mut() {
                *x *= *inv;
            }
            let want = tensor_stats(&ref_buf);
            for (a, b) in fused_buf.iter().zip(&ref_buf) {
                if a.to_bits() != b.to_bits() && !(a.is_nan() && b.is_nan()) {
                    return Err(format!("buffer diverged: {a} vs {b}"));
                }
            }
            if got != want
                || got.mean_abs.to_bits() != want.mean_abs.to_bits()
            {
                return Err(format!("stats diverged: {got:?} vs {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn fused_scan_multi_tensor_equals_concatenation() {
    let mut rng = Rng::new(4);
    let mut tensors: Vec<Vec<f32>> = (0..5)
        .map(|_| {
            (0..(1 + rng.below(50) as usize))
                .map(|_| rng.normal_f32(0.0, 100.0))
                .collect()
        })
        .collect();
    let mut flat: Vec<f32> = tensors.iter().flatten().copied().collect();
    let got = scan::fused_unscale_stats_tensors(&mut tensors, 0.125);
    for x in flat.iter_mut() {
        *x *= 0.125;
    }
    let want = tensor_stats(&flat);
    assert_stats_bit_eq(&got, &want);
}

// ---------------------------------------------------------------------------
// parallel reduce + all-reduce determinism
// ---------------------------------------------------------------------------

#[test]
fn add_and_scale_bitwise_deterministic_across_thread_counts() {
    let mut rng = Rng::new(21);
    let a: Vec<f32> = (0..100_003).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..100_003).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut want = a.clone();
    reduce::add_assign_threads(&mut want, &b, 1);
    reduce::scale_in_place_threads(&mut want, 0.25, 1);
    for threads in 2..=6 {
        let mut got = a.clone();
        reduce::add_assign_threads(&mut got, &b, threads);
        reduce::scale_in_place_threads(&mut got, 0.25, threads);
        assert!(
            want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
            "thread count {threads} changed bits"
        );
    }
}

#[test]
fn all_reduce_matches_sequential_reference_bitwise() {
    // Big enough that the chunk-parallel path engages on the adds.
    let mut rng = Rng::new(33);
    for n in [2usize, 3, 4, 5, 8] {
        let shards: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|_| {
                vec![
                    (0..300_000)
                        .map(|_| rng.normal_f32(0.0, 1.0))
                        .collect(),
                    (0..17).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ]
            })
            .collect();
        let mut a = shards.clone();
        let mut b = shards.clone();
        all_reduce_mean(&mut a);
        sequential_all_reduce_reference(&mut b);
        for (t, (x, y)) in a[0].iter().zip(b[0].iter()).enumerate() {
            assert!(
                x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits()),
                "n={n} tensor {t} diverged from sequential reference"
            );
        }
    }
}

#[test]
#[should_panic(expected = "no shards")]
fn all_reduce_finite_empty_panics() {
    all_reduce_finite(&[]);
}

// ---------------------------------------------------------------------------
// batch-kernel-backed diagnostics
// ---------------------------------------------------------------------------

#[test]
fn under_overflow_diagnostics_match_quantize_definition() {
    let mut rng = Rng::new(55);
    let mut xs: Vec<f32> = (0..10_000)
        .map(|_| {
            let log10 = rng.normal_f32(-5.0, 4.0);
            let m = 10f32.powf(log10);
            if rng.below(2) == 0 { m } else { -m }
        })
        .collect();
    xs.extend(directed_f32s());
    for fmt in [FloatFormat::F32, FloatFormat::F16, FloatFormat::Bf16] {
        let want_under = xs
            .iter()
            .filter(|&&x| x != 0.0 && fmt.quantize(x) == 0.0)
            .count() as f64
            / xs.len() as f64;
        let want_over = xs
            .iter()
            .filter(|&&x| x.is_finite() && !fmt.quantize(x).is_finite())
            .count();
        assert_eq!(
            underflow_fraction(&xs, fmt),
            want_under,
            "underflow mismatch for {fmt:?}"
        );
        assert_eq!(
            overflow_count(&xs, fmt),
            want_over,
            "overflow mismatch for {fmt:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// buffer pool + pooled pack paths
// ---------------------------------------------------------------------------

#[test]
fn pooled_padded_images_match_allocating_path() {
    use mpx::serve::{FormedBatch, Request};
    use std::time::Duration;
    let reqs: Vec<Request> = (0..3)
        .map(|i| {
            Request::new(
                i,
                vec![i as f32; 8],
                Duration::from_secs(1),
                Duration::ZERO,
            )
        })
        .collect();
    let batch = FormedBatch { requests: reqs, bucket: 8, dispatched: Duration::ZERO };
    let want = batch.padded_images();
    let pool = BufferPool::new();
    let mut buf = pool.take_f32(0);
    batch.padded_images_into(&mut buf);
    assert_eq!(want, buf);
    // Cycle it: second fill must reuse the same capacity.
    pool.put_f32(buf);
    let mut buf = pool.take_f32(0);
    batch.padded_images_into(&mut buf);
    assert_eq!(want, buf);
    assert_eq!(pool.stats().hits, 1);
}

#[test]
fn batch_recycle_feeds_the_next_batch() {
    use mpx::config::VIT_TINY;
    use mpx::data::SyntheticDataset;
    let ds = SyntheticDataset::new(&VIT_TINY, 1);
    let want = ds.batch(0, 4, 42);
    let again = ds.batch(0, 4, 42);
    assert_eq!(want.images, again.images);
    assert_eq!(want.labels, again.labels);
    // Recycling must not perturb determinism of later batches.
    again.recycle();
    let third = ds.batch(0, 4, 42);
    assert_eq!(want.images, third.images);
    assert_eq!(want.labels, third.labels);
    want.recycle();
    third.recycle();
}
