//! Integration tests for the serve network transport — a real client
//! over a real socket against the continuous-batching scheduler, with
//! a stub executor instead of PJRT, so the whole suite runs under
//! `cargo test --no-default-features` on any host.
//!
//! Covered, per scenario, with a zero-leak assertion after each
//! (`pool.busy == 0`, `pending_streams == 0` in the final report):
//!
//! * streamed completions (JSON and binary payloads, suffix and
//!   full-name lane routing), `/healthz`, `/metrics`;
//! * malformed request bodies → `400`;
//! * unknown lane → `404`;
//! * queue-full admission rejection → `429` + `Retry-After` from the
//!   lane's flush timeout;
//! * client disconnect mid-stream → slot freed and counted;
//! * draining server → `503` for new work, and streams stuck past
//!   the drain deadline abandoned with an error chunk;
//! * keep-alive reuse and pipelining on one connection (responses in
//!   request order);
//! * slowloris eviction at the whole-request deadline (`408`) while a
//!   well-behaved idle keep-alive connection survives;
//! * a many-connections soak: thousands of concurrent keep-alive
//!   sockets on a reactor whose thread count never grows with them.

use std::io::{Read, Write};
use std::net::SocketAddr;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use mpx::config::TransportConfig;
use mpx::serve::transport::client::{infer_body_json, Client};
use mpx::serve::transport::{Server, ServerHandle, TransportReport};
use mpx::serve::{BatchExecutor, BatcherConfig, LaneSpec, SchedPolicy};
use mpx::util::json::Json;

const ELEMS: usize = 4;

/// A latch the stub executor blocks on until the test opens it.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn closed() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Stub "model": every logit is the input element times a per-lane
/// scale; optionally gated so tests control exactly when batches
/// complete.
struct StubExecutor {
    scale: f32,
    gate: Option<Arc<Gate>>,
}

impl BatchExecutor for StubExecutor {
    fn execute(&mut self, images: &[f32], _batch: usize) -> Result<Vec<f32>> {
        if let Some(gate) = &self.gate {
            gate.wait();
        }
        Ok(images.iter().map(|v| v * self.scale).collect())
    }
}

fn lane(name: &str, buckets: &[usize], flush_ms: u64, cap: usize) -> LaneSpec {
    LaneSpec {
        name: name.into(),
        weight: 1,
        batcher: BatcherConfig::new(
            buckets.to_vec(),
            Duration::from_millis(flush_ms),
        )
        .unwrap(),
        queue_capacity: cap,
        deadline: Duration::from_secs(5),
    }
}

fn transport_cfg(drain_deadline_ms: u64) -> TransportConfig {
    TransportConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: 64,
        read_timeout_ms: 2_000,
        request_deadline_ms: 10_000,
        idle_timeout_ms: 30_000,
        max_pipelined: 32,
        drain_deadline_ms,
    }
}

struct Running {
    addr: SocketAddr,
    handle: ServerHandle,
    join: JoinHandle<Result<TransportReport>>,
}

impl Running {
    fn client(&self) -> Client {
        Client::new(self.addr.to_string())
            .with_timeout(Duration::from_secs(5))
    }

    fn finish(self) -> TransportReport {
        self.handle.shutdown();
        let report = self
            .join
            .join()
            .expect("server thread panicked")
            .expect("server returned an error");
        // The universal no-leak invariant: every admitted stream was
        // answered or accounted, every worker slot came back.
        assert_eq!(report.pending_streams, 0, "leaked stream registry entries");
        assert_eq!(report.pool.busy, 0, "leaked busy worker slots");
        report
    }
}

/// Bind + run a server over stub executors on an ephemeral port.
fn start(
    lanes: Vec<LaneSpec>,
    workers: usize,
    gate: Option<Arc<Gate>>,
    drain_deadline_ms: u64,
) -> Running {
    start_with_cfg(lanes, workers, gate, transport_cfg(drain_deadline_ms))
}

fn start_with_cfg(
    lanes: Vec<LaneSpec>,
    workers: usize,
    gate: Option<Arc<Gate>>,
    cfg: TransportConfig,
) -> Running {
    let server = Server::bind(&cfg).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || {
        server.run(
            lanes,
            workers,
            SchedPolicy::Continuous,
            ELEMS,
            |_worker, lane| {
                Ok(StubExecutor {
                    scale: (lane + 2) as f32,
                    gate: gate.clone(),
                })
            },
        )
    });
    Running { addr, handle, join }
}

fn image(seed: f32) -> Vec<f32> {
    (0..ELEMS).map(|i| seed + i as f32).collect()
}

/// Poll until `cond` holds or the deadline passes.
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn lane_depth(client: &Client, lane: &str) -> usize {
    let body = client.healthz().unwrap().body_string();
    let doc = Json::parse(body.trim()).unwrap();
    doc.get("lanes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .find(|l| l.get("name").and_then(Json::as_str) == Some(lane))
        .and_then(|l| l.get("depth").and_then(Json::as_i64))
        .unwrap() as usize
}

// ---------------------------------------------------------------------------

#[test]
fn streams_completions_to_real_clients() {
    let srv = start(
        vec![
            lane("vit_tiny/chat", &[1, 2, 4], 5, 64),
            lane("vit_tiny/bulk", &[1, 2, 4], 5, 64),
        ],
        2,
        None,
        2_000,
    );

    // Concurrent JSON clients on the suffix route.
    let addr = srv.addr.to_string();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client = Client::new(addr);
                (0..4)
                    .map(|i| {
                        let img = image((t * 10 + i) as f32);
                        let reply = client.infer("chat", &img).unwrap();
                        assert_eq!(reply.lane, "vit_tiny/chat");
                        assert!(reply.finite);
                        // Lane 0's stub doubles every element.
                        let want: Vec<f32> =
                            img.iter().map(|v| v * 2.0).collect();
                        assert_eq!(reply.logits, want);
                        reply.id
                    })
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    let mut ids: Vec<u64> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 16, "request ids must be unique");

    // Binary payload on the full lane name routes to lane 1 (×3).
    let client = srv.client();
    let img = image(100.0);
    let reply = client.infer_binary("vit_tiny/bulk", &img).unwrap();
    assert_eq!(reply.lane, "vit_tiny/bulk");
    let want: Vec<f32> = img.iter().map(|v| v * 3.0).collect();
    assert_eq!(reply.logits, want);

    // healthz + Prometheus metrics reflect the run.
    let health = client.healthz().unwrap();
    assert_eq!(health.status, 200);
    let doc = Json::parse(health.body_string().trim()).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    let metrics = client.metrics().unwrap();
    assert!(
        metrics.contains("mpx_serve_completed_total{lane=\"vit_tiny/chat\"} 16"),
        "metrics page should count the 16 chat completions:\n{metrics}"
    );
    assert!(metrics
        .contains("mpx_serve_completed_total{lane=\"vit_tiny/bulk\"} 1"));
    assert!(metrics.contains("mpx_serve_latency_seconds_count"));
    assert!(metrics.contains("mpx_serve_nonfinite_total"));
    assert!(metrics.contains("mpx_transport_admitted_total 17"));

    let report = srv.finish();
    assert_eq!(report.counters.admitted, 17);
    assert_eq!(report.counters.streamed, 17);
    assert_eq!(report.counters.disconnects, 0);
    assert_eq!(report.counters.malformed, 0);
    assert_eq!(report.lanes[0].completed, 16);
    assert_eq!(report.lanes[1].completed, 1);
    assert_eq!(report.lanes[0].nonfinite, 0);
}

#[test]
fn malformed_bodies_are_rejected_with_400() {
    let srv = start(vec![lane("vit_tiny/chat", &[1, 2], 5, 16)], 1, None, 1_000);
    let client = srv.client();

    let cases: Vec<(&str, &str, Vec<u8>)> = vec![
        ("not json at all", "application/json", b"hello".to_vec()),
        ("missing lane", "application/json", b"{\"image\":[1,2,3,4]}".to_vec()),
        (
            "missing image",
            "application/json",
            b"{\"lane\":\"chat\"}".to_vec(),
        ),
        (
            "non-numeric image",
            "application/json",
            b"{\"lane\":\"chat\",\"image\":[1,\"x\",3,4]}".to_vec(),
        ),
        (
            "wrong element count",
            "application/json",
            b"{\"lane\":\"chat\",\"image\":[1,2,3]}".to_vec(),
        ),
        (
            "binary length not a multiple of 4",
            "application/octet-stream",
            vec![0u8; 7],
        ),
        ("binary without a lane", "application/octet-stream", vec![0u8; 16]),
    ];
    let n = cases.len() as u64;
    for (what, content_type, body) in cases {
        let extra: &[(&str, &str)] =
            if what == "binary length not a multiple of 4" {
                &[("X-Mpx-Lane", "chat")]
            } else {
                &[]
            };
        let resp = client
            .request("POST", "/v1/infer", content_type, extra, &body)
            .unwrap();
        assert_eq!(resp.status, 400, "{what}: {}", resp.body_string());
        assert!(resp.body_string().contains("error"), "{what}");
    }

    // Unknown endpoints 404 without counting as malformed.
    let resp = client
        .request("GET", "/nope", "text/plain", &[], &[])
        .unwrap();
    assert_eq!(resp.status, 404);

    let report = srv.finish();
    assert_eq!(report.counters.malformed, n);
    assert_eq!(report.counters.admitted, 0);
}

#[test]
fn unknown_lane_is_404_naming_the_known_lanes() {
    let srv = start(vec![lane("vit_tiny/chat", &[1, 2], 5, 16)], 1, None, 1_000);
    let client = srv.client();
    let body = mpx::serve::transport::client::infer_body_json(
        "nope",
        &image(0.0),
    );
    let resp = client
        .request("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 404);
    let text = resp.body_string();
    assert!(text.contains("nope"), "{text}");
    assert!(text.contains("vit_tiny/chat"), "{text}");

    let report = srv.finish();
    assert_eq!(report.counters.unknown_lane, 1);
    assert_eq!(report.counters.admitted, 0);
}

#[test]
fn queue_full_is_429_with_retry_after_from_the_flush_timeout() {
    // One worker, gate held: the first request occupies the slot, the
    // next two fill the capacity-2 queue, the fourth must bounce.
    let gate = Gate::closed();
    let srv = start(
        vec![lane("vit_tiny/chat", &[1], 300, 2)],
        1,
        Some(gate.clone()),
        2_000,
    );
    let client = srv.client();
    let body = mpx::serve::transport::client::infer_body_json(
        "chat",
        &image(1.0),
    );

    // First request: admitted and dispatched (depth back to 0).
    let s1 = client
        .open("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(s1.status, 200);
    let probe = srv.client();
    wait_for("the first request to be dispatched", || {
        lane_depth(&probe, "vit_tiny/chat") == 0
    });

    // Two more fill the queue while the worker is gated.
    let s2 = client
        .open("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(s2.status, 200);
    let s3 = client
        .open("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(s3.status, 200);
    wait_for("the queue to fill", || {
        lane_depth(&probe, "vit_tiny/chat") == 2
    });

    // Fourth: 429, Retry-After = ceil(flush timeout) clamped to ≥ 1s.
    let resp = client
        .request("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 429, "{}", resp.body_string());
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert!(resp.body_string().contains("queue is full"));

    // Release the gate: all three admitted streams complete.
    gate.release();
    for mut s in [s1, s2, s3] {
        let mut saw_result = false;
        while let Some(chunk) = s.next_chunk().unwrap() {
            if String::from_utf8_lossy(&chunk).contains("logits") {
                saw_result = true;
            }
        }
        assert!(saw_result, "admitted stream must deliver its result");
    }

    let report = srv.finish();
    assert_eq!(report.counters.admitted, 3);
    assert_eq!(report.counters.streamed, 3);
    assert_eq!(report.counters.rejected_full, 1);
    assert_eq!(report.lanes[0].queue.rejected, 1);
}

#[test]
fn client_disconnect_mid_stream_frees_and_counts_the_slot() {
    let gate = Gate::closed();
    let srv = start(
        vec![lane("vit_tiny/chat", &[1], 5, 16)],
        1,
        Some(gate.clone()),
        2_000,
    );
    let client = srv.client();
    let body = mpx::serve::transport::client::infer_body_json(
        "chat",
        &image(3.0),
    );

    // Admit a request, confirm the stream is live, then vanish.
    {
        let mut s = client
            .open(
                "POST",
                "/v1/infer",
                "application/json",
                &[],
                body.as_bytes(),
            )
            .unwrap();
        assert_eq!(s.status, 200);
        let ack = s.next_chunk().unwrap().unwrap();
        assert!(String::from_utf8_lossy(&ack).contains("queued"));
        // Dropped here: the TCP connection closes mid-stream.
    }
    assert_eq!(srv.handle.pending_streams(), 1, "stream registered");

    // Let the batch complete against a dead client.
    gate.release();
    wait_for("the disconnect to be detected", || {
        srv.handle.counters().disconnects == 1
    });

    // The slot is free: a healthy request goes straight through.
    let reply = client.infer("chat", &image(5.0)).unwrap();
    assert_eq!(reply.logits, image(5.0).iter().map(|v| v * 2.0).collect::<Vec<_>>());

    let report = srv.finish();
    assert_eq!(report.counters.admitted, 2);
    assert_eq!(report.counters.disconnects, 1);
    // Both completions were executed and accounted by the engine,
    // only one reached a live client.
    assert_eq!(report.lanes[0].completed, 2);
    assert_eq!(report.counters.streamed, 1);
}

#[test]
fn draining_rejects_new_requests_with_503() {
    let gate = Gate::closed();
    let srv = start(
        vec![lane("vit_tiny/chat", &[1], 5, 16)],
        1,
        Some(gate.clone()),
        5_000,
    );
    let client = srv.client();
    let body = mpx::serve::transport::client::infer_body_json(
        "chat",
        &image(7.0),
    );

    // One admitted stream keeps the server draining (not exited).
    let mut pending = client
        .open("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(pending.status, 200);
    let _ack = pending.next_chunk().unwrap().unwrap();

    srv.handle.shutdown();
    wait_for("drain mode", || srv.handle.is_draining());

    // New work is turned away with an orderly 503 + Retry-After…
    let resp = client
        .request("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 503, "{}", resp.body_string());
    assert!(resp.header("retry-after").is_some());
    assert!(resp.body_string().contains("draining"));

    // …while /healthz still answers and reports the drain.
    let health = client.healthz().unwrap();
    assert_eq!(health.status, 200);
    assert!(health.body_string().contains("draining"));

    // The pending stream still gets its result before exit.
    gate.release();
    let mut saw_result = false;
    while let Some(chunk) = pending.next_chunk().unwrap() {
        if String::from_utf8_lossy(&chunk).contains("logits") {
            saw_result = true;
        }
    }
    assert!(saw_result, "in-flight stream must flush during the drain");

    let report = srv.finish();
    assert_eq!(report.counters.rejected_draining, 1);
    assert_eq!(report.counters.streamed, 1);
    assert_eq!(report.counters.drain_abandoned, 0);
}

#[test]
fn drain_deadline_abandons_stuck_streams_with_an_error() {
    // Tiny drain budget, gate never released until the end: the
    // pending stream must be abandoned with an in-stream error chunk
    // rather than leaking or hanging the shutdown.
    let gate = Gate::closed();
    let srv = start(
        vec![lane("vit_tiny/chat", &[1], 5, 16)],
        1,
        Some(gate.clone()),
        250,
    );
    let client = srv.client();
    let body = mpx::serve::transport::client::infer_body_json(
        "chat",
        &image(9.0),
    );
    let mut pending = client
        .open("POST", "/v1/infer", "application/json", &[], body.as_bytes())
        .unwrap();
    assert_eq!(pending.status, 200);
    let _ack = pending.next_chunk().unwrap().unwrap();

    srv.handle.shutdown();
    // The stream ends with an error chunk once the deadline passes.
    let mut error_line = String::new();
    while let Some(chunk) = pending.next_chunk().unwrap() {
        error_line = String::from_utf8_lossy(&chunk).into_owned();
    }
    assert!(
        error_line.contains("drain deadline"),
        "expected a drain-deadline error chunk, got {error_line:?}"
    );

    // Unblock the worker so the pool can exit; its late completion
    // finds no registered stream (the handler deregistered).
    gate.release();
    let report = srv.finish();
    assert_eq!(report.counters.drain_abandoned, 1);
    assert_eq!(report.counters.streamed, 0);
    assert_eq!(report.lanes[0].completed, 1);
}

#[test]
fn keep_alive_connection_serves_many_requests() {
    let srv = start(
        vec![lane("vit_tiny/chat", &[1, 2, 4], 5, 64)],
        1,
        None,
        2_000,
    );
    let client = srv.client();
    let mut conn = client.connect_keep_alive().unwrap();
    for i in 0..5 {
        let img = image(i as f32);
        let reply = conn.infer("chat", &img).unwrap();
        let want: Vec<f32> = img.iter().map(|v| v * 2.0).collect();
        assert_eq!(reply.logits, want, "request {i} on the reused socket");
    }

    // The sixth request on the same socket scrapes /metrics: the page
    // must count this very connection's reuse (the scrape included).
    let resp = conn.request("GET", "/metrics", "text/plain", &[], &[]);
    let resp = resp.unwrap();
    assert_eq!(resp.status, 200);
    let metrics = resp.body_string();
    assert!(
        metrics.contains("mpx_transport_connections_total 1"),
        "one connection served everything:\n{metrics}"
    );
    assert!(
        metrics.contains("mpx_transport_keepalive_reuses_total 5"),
        "{metrics}"
    );
    assert!(metrics.contains("mpx_transport_connections_open 1"), "{metrics}");
    assert!(metrics.contains("mpx_transport_requests_total 6"), "{metrics}");
    assert!(
        metrics.contains("mpx_transport_keepalive_requests_per_connection 6"),
        "{metrics}"
    );
    drop(conn);

    let report = srv.finish();
    assert_eq!(report.counters.connections, 1);
    assert_eq!(report.counters.requests, 6);
    assert_eq!(report.counters.keepalive_reuses, 5);
    assert_eq!(report.counters.admitted, 5);
    assert_eq!(report.counters.streamed, 5);
    assert_eq!(report.counters.disconnects, 0);
}

#[test]
fn pipelined_requests_are_answered_in_request_order() {
    let srv = start(
        vec![lane("vit_tiny/chat", &[1, 2, 4, 8], 5, 64)],
        2,
        None,
        2_000,
    );
    let client = srv.client();
    let mut conn = client.connect_keep_alive().unwrap();

    // Six requests on the wire before the first response is read.
    let n = 6usize;
    for i in 0..n {
        let body = infer_body_json("chat", &image(i as f32 * 10.0));
        let raw = body.as_bytes();
        conn.send("POST", "/v1/infer", "application/json", &[], raw).unwrap();
    }
    for i in 0..n {
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200, "pipelined response {i}");
        // The result line must carry *this* request's logits: strict
        // request-order delivery.
        let want: Vec<f32> =
            image(i as f32 * 10.0).iter().map(|v| v * 2.0).collect();
        let body = resp.body_string();
        let logits: Vec<f32> = body
            .lines()
            .filter(|l| !l.trim().is_empty())
            .filter_map(|l| Json::parse(l.trim()).ok())
            .find_map(|doc| {
                doc.get("logits").and_then(Json::as_arr).map(|arr| {
                    arr.iter()
                        .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                        .collect()
                })
            })
            .unwrap_or_default();
        assert_eq!(logits, want, "response {i} out of order:\n{body}");
    }
    drop(conn);

    let report = srv.finish();
    assert_eq!(report.counters.connections, 1);
    assert_eq!(report.counters.admitted, n as u64);
    assert_eq!(report.counters.streamed, n as u64);
    assert_eq!(report.counters.keepalive_reuses, n as u64 - 1);
    assert_eq!(report.counters.disconnects, 0);
}

#[test]
fn slowloris_is_evicted_with_408_while_idle_keepalive_survives() {
    let mut cfg = transport_cfg(2_000);
    // Each drip lands well inside the inter-byte budget; only the
    // whole-request deadline can evict.
    cfg.read_timeout_ms = 10_000;
    cfg.request_deadline_ms = 400;
    let srv = start_with_cfg(
        vec![lane("vit_tiny/chat", &[1, 2], 5, 16)],
        1,
        None,
        cfg,
    );
    let client = srv.client();

    // A well-behaved keep-alive connection that will sit idle (within
    // its own, much larger, idle budget) while the trickler is dealt
    // with.
    let mut good = client.connect_keep_alive().unwrap();
    let reply = good.infer("chat", &image(1.0)).unwrap();
    assert!(reply.finite);

    // The trickler: drip header bytes, never completing the request,
    // then stop and wait — no writes after the eviction so the 408
    // cannot be lost to a reset.
    let mut slow = std::net::TcpStream::connect(srv.addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let partial: &[u8] = b"POST /v1/infer HTTP/1.1\r\nHost: x\r\n";
    for chunk in partial.chunks(6) {
        slow.write_all(chunk).unwrap();
        slow.flush().unwrap();
        std::thread::sleep(Duration::from_millis(60));
    }
    let mut buf = Vec::new();
    let mut tmp = [0u8; 1024];
    loop {
        match slow.read(&mut tmp) {
            Ok(0) => break,
            Ok(k) => buf.extend_from_slice(&tmp[..k]),
            Err(_) => break,
        }
    }
    let text = String::from_utf8_lossy(&buf);
    assert!(text.contains("408"), "expected a 408 eviction, got {text:?}");
    assert!(text.contains("request deadline exceeded"), "{text:?}");
    wait_for("the eviction counter", || {
        srv.handle.counters().deadline_evictions == 1
    });

    // The idle keep-alive connection was untouched and still serves.
    let reply = good.infer("chat", &image(2.0)).unwrap();
    let want: Vec<f32> = image(2.0).iter().map(|v| v * 2.0).collect();
    assert_eq!(reply.logits, want);
    drop(good);

    let report = srv.finish();
    assert_eq!(report.counters.deadline_evictions, 1);
    assert_eq!(report.counters.admitted, 2);
    assert_eq!(report.counters.streamed, 2);
    assert_eq!(report.counters.disconnects, 0);
}

#[cfg(target_os = "linux")]
fn process_thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line in /proc/self/status")
        .trim()
        .parse()
        .unwrap()
}

#[test]
fn soak_thousands_of_keepalive_connections_one_reactor_thread() {
    const TARGET: usize = 2_048;
    const OPENERS: usize = 8;
    const REUSE_PER_OPENER: usize = 16;

    // Client + server halves both live in this process: make sure the
    // descriptor budget covers ~2 per connection, or skip with a note.
    let need = (TARGET * 2 + 512) as u64;
    match mpx::serve::transport::reactor::raise_nofile_limit(need) {
        Ok(limit) if limit >= need => {}
        Ok(limit) => {
            eprintln!(
                "soak skipped: nofile limit {limit} < {need} \
                 (hard limit too low on this host)"
            );
            return;
        }
        Err(e) => {
            eprintln!("soak skipped: rlimit unavailable: {e}");
            return;
        }
    }

    let mut cfg = transport_cfg(5_000);
    cfg.max_connections = TARGET * 2;
    let srv = start_with_cfg(
        vec![lane("vit_tiny/chat", &[1, 2, 4, 8, 16], 2, 4_096)],
        2,
        None,
        cfg,
    );

    let per = TARGET / OPENERS;
    let barrier = Arc::new(std::sync::Barrier::new(OPENERS + 1));
    let addr = srv.addr.to_string();
    let handles: Vec<_> = (0..OPENERS)
        .map(|t| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let timeout = Duration::from_secs(30);
                let client = Client::new(addr).with_timeout(timeout);
                let mut conns = Vec::with_capacity(per);
                for i in 0..per {
                    let mut conn = client.connect_keep_alive().unwrap();
                    let reply = conn
                        .infer("chat", &image((t * per + i) as f32))
                        .unwrap();
                    assert_eq!(reply.logits.len(), ELEMS);
                    conns.push(conn);
                }
                barrier.wait(); // every connection is open
                barrier.wait(); // main thread sampled the reactor
                for conn in conns.iter_mut().take(REUSE_PER_OPENER) {
                    let reply = conn.infer("chat", &image(7.0)).unwrap();
                    assert!(reply.finite);
                }
                drop(conns);
            })
        })
        .collect();

    barrier.wait();
    let open = srv.handle.open_connections();
    assert!(
        open >= TARGET,
        "expected ≥{TARGET} concurrent keep-alive connections, \
         the reactor owns {open}"
    );
    // Thread-per-connection would need ≥ `open` threads right now;
    // the reactor needs one.  Bound well below `open` but loosely
    // enough for whatever else libtest is running in this process.
    #[cfg(target_os = "linux")]
    {
        let threads = process_thread_count();
        assert!(
            threads < open / 8,
            "thread count {threads} must not scale with {open} \
             connections (reactor + workers + test threads only)"
        );
    }
    barrier.wait();
    for h in handles {
        h.join().unwrap();
    }
    wait_for("every connection to close", || {
        srv.handle.open_connections() == 0
    });

    let report = srv.finish();
    let reused = (OPENERS * REUSE_PER_OPENER) as u64;
    assert_eq!(report.counters.connections, TARGET as u64);
    assert_eq!(report.counters.admitted, TARGET as u64 + reused);
    assert_eq!(report.counters.streamed, TARGET as u64 + reused);
    assert_eq!(report.counters.keepalive_reuses, reused);
    assert_eq!(report.counters.disconnects, 0);
    assert_eq!(report.counters.deadline_evictions, 0);
}
