//! Cross-checks between the analytic memory model, the AOT manifests
//! and the HLO census — the Fig. 2 credibility tests.
//!
//! Everything here reads manifest/HLO *files* only (no PJRT client),
//! so the suite runs under `--no-default-features` too — it still
//! skips gracefully when `make artifacts` has not produced the files.

use mpx::config::{Precision, VIT_BASE, VIT_DESKTOP, VIT_TINY};
use mpx::hlo::HloModule;
use mpx::memmodel::ActivationModel;
use mpx::pytree::Which;

mod common;
use common::manifests as store;

#[test]
fn analytic_param_count_matches_manifests_exactly() {
    let Some(store) = store() else { return };
    for (preset, name) in [
        (VIT_TINY, "init_vit_tiny_fp32"),
        (VIT_DESKTOP, "init_vit_desktop_fp32"),
        (VIT_BASE, "init_vit_base_fp32"),
    ] {
        let m = store.manifest(name).unwrap();
        let manifest_params: u64 = m
            .outputs
            .iter()
            .filter(|l| l.group == "params" && l.dtype.is_float())
            .map(|l| l.elems() as u64)
            .sum();
        let analytic = ActivationModel::new(preset).param_count();
        assert_eq!(
            analytic, manifest_params,
            "{name}: analytic {analytic} vs manifest {manifest_params}"
        );
    }
}

#[test]
fn optimizer_state_is_twice_params() {
    // Adam: mu + nu (float leaves) + a scalar count.
    let Some(store) = store() else { return };
    let m = store.manifest("init_vit_desktop_fp32").unwrap();
    let params: u64 = m
        .outputs
        .iter()
        .filter(|l| l.group == "params" && l.dtype.is_float())
        .map(|l| l.elems() as u64)
        .sum();
    let opt_float: u64 = m
        .outputs
        .iter()
        .filter(|l| l.group == "opt_state" && l.dtype.is_float())
        .map(|l| l.elems() as u64)
        .sum();
    assert_eq!(opt_float, 2 * params);
}

#[test]
fn census_mixed_vs_full_ratio_matches_model_direction() {
    // The HLO census and the analytic model must agree on the SIGN
    // and rough size of the effect: mixed workspace < full workspace,
    // with the ratio growing toward 2 as batch grows.
    let Some(store) = store() else { return };
    let mut prev_ratio = 0.0f64;
    for b in [8usize, 32, 128] {
        let f = HloModule::parse(
            &store
                .hlo_text(&format!("step_fused_vit_desktop_fp32_b{b}"))
                .unwrap(),
        )
        .unwrap();
        let m = HloModule::parse(
            &store
                .hlo_text(&format!("step_fused_vit_desktop_mixed_f16_b{b}"))
                .unwrap(),
        )
        .unwrap();
        let fw: u64 = f.workspace_bytes_by_dtype().values().sum();
        let mw: u64 = m.workspace_bytes_by_dtype().values().sum();
        let ratio = fw as f64 / mw as f64;
        assert!(ratio > 1.15, "batch {b}: census ratio only {ratio}");
        assert!(
            ratio >= prev_ratio * 0.95,
            "ratio should not collapse with batch: {prev_ratio} → {ratio}"
        );
        prev_ratio = ratio;
    }
}

#[test]
fn mixed_artifact_moves_half_precision_activations() {
    // The mixed step's HLO must actually contain a large f16 workspace
    // (if casting silently failed everything would still be f32).
    let Some(store) = store() else { return };
    let m = HloModule::parse(
        &store
            .hlo_text("step_fused_vit_desktop_mixed_f16_b64")
            .unwrap(),
    )
    .unwrap();
    let by = m.workspace_bytes_by_dtype();
    let f16 = *by.get("f16").unwrap_or(&0);
    let f32_ = *by.get("f32").unwrap_or(&0);
    assert!(f16 > 100 << 20, "f16 workspace suspiciously small: {f16}");
    // fp32 remains for masters/opt/grads + force_full_precision islands
    assert!(f32_ > 0);

    let full = HloModule::parse(
        &store.hlo_text("step_fused_vit_desktop_fp32_b64").unwrap(),
    )
    .unwrap();
    assert_eq!(
        *full.workspace_bytes_by_dtype().get("f16").unwrap_or(&0),
        0,
        "fp32 artifact must contain no f16 buffers"
    );
}

#[test]
fn manifest_batch_scaling_only_in_batch_groups() {
    // Between b8 and b64 artifacts, only images/labels input bytes
    // change — state is batch-independent (the Fig. 2 constant term).
    let Some(store) = store() else { return };
    let a = store.manifest("step_fused_vit_desktop_mixed_f16_b8").unwrap();
    let b = store
        .manifest("step_fused_vit_desktop_mixed_f16_b64")
        .unwrap();
    let ba = a.bytes_by_group(Which::Inputs);
    let bb = b.bytes_by_group(Which::Inputs);
    assert_eq!(ba["params"], bb["params"]);
    assert_eq!(ba["opt_state"], bb["opt_state"]);
    assert_eq!(ba["scaling"], bb["scaling"]);
    assert_eq!(bb["images"], 8 * ba["images"]);
}

#[test]
fn estimate_dominated_by_activations_at_large_batch() {
    let am = ActivationModel::new(VIT_DESKTOP);
    let e = am.estimate(Precision::Fp32, 256);
    assert!(e.activation_bytes() > 3 * e.state_bytes());
}
