//! Serving-subsystem tests that run without AOT artifacts: a fake
//! executor stands in for PJRT, so queueing, continuous batching,
//! multi-lane scheduling, padding accounting, streamed completions,
//! and latency aggregation are exercised on any machine.  The
//! artifact-backed path is covered by `mpx serve` and the runtime
//! integration suite; timing-exact policy behaviour is proven in
//! `serve_sim.rs` on the virtual clock.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use mpx::config::ServeConfig;
use mpx::serve::{
    self, simulate, AutoscalePolicy, BatchExecutor, BatcherConfig,
    EngineOpts, LaneLoad, LaneSpec, LaneTraffic, Request, SchedPolicy,
    Scheduler, SimSpec, VirtualClock, WallClock,
};
use mpx::util::proptest::forall;

const IMG_ELEMS: usize = 4;

/// Stand-in executor: checks shapes, optionally sleeps, logs buckets.
struct FakeExecutor {
    delay: Duration,
    calls: Arc<Mutex<Vec<usize>>>,
}

impl BatchExecutor for FakeExecutor {
    fn execute(&mut self, images: &[f32], batch: usize) -> anyhow::Result<Vec<f32>> {
        assert_eq!(
            images.len(),
            batch * IMG_ELEMS,
            "executor got a non-padded or mis-shaped batch"
        );
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.calls.lock().unwrap().push(batch);
        Ok(vec![0.0; batch])
    }
}

fn fake_factory(
    delay: Duration,
) -> (Arc<Mutex<Vec<usize>>>, impl Fn(usize) -> anyhow::Result<FakeExecutor> + Sync)
{
    let calls = Arc::new(Mutex::new(Vec::new()));
    let calls2 = calls.clone();
    let factory = move |_worker: usize| {
        Ok(FakeExecutor { delay, calls: calls2.clone() })
    };
    (calls, factory)
}

fn image(i: u64) -> Vec<f32> {
    vec![i as f32; IMG_ELEMS]
}

fn base_cfg() -> ServeConfig {
    ServeConfig {
        requests: 5,
        workers: 1,
        queue_capacity: 64,
        flush_timeout_ms: 1000,
        deadline_ms: 10_000,
        arrival_rate: 0.0,
        open_loop: false,
        ..ServeConfig::default()
    }
}

#[test]
fn padded_batch_requests_counted_once() {
    // 5 requests into a bucket-8 artifact: one batch, 3 padding rows,
    // and exactly 5 latency samples — padding must not double-count.
    let cfg = base_cfg();
    let (calls, factory) = fake_factory(Duration::ZERO);
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();

    assert_eq!(report.completed(), 5);
    assert_eq!(report.latency.count(), 5, "padded rows leaked into stats");
    assert_eq!(report.batches(), 1);
    assert_eq!(report.padded(), 3);
    assert_eq!(*calls.lock().unwrap(), vec![8]);
    assert_eq!(report.queue.accepted, 5);
    assert_eq!(report.queue.rejected, 0);
    assert!((report.padding_fraction() - 3.0 / 8.0).abs() < 1e-12);
    // The single lane's report mirrors the aggregate.
    assert_eq!(report.lanes.len(), 1);
    assert_eq!(report.lanes[0].completed(), 5);
    assert_eq!(report.lanes[0].padded, 3);
}

#[test]
fn size_buckets_avoid_padding_on_close_drain() {
    // Form-first keeps the whole backlog to close time, so 4 requests
    // round to exactly bucket 4 — deterministic, unlike continuous
    // mode where a fast worker may split the burst into exact fits.
    let mut cfg = base_cfg();
    cfg.requests = 4;
    cfg.policy = SchedPolicy::FormFirst;
    let (calls, factory) = fake_factory(Duration::ZERO);
    let report = serve::run(&cfg, vec![1, 2, 4, 8], factory, image).unwrap();
    assert_eq!(report.completed(), 4);
    assert_eq!(report.padded(), 0);
    assert_eq!(*calls.lock().unwrap(), vec![4]);
}

#[test]
fn continuous_mode_loses_nothing_on_bursts() {
    // Same burst under continuous batching: the batch split depends
    // on worker/producer interleaving, but conservation does not.
    let mut cfg = base_cfg();
    cfg.requests = 23;
    cfg.workers = 2;
    let (calls, factory) = fake_factory(Duration::ZERO);
    let report = serve::run(&cfg, vec![1, 2, 4, 8], factory, image).unwrap();
    assert_eq!(report.completed(), 23);
    assert_eq!(report.queue.rejected, 0);
    let total_rows: usize = calls.lock().unwrap().iter().sum();
    assert_eq!(total_rows as u64, report.completed() + report.padded());
}

#[test]
fn per_worker_histograms_merge_into_run_aggregate() {
    let mut cfg = base_cfg();
    cfg.requests = 40;
    cfg.workers = 2;
    cfg.flush_timeout_ms = 1;
    let (_calls, factory) = fake_factory(Duration::from_millis(1));
    let report = serve::run(&cfg, vec![1, 2, 4, 8], factory, image).unwrap();

    assert_eq!(report.completed(), 40);
    let per_worker: usize =
        report.workers.iter().map(|w| w.latency().count()).sum();
    assert_eq!(report.latency.count(), per_worker);
    let s = report.latency.summary().unwrap();
    assert_eq!(s.count, 40);
    assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    // every latency is at least the executor delay
    assert!(s.p50 >= Duration::from_millis(1));
    // per-lane histogram set mirrors the merged counts
    let lanes = report.lane_histograms();
    assert_eq!(lanes.len(), 1);
    assert_eq!(lanes.merged().count(), 40);
}

#[test]
fn open_loop_admission_control_rejects_when_full() {
    // A burst of 40 instant arrivals against capacity 8 and a slow
    // single worker: the bound must hold and the excess be rejected.
    let mut cfg = base_cfg();
    cfg.requests = 40;
    cfg.queue_capacity = 8;
    cfg.open_loop = true;
    cfg.flush_timeout_ms = 50;
    let (_calls, factory) = fake_factory(Duration::from_millis(20));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();

    assert_eq!(report.queue.accepted + report.queue.rejected, 40);
    assert!(report.queue.rejected > 0, "admission control never engaged");
    assert_eq!(report.completed(), report.queue.accepted);
    assert!(report.queue.peak_depth <= 8);
}

#[test]
fn closed_loop_backpressure_never_drops() {
    let mut cfg = base_cfg();
    cfg.requests = 30;
    cfg.queue_capacity = 8;
    cfg.flush_timeout_ms = 2;
    let (_calls, factory) = fake_factory(Duration::from_millis(2));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();
    assert_eq!(report.queue.rejected, 0);
    assert_eq!(report.completed(), 30);
}

#[test]
fn deadline_misses_are_reported() {
    let mut cfg = base_cfg();
    cfg.requests = 10;
    cfg.deadline_ms = 0; // everything misses
    let (_calls, factory) = fake_factory(Duration::from_millis(2));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();
    assert_eq!(report.deadline_misses(), report.completed());
    assert_eq!(report.lanes[0].deadline_misses, report.completed());
}

#[test]
fn worker_factory_failure_propagates_without_hanging() {
    let cfg = base_cfg();
    let factory = |_worker: usize| -> anyhow::Result<FakeExecutor> {
        anyhow::bail!("executor construction failed")
    };
    let res = serve::run(&cfg, vec![8], factory, image);
    assert!(res.is_err());
}

#[test]
fn submit_after_close_is_rejected_and_counted() {
    let clock = Arc::new(VirtualClock::new());
    let sched = Scheduler::new(
        vec![LaneSpec {
            name: "a".into(),
            weight: 1,
            batcher: BatcherConfig::new(vec![8], Duration::from_millis(5))
                .unwrap(),
            queue_capacity: 8,
            deadline: Duration::from_secs(1),
        }],
        SchedPolicy::Continuous,
        AutoscalePolicy::fixed(1),
        clock,
        None,
    )
    .unwrap();
    assert!(sched.submit(
        0,
        Request::new(0, image(0), Duration::from_secs(1), Duration::ZERO)
    ));
    sched.close_all();
    assert!(!sched.submit(
        0,
        Request::new(1, image(1), Duration::from_secs(1), Duration::ZERO)
    ));
    assert!(!sched.submit_blocking(
        0,
        Request::new(2, image(2), Duration::from_secs(1), Duration::ZERO)
    ));
    let s = sched.lane_stats(0);
    assert_eq!(s.accepted, 1);
    assert_eq!(s.rejected, 2);
    assert_eq!(s.rejected_closed, 2);
}

#[test]
fn zero_capacity_lane_rejects_everything_through_the_scheduler() {
    let clock = Arc::new(VirtualClock::new());
    let sched = Scheduler::new(
        vec![LaneSpec {
            name: "disabled".into(),
            weight: 1,
            batcher: BatcherConfig::new(vec![4], Duration::from_millis(5))
                .unwrap(),
            queue_capacity: 0,
            deadline: Duration::from_secs(1),
        }],
        SchedPolicy::Continuous,
        AutoscalePolicy::fixed(1),
        clock,
        None,
    )
    .unwrap();
    // Both admission paths refuse immediately — no deadlock.
    assert!(!sched.submit(
        0,
        Request::new(0, image(0), Duration::from_secs(1), Duration::ZERO)
    ));
    assert!(!sched.submit_blocking(
        0,
        Request::new(1, image(1), Duration::from_secs(1), Duration::ZERO)
    ));
    let s = sched.lane_stats(0);
    assert_eq!(s.accepted, 0);
    assert_eq!(s.rejected, 2);
    assert_eq!(s.rejected_closed, 0);
}

#[test]
fn streamed_completions_fire_exactly_once_per_admitted_request() {
    // Two weighted lanes, two workers, closed loop (nothing is
    // rejected): the completion callback must fire exactly once per
    // (lane, id) — no request lost, none duplicated, padding never
    // surfaces as a completion.
    let counts: Arc<Mutex<HashMap<(usize, u64), u32>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let counts_cb = counts.clone();
    let lane = |name: &str, weight: u64| LaneTraffic {
        spec: LaneSpec {
            name: name.into(),
            weight,
            batcher: BatcherConfig::new(
                vec![1, 2, 4, 8],
                Duration::from_millis(2),
            )
            .unwrap(),
            queue_capacity: 64,
            deadline: Duration::from_secs(10),
        },
        requests: 60,
        arrival_rate: 0.0,
    };
    let (_calls, factory) = fake_factory(Duration::from_micros(200));
    let report = serve::run_lanes(
        &EngineOpts {
            policy: SchedPolicy::Continuous,
            autoscale: AutoscalePolicy::fixed(2),
            open_loop: false,
            seed: 3,
            trace: mpx::trace::TraceConfig::default(),
        },
        vec![lane("a", 2), lane("b", 1)],
        Arc::new(WallClock::new()),
        |w, _lane| factory(w),
        |_lane, i| image(i),
        Some(Box::new(move |c| {
            *counts_cb
                .lock()
                .unwrap()
                .entry((c.lane, c.request.id))
                .or_insert(0) += 1;
        })),
    )
    .unwrap();

    assert_eq!(report.completed(), 120);
    assert_eq!(report.queue.rejected, 0);
    let counts = counts.lock().unwrap();
    assert_eq!(counts.len(), 120, "some completion never streamed");
    for (&(lane, id), &n) in counts.iter() {
        assert_eq!(n, 1, "request (lane {lane}, id {id}) streamed {n} times");
    }
    for lane in 0..2 {
        for id in 0..60u64 {
            assert!(counts.contains_key(&(lane, id)));
        }
    }
    // Per-lane reports carry the same totals.
    assert_eq!(report.lanes[0].completed(), 60);
    assert_eq!(report.lanes[1].completed(), 60);
}

// ---------------------------------------------------------------------------
// Property tests (mini-proptest): batcher + scheduler invariants
// ---------------------------------------------------------------------------

/// Random strictly-ascending bucket set from a selector mask; always
/// contains at least one bucket.
fn buckets_from_mask(mask: u64) -> Vec<usize> {
    let mut buckets: Vec<usize> = [1usize, 2, 4, 8, 16]
        .iter()
        .enumerate()
        .filter(|(i, _)| mask & (1 << i) != 0)
        .map(|(_, &b)| b)
        .collect();
    if buckets.is_empty() {
        buckets.push(8);
    }
    buckets
}

#[test]
fn prop_bucket_for_is_monotone_and_sound() {
    forall(
        300,
        |r| (r.below(32), r.below(40)),
        |&(mask, probe)| {
            let cfg = BatcherConfig::new(
                buckets_from_mask(mask),
                Duration::from_millis(1),
            )
            .unwrap();
            let max = cfg.max_batch();
            let take = 1 + (probe as usize) % max;
            let b = cfg.bucket_for(take);
            if b < take {
                return Err(format!("bucket_for({take}) = {b} < take"));
            }
            if !cfg.buckets.contains(&b) {
                return Err(format!("bucket_for({take}) = {b} not a bucket"));
            }
            // monotone in take
            if take > 1 && cfg.bucket_for(take - 1) > b {
                return Err(format!(
                    "bucket_for not monotone at take {take}"
                ));
            }
            // largest_fit is sound and consistent
            match cfg.largest_fit(take) {
                Some(f) => {
                    if f > take || !cfg.buckets.contains(&f) {
                        return Err(format!(
                            "largest_fit({take}) = {f} unsound"
                        ));
                    }
                }
                None => {
                    if cfg.buckets.iter().any(|&x| x <= take) {
                        return Err(format!(
                            "largest_fit({take}) = None despite a fit"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Run a randomised scenario through the deterministic simulator and
/// hand back its detail report.
fn sim_case(
    seed: u64,
    n: u64,
    mask: u64,
    workers: u64,
    continuous: bool,
) -> mpx::serve::SimReport {
    let n = 1 + n % 120;
    let rate = 500.0 + 97.0 * (seed % 40) as f64;
    simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: LaneSpec {
                name: "p".into(),
                weight: 1,
                batcher: BatcherConfig::new(
                    buckets_from_mask(mask),
                    Duration::from_millis(3),
                )
                .unwrap(),
                queue_capacity: 4096,
                deadline: Duration::from_millis(50),
            },
            arrivals: mpx::serve::loadgen::poisson_offsets(n, rate, seed),
        }],
        policy: if continuous {
            SchedPolicy::Continuous
        } else {
            SchedPolicy::FormFirst
        },
        autoscale: AutoscalePolicy::fixed(1 + (workers as usize) % 3),
        exec_overhead: Duration::from_micros(150),
        exec_per_row: Duration::from_micros(40),
        stop_at: None,
        record_detail: true,
        trace: false,
        replan: None,
    })
    .unwrap()
}

#[test]
fn prop_no_request_lost_or_duplicated_across_refills() {
    forall(
        60,
        |r| {
            ((r.below(1u64 << 32), r.below(1u64 << 16)), (r.below(32), r.below(8)))
        },
        |&((seed, n), (mask, workers))| {
            for continuous in [true, false] {
                let n_req = 1 + n % 120;
                let rep = sim_case(seed, n, mask, workers, continuous);
                if rep.completed() != n_req {
                    return Err(format!(
                        "completed {} of {n_req} admitted",
                        rep.completed()
                    ));
                }
                let mut seen = vec![0u32; n_req as usize];
                for c in &rep.completions {
                    seen[c.id as usize] += 1;
                }
                if let Some(id) = seen.iter().position(|&s| s != 1) {
                    return Err(format!(
                        "request {id} completed {} times",
                        seen[id]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padding_bounded_and_buckets_valid_across_refills() {
    forall(
        60,
        |r| {
            ((r.below(1u64 << 32), r.below(1u64 << 16)), (r.below(32), r.below(8)))
        },
        |&((seed, n), (mask, workers))| {
            let buckets = buckets_from_mask(mask);
            for continuous in [true, false] {
                let rep = sim_case(seed, n, mask, workers, continuous);
                for b in &rep.batches {
                    if b.take == 0 {
                        return Err("dispatched an empty batch".into());
                    }
                    if b.take > b.bucket {
                        return Err(format!(
                            "take {} over bucket {}",
                            b.take, b.bucket
                        ));
                    }
                    // The bucket must be the *smallest* in the set
                    // that fits the real rows — this both bounds
                    // padding at bucket − 1 (take ≥ 1) and catches a
                    // scheduler that rounds into an oversized bucket
                    // when a tighter one exists.
                    let minimal =
                        buckets.iter().copied().find(|&x| x >= b.take);
                    if Some(b.bucket) != minimal {
                        return Err(format!(
                            "take {} dispatched into bucket {} (minimal \
                             fit is {minimal:?})",
                            b.take, b.bucket
                        ));
                    }
                }
                let padded: u64 =
                    rep.batches.iter().map(|b| (b.bucket - b.take) as u64).sum();
                if padded != rep.lanes[0].padded {
                    return Err("padding accounting disagrees".into());
                }
            }
            Ok(())
        },
    );
}
