//! Serving-subsystem tests that run without AOT artifacts: a fake
//! executor stands in for PJRT, so queueing, dynamic batching,
//! padding accounting, and latency aggregation are exercised on any
//! machine.  The artifact-backed path is covered by `mpx serve` and
//! the runtime integration suite.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mpx::config::ServeConfig;
use mpx::serve::{self, BatchExecutor, BatcherConfig, Request, RequestQueue};

const IMG_ELEMS: usize = 4;

/// Stand-in executor: checks shapes, optionally sleeps, logs buckets.
struct FakeExecutor {
    delay: Duration,
    calls: Arc<Mutex<Vec<usize>>>,
}

impl BatchExecutor for FakeExecutor {
    fn execute(&mut self, images: &[f32], batch: usize) -> anyhow::Result<Vec<f32>> {
        assert_eq!(
            images.len(),
            batch * IMG_ELEMS,
            "executor got a non-padded or mis-shaped batch"
        );
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.calls.lock().unwrap().push(batch);
        Ok(vec![0.0; batch])
    }
}

fn fake_factory(
    delay: Duration,
) -> (Arc<Mutex<Vec<usize>>>, impl Fn(usize) -> anyhow::Result<FakeExecutor> + Sync)
{
    let calls = Arc::new(Mutex::new(Vec::new()));
    let calls2 = calls.clone();
    let factory = move |_worker: usize| {
        Ok(FakeExecutor { delay, calls: calls2.clone() })
    };
    (calls, factory)
}

fn image(i: u64) -> Vec<f32> {
    vec![i as f32; IMG_ELEMS]
}

fn base_cfg() -> ServeConfig {
    ServeConfig {
        requests: 5,
        workers: 1,
        queue_capacity: 64,
        flush_timeout_ms: 1000,
        deadline_ms: 10_000,
        arrival_rate: 0.0,
        open_loop: false,
        ..ServeConfig::default()
    }
}

#[test]
fn padded_batch_requests_counted_once() {
    // 5 requests into a bucket-8 artifact: one batch, 3 padding rows,
    // and exactly 5 latency samples — padding must not double-count.
    let cfg = base_cfg();
    let (calls, factory) = fake_factory(Duration::ZERO);
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();

    assert_eq!(report.completed(), 5);
    assert_eq!(report.latency.count(), 5, "padded rows leaked into stats");
    assert_eq!(report.batches(), 1);
    assert_eq!(report.padded(), 3);
    assert_eq!(*calls.lock().unwrap(), vec![8]);
    assert_eq!(report.queue.accepted, 5);
    assert_eq!(report.queue.rejected, 0);
    assert!((report.padding_fraction() - 3.0 / 8.0).abs() < 1e-12);
}

#[test]
fn size_buckets_avoid_padding_when_available() {
    // Same 5 requests, but with 1/2/4/8 buckets the close-drain takes
    // all 5 and rounds up to 8; a 4-request run rounds to exactly 4.
    let mut cfg = base_cfg();
    cfg.requests = 4;
    let (calls, factory) = fake_factory(Duration::ZERO);
    let report = serve::run(&cfg, vec![1, 2, 4, 8], factory, image).unwrap();
    assert_eq!(report.completed(), 4);
    assert_eq!(report.padded(), 0);
    assert_eq!(*calls.lock().unwrap(), vec![4]);
}

#[test]
fn flush_on_timeout_fires_at_the_deadline() {
    // 3 requests sit in a bucket-8 queue with no close and no more
    // arrivals: next_batch must block ~flush_timeout, then flush.
    let q = RequestQueue::new(64);
    let t0 = Instant::now();
    for i in 0..3u64 {
        assert!(q.try_enqueue(Request::new(i, image(i), Duration::from_secs(1))));
    }
    let bcfg =
        BatcherConfig::new(vec![8], Duration::from_millis(40)).unwrap();
    let batch = q.next_batch(&bcfg).expect("flush should dispatch");
    let waited = t0.elapsed();
    assert_eq!(batch.requests.len(), 3);
    assert_eq!(batch.bucket, 8);
    assert_eq!(batch.padding(), 5);
    assert!(
        waited >= Duration::from_millis(35),
        "flushed before the deadline: {waited:?}"
    );
    assert!(waited < Duration::from_secs(5), "flush never fired");
    assert_eq!(q.depth(), 0);
}

#[test]
fn fifo_order_preserved_within_and_across_batches() {
    let q = RequestQueue::new(64);
    for i in 0..20u64 {
        assert!(q.try_enqueue(Request::new(i, image(i), Duration::from_secs(1))));
    }
    q.close();
    let bcfg = BatcherConfig::new(
        vec![1, 2, 4, 8],
        Duration::from_millis(100),
    )
    .unwrap();
    let mut ids = Vec::new();
    let mut padding = 0;
    while let Some(batch) = q.next_batch(&bcfg) {
        assert!(batch.bucket >= batch.requests.len());
        padding += batch.padding();
        ids.extend(batch.requests.iter().map(|r| r.id));
    }
    // 20 → batches of 8, 8, 4: strict FIFO, no padding needed.
    assert_eq!(ids, (0..20).collect::<Vec<u64>>());
    assert_eq!(padding, 0);
}

#[test]
fn per_worker_histograms_merge_into_run_aggregate() {
    let mut cfg = base_cfg();
    cfg.requests = 40;
    cfg.workers = 2;
    cfg.flush_timeout_ms = 1;
    let (_calls, factory) = fake_factory(Duration::from_millis(1));
    let report = serve::run(&cfg, vec![1, 2, 4, 8], factory, image).unwrap();

    assert_eq!(report.completed(), 40);
    let per_worker: usize =
        report.workers.iter().map(|w| w.latency.count()).sum();
    assert_eq!(report.latency.count(), per_worker);
    let s = report.latency.summary().unwrap();
    assert_eq!(s.count, 40);
    assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    // every latency is at least the executor delay
    assert!(s.p50 >= Duration::from_millis(1));
}

#[test]
fn open_loop_admission_control_rejects_when_full() {
    // A burst of 40 instant arrivals against capacity 8 and a slow
    // single worker: the bound must hold and the excess be rejected.
    let mut cfg = base_cfg();
    cfg.requests = 40;
    cfg.queue_capacity = 8;
    cfg.open_loop = true;
    cfg.flush_timeout_ms = 50;
    let (_calls, factory) = fake_factory(Duration::from_millis(20));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();

    assert_eq!(report.queue.accepted + report.queue.rejected, 40);
    assert!(report.queue.rejected > 0, "admission control never engaged");
    assert_eq!(report.completed(), report.queue.accepted);
    assert!(report.queue.peak_depth <= 8);
}

#[test]
fn closed_loop_backpressure_never_drops() {
    let mut cfg = base_cfg();
    cfg.requests = 30;
    cfg.queue_capacity = 8;
    cfg.flush_timeout_ms = 2;
    let (_calls, factory) = fake_factory(Duration::from_millis(2));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();
    assert_eq!(report.queue.rejected, 0);
    assert_eq!(report.completed(), 30);
}

#[test]
fn deadline_misses_are_reported() {
    let mut cfg = base_cfg();
    cfg.requests = 10;
    cfg.deadline_ms = 0; // everything misses
    let (_calls, factory) = fake_factory(Duration::from_millis(2));
    let report = serve::run(&cfg, vec![8], factory, image).unwrap();
    assert_eq!(report.deadline_misses(), report.completed());
}

#[test]
fn worker_factory_failure_propagates_without_hanging() {
    let cfg = base_cfg();
    let factory = |_worker: usize| -> anyhow::Result<FakeExecutor> {
        anyhow::bail!("executor construction failed")
    };
    let res = serve::run(&cfg, vec![8], factory, image);
    assert!(res.is_err());
}
