//! Virtual-clock simulation tests for the continuous-batching
//! scheduler (`mpx::serve::sched`).
//!
//! Every test replays a scenario through `serve::simulate` — the
//! exact production `Scheduler` state machine driven single-threaded
//! over an event heap on a `VirtualClock`.  No test body sleeps, ever
//! (`std::thread::sleep` does not appear in this file): timing
//! assertions are *equalities* on virtual instants, not tolerances
//! around real ones, and every run is bit-identical for a given spec.

use std::time::Duration;

use mpx::serve::planner::{self, LaneProfile, PlannerConfig, ServiceModel};
use mpx::serve::{
    loadgen, simulate, AutoscalePolicy, BatcherConfig, DriftConfig, LaneLoad,
    LaneSpec, ReplanSpec, SchedPolicy, SimReplan, SimReport, SimSpec,
};
use mpx::trace::{
    chrome, service_samples, LaneId, ServiceSample, Span, SpanKind,
};
use mpx::util::json::Json;

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

fn lane(
    name: &str,
    weight: u64,
    buckets: &[usize],
    flush: Duration,
    deadline: Duration,
) -> LaneSpec {
    LaneSpec {
        name: name.into(),
        weight,
        batcher: BatcherConfig::new(buckets.to_vec(), flush).unwrap(),
        queue_capacity: 10_000,
        deadline,
    }
}

#[test]
fn flush_on_timeout_fires_at_exactly_flush_timeout() {
    // Three requests trickle into a bucket-8 lane (nothing below the
    // bucket can exact-fill) with a 5 ms flush timeout and one idle
    // worker.  The partial batch must dispatch at *exactly*
    // oldest-enqueue + 5 ms — not at close, not a tick late.
    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("a", 1, &[8], ms(5), Duration::from_secs(1)),
            arrivals: vec![ms(0), ms(1), ms(2)],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: ms(1),
        exec_per_row: Duration::ZERO,
        // Hold the lane open well past the flush deadline so the
        // dispatch can only come from the flush timer.
        stop_at: Some(Duration::from_secs(1)),
        record_detail: true,
        trace: false,
        replan: None,
    })
    .unwrap();

    // One batch, dispatched at exactly t = 0 + flush_timeout.
    assert_eq!(rep.batches.len(), 1);
    let b = &rep.batches[0];
    assert_eq!(b.at, ms(5), "flush fired at {:?}, want 5ms exactly", b.at);
    assert_eq!(b.take, 3);
    assert_eq!(b.bucket, 8);
    assert_eq!(rep.lanes[0].padded, 5);

    // All three complete together at flush + service.
    assert_eq!(rep.completions.len(), 3);
    for c in &rep.completions {
        assert_eq!(c.done, ms(6));
    }
    // Exact per-request latencies: 6, 5, 4 ms by arrival order.
    let lat: Vec<Duration> = rep
        .completions
        .iter()
        .map(|c| c.done - c.enqueued)
        .collect();
    assert_eq!(lat, vec![ms(6), ms(5), ms(4)]);
    assert_eq!(rep.wall, ms(6));
}

#[test]
fn continuous_refill_keeps_occupancy_above_floor_under_poisson_load() {
    // 3000 Poisson arrivals at ~77 % of full-batch capacity over a
    // fixed 4-worker pool.  Continuous refill hands every freed slot
    // the largest exactly-fillable bucket immediately, so workers
    // stay saturated while the backlog lasts: mean occupancy must
    // clear a 0.6 floor (offered utilisation is ~0.77; smaller
    // batches only push busy time *up*).
    let spec = SimSpec {
        lanes: vec![LaneLoad {
            spec: lane(
                "a",
                1,
                &[1, 2, 4, 8],
                ms(2),
                Duration::from_secs(10),
            ),
            arrivals: loadgen::poisson_offsets(3000, 19_000.0, 11),
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(4),
        exec_overhead: Duration::from_micros(100),
        exec_per_row: Duration::from_micros(150),
        stop_at: None,
        record_detail: false,
        trace: false,
        replan: None,
    };
    let rep = simulate(spec.clone()).unwrap();
    assert_eq!(rep.completed(), 3000, "under-capacity load must all finish");
    assert_eq!(rep.lanes[0].rejected, 0);
    let occ = rep.occupancy(4);
    assert!(
        occ >= 0.6,
        "worker occupancy {occ:.3} fell below the 0.6 floor"
    );
    assert!(occ <= 1.0 + 1e-9, "occupancy {occ:.3} over 1 is impossible");

    // And the whole replay is deterministic: same spec, same report.
    let again = simulate(spec).unwrap();
    assert_eq!(rep.wall, again.wall);
    assert_eq!(rep.busy, again.busy);
    assert_eq!(
        rep.lanes[0].latency.quantile(0.99),
        again.lanes[0].latency.quantile(0.99)
    );
}

#[test]
fn deadline_miss_accounting_is_exact() {
    // Five simultaneous arrivals, bucket-1 lane, one worker, 10 ms
    // service, 25 ms deadline: completions land at 10/20/30/40/50 ms,
    // so exactly requests 3, 4, 5 miss.  Not a statistical bound —
    // the exact set.
    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("a", 1, &[1], ms(1), ms(25)),
            arrivals: vec![ms(0); 5],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: ms(10),
        exec_per_row: Duration::ZERO,
        stop_at: None,
        record_detail: true,
        trace: false,
        replan: None,
    })
    .unwrap();

    assert_eq!(rep.completed(), 5);
    assert_eq!(rep.deadline_misses(), 3);
    assert_eq!(rep.lanes[0].deadline_misses, 3);
    let done: Vec<Duration> =
        rep.completions.iter().map(|c| c.done).collect();
    assert_eq!(done, vec![ms(10), ms(20), ms(30), ms(40), ms(50)]);
    let missed: Vec<bool> =
        rep.completions.iter().map(|c| c.missed_deadline).collect();
    assert_eq!(missed, vec![false, false, true, true, true]);
    assert_eq!(rep.wall, ms(50));
}

#[test]
fn two_lanes_with_2_to_1_weights_get_2_to_1_service_under_saturation() {
    // Both lanes saturated (8000 back-to-back arrivals each), one
    // worker, 1 ms per batch, truncated at t = 600 ms: the
    // weighted-deficit picker must produce the exact A,A,B dispatch
    // cycle, i.e. 400 lane-a batches (3200 requests) to 200 lane-b
    // batches (1600 requests).  Exactly 2:1 — not approximately.
    let rep = simulate(SimSpec {
        lanes: vec![
            LaneLoad {
                spec: lane("a", 2, &[8], ms(5), Duration::from_secs(10)),
                arrivals: vec![Duration::ZERO; 8000],
            },
            LaneLoad {
                spec: lane("b", 1, &[8], ms(5), Duration::from_secs(10)),
                arrivals: vec![Duration::ZERO; 8000],
            },
        ],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: ms(1),
        exec_per_row: Duration::ZERO,
        stop_at: Some(ms(600)),
        record_detail: true,
        trace: false,
        replan: None,
    })
    .unwrap();

    // Dispatches happen at t = 0, 1, …, 600 ms (the t = 600 batch is
    // in flight when the replay truncates, so it is dispatched but
    // not completed): 601 dispatches = 401 A + 200 B; 600 completed
    // batches = 400 A + 200 B — exactly 2:1 service in requests.
    assert_eq!(rep.lanes[0].batches, 401);
    assert_eq!(rep.lanes[1].batches, 200);
    assert_eq!(rep.lanes[0].completed, 3200);
    assert_eq!(rep.lanes[1].completed, 1600);
    // The dispatch pattern itself: A, A, B repeating from the start.
    let first9: Vec<usize> =
        rep.batches.iter().take(9).map(|b| b.lane).collect();
    assert_eq!(first9, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
    // No padding under saturation: every batch a full bucket.
    assert_eq!(rep.lanes[0].padded + rep.lanes[1].padded, 0);
}

#[test]
fn autoscaler_grows_the_pool_on_backlog_and_completes_everything() {
    // A 64-request burst into a 1..4-worker pool that scales at 8
    // queued requests per worker: the pool must grow past its
    // initial size, never exceed the ceiling, and still drain every
    // request.
    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("a", 1, &[8], ms(2), Duration::from_secs(10)),
            arrivals: vec![Duration::ZERO; 64],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy {
            min_workers: 1,
            max_workers: 4,
            depth_per_worker: 8,
        },
        exec_overhead: ms(5),
        exec_per_row: Duration::ZERO,
        stop_at: None,
        record_detail: false,
        trace: false,
        replan: None,
    })
    .unwrap();

    assert_eq!(rep.completed(), 64);
    assert!(rep.spawned >= 1, "backlog never grew the pool");
    assert!(rep.peak_workers > 1);
    assert!(rep.peak_workers <= 4, "pool exceeded max_workers");
}

#[test]
fn planner_buckets_meet_the_slo_the_static_bucket_list_misses() {
    // The PR-3 static deployment cannot express per-lane SLOs: lanes
    // get whatever bucket sizes were AOT-compiled and one global
    // flush timeout.  Scenario: an interactive lane offering one
    // request every 50 ms with a 12 ms p99 deadline, on a service
    // model of service(b) = 1 ms + b × 1 ms (the exact linear model
    // `simulate` executes batches with).
    //
    // Static setup: only the throughput buckets [4, 8] were compiled,
    // global flush 20 ms.  Every lone request sits below the smallest
    // bucket, waits the full flush, pads up to bucket 4, and
    // completes at exactly 20 + (1 + 4) = 25 ms — every single one
    // misses the 12 ms deadline, deterministically.
    //
    // Planner setup: given the same load profile, rate, and deadline,
    // the planner selects a bucket set containing size 1 (lone
    // requests dispatch the instant a worker frees, no flush
    // exposure, no padding), so every request completes at exactly
    // service(1) = 2 ms and the lane meets its SLO.
    let model = ServiceModel {
        overhead: ms(1),
        per_row: ms(1),
    };
    let deadline = ms(12);
    let requests = 40u64;
    let arrivals: Vec<Duration> =
        (0..requests).map(|i| ms(50 * i)).collect();
    // Hold the lane open past the last flush so the tail request pays
    // the same flush stall as the rest (no close-drain bailout).
    let stop_at = Some(Duration::from_secs(10));

    let run = |spec: LaneSpec| -> SimReport {
        simulate(SimSpec {
            lanes: vec![LaneLoad { spec, arrivals: arrivals.clone() }],
            policy: SchedPolicy::Continuous,
            autoscale: AutoscalePolicy::fixed(1),
            exec_overhead: model.overhead,
            exec_per_row: model.per_row,
            stop_at,
            record_detail: true,
            trace: false,
            replan: None,
        })
        .unwrap()
    };

    // --- static bucket list: all 40 requests miss, at exactly 25 ms.
    let static_rep = run(lane("interactive", 1, &[4, 8], ms(20), deadline));
    assert_eq!(static_rep.completed(), requests);
    assert_eq!(
        static_rep.deadline_misses(),
        requests,
        "every lone request must miss under the static buckets"
    );
    for c in &static_rep.completions {
        assert_eq!(c.done - c.enqueued, ms(25));
        assert!(c.missed_deadline);
    }
    let static_p99 = static_rep.latency().quantile(0.99).unwrap();
    assert_eq!(static_p99, ms(25));
    assert!(static_p99 > deadline);
    // Padding ballast: 3 padded rows per bucket-4 dispatch of 1.
    assert_eq!(static_rep.lanes[0].padded, 3 * requests);

    // --- the planner, fed the offered-load profile, fixes it.
    let profile = LaneProfile {
        name: "interactive".into(),
        rate: 20.0, // one request per 50 ms
        deadline,
        weight: 1,
        size_dist: Vec::new(),
    };
    let pcfg = PlannerConfig {
        candidates: vec![1, 2, 4, 8],
        workers: 1,
        max_compiled: 0,
        safety: 0.9,
        max_flush: ms(20),
    };
    let plan = planner::plan(&pcfg, &model, &[profile]).unwrap();
    assert!(plan.is_feasible(), "the SLO is meetable — plan must say so");
    let lp = &plan.lanes[0];
    assert!(
        lp.buckets.contains(&1),
        "sparse traffic needs bucket 1, planner chose {:?}",
        lp.buckets
    );
    assert!(lp.predicted.p99 <= deadline);

    let planned_rep = run(lp.lane_spec(10_000).unwrap());
    assert_eq!(planned_rep.completed(), requests);
    assert_eq!(
        planned_rep.deadline_misses(),
        0,
        "planned buckets must meet the per-lane deadline"
    );
    for c in &planned_rep.completions {
        assert_eq!(c.done - c.enqueued, ms(2)); // service(1), exactly
    }
    let planned_p99 = planned_rep.latency().quantile(0.99).unwrap();
    assert_eq!(planned_p99, ms(2));
    assert!(planned_p99 <= deadline);
    assert_eq!(planned_rep.lanes[0].padded, 0, "exact fills never pad");
    // The planner's conservative p99 bound really bounds the measured
    // virtual-clock p99.
    assert!(lp.predicted.p99 >= planned_p99);
}

#[test]
fn planner_saturated_lane_plan_sustains_full_buckets_in_the_sim() {
    // A back-to-back lane is throughput-planned: the planner picks a
    // single full-size bucket (zero padding at saturation, best
    // per-row service).  Replay 64 simultaneous arrivals through the
    // planned spec: 8 full bucket-8 batches, no padding anywhere.
    let model = ServiceModel {
        overhead: ms(1),
        per_row: Duration::ZERO,
    };
    let plan = planner::plan(
        &PlannerConfig {
            candidates: vec![1, 2, 4, 8],
            workers: 2,
            max_compiled: 0,
            safety: 0.9,
            max_flush: ms(5),
        },
        &model,
        &[LaneProfile {
            name: "bulk".into(),
            rate: 0.0,
            deadline: Duration::from_secs(1),
            weight: 1,
            size_dist: Vec::new(),
        }],
    )
    .unwrap();
    assert!(plan.is_feasible());
    assert_eq!(plan.lanes[0].buckets, vec![8]);

    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: plan.lanes[0].lane_spec(10_000).unwrap(),
            arrivals: vec![Duration::ZERO; 64],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(2),
        exec_overhead: model.overhead,
        exec_per_row: model.per_row,
        stop_at: None,
        record_detail: false,
        trace: false,
        replan: None,
    })
    .unwrap();
    assert_eq!(rep.completed(), 64);
    assert_eq!(rep.lanes[0].batches, 8);
    assert_eq!(rep.lanes[0].padded, 0);
}

#[test]
fn continuous_beats_form_first_on_identical_simulated_load() {
    // The bench acceptance bar, as a test: identical Poisson traffic,
    // identical 2-worker pool — continuous batching must complete the
    // run no slower than the old form-whole-batch-then-execute loop
    // (it dispatches exact-fill buckets instead of idling toward
    // flush deadlines), and cut p50 latency.  `stop_at` far in the
    // future keeps the lanes open, so form-first pays its real flush
    // stalls instead of being bailed out by close-drain.
    let run = |policy: SchedPolicy| -> SimReport {
        simulate(SimSpec {
            lanes: vec![LaneLoad {
                spec: lane(
                    "a",
                    1,
                    &[1, 2, 4, 8],
                    ms(20),
                    Duration::from_secs(10),
                ),
                // 250 req/s < max_batch/flush_timeout (8 / 20 ms =
                // 400 req/s): form-first cannot fill a bucket before
                // the flush fires, so its stalls are structural, not
                // a seed accident.
                arrivals: loadgen::poisson_offsets(2003, 250.0, 42),
            }],
            policy,
            autoscale: AutoscalePolicy::fixed(2),
            exec_overhead: Duration::from_micros(300),
            exec_per_row: Duration::from_micros(130),
            stop_at: Some(Duration::from_secs(3600)),
            record_detail: false,
            trace: false,
            replan: None,
        })
        .unwrap()
    };
    let form_first = run(SchedPolicy::FormFirst);
    let continuous = run(SchedPolicy::Continuous);
    assert_eq!(form_first.completed(), 2003);
    assert_eq!(continuous.completed(), 2003);
    // Below the flush-fill threshold, form-first's median request
    // sits out most of a flush window; continuous dispatches on
    // arrival. The gap is an order of magnitude, not a tolerance.
    assert!(
        continuous.wall <= form_first.wall,
        "continuous drained in {:?}, form-first in {:?}",
        continuous.wall,
        form_first.wall
    );
    let p50_c = continuous.latency().quantile(0.5).unwrap();
    let p50_f = form_first.latency().quantile(0.5).unwrap();
    assert!(
        p50_c < p50_f,
        "continuous p50 {p50_c:?} not below form-first {p50_f:?}"
    );
    assert!(
        continuous.throughput_rps() >= form_first.throughput_rps(),
        "continuous {:.1} rps below form-first {:.1} rps",
        continuous.throughput_rps(),
        form_first.throughput_rps()
    );
}

#[test]
fn trace_spans_tile_observed_latency_exactly() {
    // The flush-timeout scenario, traced: three requests trickle in at
    // t = 0, 1, 2 ms, dispatch together when the 5 ms flush fires, and
    // complete at t = 6 ms.  Under the virtual clock the span algebra
    // must hold as *equalities* on exact instants — for every request,
    // queue_wait + service == done − enqueued — and the whole trace
    // must be bit-identical run-to-run.
    let mk = || SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("a", 1, &[8], ms(5), Duration::from_secs(1)),
            arrivals: vec![ms(0), ms(1), ms(2)],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: ms(1),
        exec_per_row: Duration::ZERO,
        stop_at: Some(Duration::from_secs(1)),
        record_detail: true,
        trace: true,
        replan: None,
    };
    let rep = simulate(mk()).unwrap();
    assert_eq!(rep.completions.len(), 3);

    let span_of = |kind: SpanKind, id: u64| -> Span {
        rep.spans
            .iter()
            .find(|s| s.kind == kind && s.b == id)
            .copied()
            .unwrap_or_else(|| panic!("no {kind:?} span for request {id}"))
    };

    for c in &rep.completions {
        let adm = span_of(SpanKind::Admit, c.id);
        let qw = span_of(SpanKind::QueueWait, c.id);
        let sv = span_of(SpanKind::Service, c.id);
        // The three spans tile the observed request latency exactly:
        // admit at enqueue, queue-wait up to the dispatch pivot,
        // service to completion.  Equalities, not tolerances.
        assert_eq!(adm.start, c.enqueued);
        assert_eq!(adm.duration(), Duration::ZERO);
        assert_eq!(qw.start, c.enqueued);
        assert_eq!(qw.end, sv.start);
        assert_eq!(sv.end, c.done);
        assert_eq!(qw.duration() + sv.duration(), c.done - c.enqueued);
        // All three dispatched at the flush instant, 1 ms service.
        assert_eq!(qw.end, ms(5));
        assert_eq!(sv.duration(), ms(1));
    }

    // Exactly one execute span — the dispatched batch — carrying the
    // planner's calibration attributes (lane 0, bucket 8, take 3).
    let execs: Vec<&Span> =
        rep.spans.iter().filter(|s| s.kind == SpanKind::Execute).collect();
    assert_eq!(execs.len(), 1);
    assert_eq!((execs[0].start, execs[0].end), (ms(5), ms(6)));
    assert_eq!((execs[0].a, execs[0].b, execs[0].c), (0, 8, 3));
    let ids = [LaneId::new("vit_tiny/a", "mixed_f16")];
    let samples = service_samples(&rep.spans, &ids);
    assert_eq!(
        samples,
        vec![ServiceSample {
            lane: "vit_tiny/a".into(),
            precision: "mixed_f16".into(),
            batch_rows: 8,
            exec_us: 1000,
        }]
    );

    // Bit-deterministic: replaying the same spec yields the same
    // spans, field for field.
    assert_eq!(simulate(mk()).unwrap().spans, rep.spans);

    // Chrome export: parses back through the crate's own JSON parser
    // unchanged, and every B event closes with an E on its track.
    let doc = chrome::chrome_trace(&rep.spans, 0);
    let parsed = Json::parse(&doc.dump()).unwrap();
    assert_eq!(parsed, doc);
    let pairs = chrome::check_nesting(&parsed).unwrap();
    assert_eq!(pairs, rep.spans.len());
}

/// The replan scenarios below share one service model — the exact
/// linear model `simulate` executes batches with — so the planner's
/// predictions and the replayed executions agree by construction:
/// service(b) = 4 ms + 0.5 ms × b, i.e. bucket 1 serves 222 req/s
/// and bucket 8 serves 1000 rows/s.
fn step_model() -> ServiceModel {
    ServiceModel {
        overhead: ms(4),
        per_row: Duration::from_micros(500),
    }
}

/// Arrival timeline for the rate-step scenarios: one request every
/// 10 ms through t = `step`, then one every 2 ms through `end` —
/// a clean 100 → 500 req/s step at `step`.
fn step_arrivals(step: u64, end: u64) -> Vec<Duration> {
    let mut arrivals: Vec<Duration> =
        (1..=step / 10).map(|i| ms(10 * i)).collect();
    let mut t = step + 2;
    while t <= end {
        arrivals.push(ms(t));
        t += 2;
    }
    arrivals
}

fn step_replan(
    planned_rate: f64,
    patience: u32,
    compiled: Vec<Vec<usize>>,
) -> SimReplan {
    SimReplan {
        spec: ReplanSpec {
            drift: DriftConfig {
                window: ms(500),
                alpha: 0.5,
                rate_ratio: 2.0,
                // > 1.0 can never trip: the rate breach is the one
                // deterministic trigger under test.
                miss_ratio: 2.0,
                patience,
                cooldown: Duration::from_secs(10),
            },
            planner: PlannerConfig {
                candidates: vec![1, 2, 4, 8],
                workers: 1,
                max_compiled: 0,
                safety: 0.9,
                max_flush: ms(5),
            },
            models: vec![step_model()],
            compiled,
        },
        planned_rates: vec![planned_rate],
    }
}

#[test]
fn rate_step_triggers_a_live_replan_and_p99_recovers() {
    // The closed loop, end to end on the virtual clock.  A lane is
    // planned for 100 req/s and served with buckets [1] (capacity
    // 222 req/s).  At t = 2 s the offered rate steps to 500 req/s:
    // bucket 1 can no longer keep up and the backlog — and with it
    // the latency — grows without bound.  The drift monitor samples
    // 500 ms windows (EWMA α = 0.5, breach above 2× planned,
    // patience 2):
    //
    //   t=0.5/1.0/1.5/2.0  rate 100  ema 100      no breach
    //   t=2.5              rate 500  ema 300      breach 1 (> 200)
    //   t=3.0              rate 500  ema 400      breach 2 → REPLAN
    //
    // The replan at *exactly* t = 3 s re-runs the planner at the
    // measured 400 req/s; bucket 1 alone is over capacity, so the
    // plan adds bucket 8 (1000 rows/s) and `adopt_plan` hot-swaps
    // the lane to [1, 8] with nothing drained: the in-flight
    // bucket-1 batch (dispatched t = 2.999 s) finishes untouched at
    // t = 3.0035 s, and the very next dispatch is the first bucket-8
    // batch, at exactly that instant.  The backlog then drains at
    // 2× the offered rate and the tail of the run meets the deadline
    // again.  Every request is answered exactly once — the swap
    // drops and duplicates nothing.
    let deadline = ms(80);
    let arrivals = step_arrivals(2000, 5000);
    let offered = arrivals.len() as u64;
    assert_eq!(offered, 1700);
    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("step", 1, &[1], ms(5), deadline),
            arrivals,
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: step_model().overhead,
        exec_per_row: step_model().per_row,
        stop_at: Some(Duration::from_secs(10)),
        record_detail: true,
        trace: true,
        replan: Some(step_replan(100.0, 2, vec![vec![1, 2, 4, 8]])),
    })
    .unwrap();

    // Exactly one replan, at exactly the second breached window.
    assert_eq!(rep.replans, vec![Duration::from_secs(3)]);

    // Nothing dropped, nothing duplicated across the switchover.
    assert_eq!(rep.completed(), offered);
    assert_eq!(rep.lanes[0].rejected, 0);
    let ids: std::collections::BTreeSet<u64> =
        rep.completions.iter().map(|c| c.id).collect();
    assert_eq!(ids.len() as u64, offered);

    // Before the swap every dispatch is the old bucket-1 shape; the
    // first bucket-8 batch leaves the instant the in-flight bucket-1
    // batch frees the worker.
    assert!(rep
        .batches
        .iter()
        .filter(|b| b.at < Duration::from_secs(3))
        .all(|b| b.bucket == 1));
    let first8 = rep
        .batches
        .iter()
        .find(|b| b.bucket == 8)
        .expect("the replan must introduce bucket-8 batches");
    assert_eq!(first8.at, Duration::from_micros(3_003_500));
    assert_eq!(first8.take, 8);

    // The overload cohort (enqueued in the half-window before the
    // replan) blows straight through the deadline; the recovered
    // tail (enqueued from t = 4.2 s, backlog long drained) meets it.
    let cohort = |from: Duration, to: Duration| -> Vec<Duration> {
        let mut lat: Vec<Duration> = rep
            .completions
            .iter()
            .filter(|c| c.enqueued >= from && c.enqueued < to)
            .map(|c| c.done - c.enqueued)
            .collect();
        lat.sort();
        lat
    };
    let overload = cohort(ms(2500), ms(3000));
    assert!(!overload.is_empty());
    let p99 = |lat: &[Duration]| lat[(lat.len() - 1) * 99 / 100];
    assert!(
        p99(&overload) > deadline,
        "overload cohort p99 {:?} should miss the {deadline:?} deadline",
        p99(&overload)
    );
    let recovered = cohort(ms(4200), ms(5001));
    assert!(!recovered.is_empty());
    assert!(
        p99(&recovered) <= deadline,
        "post-replan p99 {:?} should meet the {deadline:?} deadline",
        p99(&recovered)
    );

    // The trace carries the replan instant: ordinal 1, one lane
    // retuned, fully covered by the compiled set.
    let replans: Vec<&mpx::trace::Span> = rep
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Replan)
        .collect();
    assert_eq!(replans.len(), 1);
    let r = replans[0];
    assert_eq!(r.start, Duration::from_secs(3));
    assert_eq!(r.end, r.start);
    assert_eq!((r.a, r.b, r.c), (1, 1, 1));

    // Bit-deterministic, replans and all.
    let again = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("step", 1, &[1], ms(5), deadline),
            arrivals: step_arrivals(2000, 5000),
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: step_model().overhead,
        exec_per_row: step_model().per_row,
        stop_at: Some(Duration::from_secs(10)),
        record_detail: true,
        trace: true,
        replan: Some(step_replan(100.0, 2, vec![vec![1, 2, 4, 8]])),
    })
    .unwrap();
    assert_eq!(again.replans, rep.replans);
    assert_eq!(again.spans, rep.spans);
}

#[test]
fn replan_falls_back_to_the_compiled_bucket_subset() {
    // Same rate step, but only buckets [2, 8] were ever AOT-compiled.
    // The planner's wish at the measured rate is [1, 8]; bucket 1 is
    // not servable, so the adopted retune is the feasible subset [8]
    // and the replan reports partial coverage (Replan span c = 0)
    // instead of silently pretending the full plan landed.  With
    // patience 1 the first breached window fires: t = 2.5 s exactly.
    let deadline = ms(80);
    let arrivals = step_arrivals(2000, 3000);
    let offered = arrivals.len() as u64;
    assert_eq!(offered, 700);
    let rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane("step", 1, &[1], ms(5), deadline),
            arrivals,
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: step_model().overhead,
        exec_per_row: step_model().per_row,
        stop_at: Some(Duration::from_secs(10)),
        record_detail: true,
        trace: true,
        replan: Some(step_replan(100.0, 1, vec![vec![2, 8]])),
    })
    .unwrap();

    assert_eq!(rep.replans, vec![ms(2500)]);
    assert_eq!(rep.completed(), offered);
    assert_eq!(rep.lanes[0].rejected, 0);

    // Old shape before the swap; after it the lane serves *only*
    // bucket 8 — bucket 1 fell out of the plan entirely.
    assert!(rep
        .batches
        .iter()
        .filter(|b| b.at < ms(2500))
        .all(|b| b.bucket == 1));
    assert!(rep
        .batches
        .iter()
        .filter(|b| b.at >= ms(2500))
        .all(|b| b.bucket == 8));
    assert!(rep.batches.iter().any(|b| b.bucket == 8));

    // Partial coverage is announced, not hidden: c = 0.
    let r = rep
        .spans
        .iter()
        .find(|s| s.kind == SpanKind::Replan)
        .expect("replan span");
    assert_eq!(r.start, ms(2500));
    assert_eq!((r.a, r.b, r.c), (1, 1, 0));
}
