//! Shared helpers for the integration test binaries (`mod common;`).

use mpx::runtime::ArtifactStore;

/// Open the artifact store, or `None` when the artifacts have not
/// been built — the caller's test skips with a note, which keeps
/// `cargo test` meaningful on fresh clones and in CI where
/// `make artifacts` has not run.
///
/// Each test builds its own store (and PJRT client): the xla crate's
/// client is Rc-based (!Send), so it cannot live in a shared static
/// across the test harness's threads.
pub fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}
