//! Shared helpers for the integration test binaries (`mod common;`).
//!
//! Two access levels:
//!
//! * [`manifests`] — manifest/HLO-text file reads only (no compile).
//! * [`store`] — the full [`ArtifactStore`], compiling through the
//!   build's default backend: PJRT with the `xla` feature, the
//!   pure-Rust host interpreter under `--no-default-features`.  Every
//!   artifact-backed suite therefore *runs* in both builds.
//!
//! Both return `None` (with a note) when the artifacts have not been
//! built, so `cargo test` stays meaningful on fresh clones and in CI
//! where `make artifacts` has not run.

use std::path::PathBuf;

use mpx::pytree::Manifest;
use mpx::runtime::ArtifactStore;

/// Manifest-only view of the artifact directory (no PJRT client).
#[allow(dead_code)]
pub struct ManifestDir {
    dir: PathBuf,
}

#[allow(dead_code)]
impl ManifestDir {
    pub fn manifest(&self, name: &str) -> anyhow::Result<Manifest> {
        let path = self.dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)?;
        Manifest::parse(&text)
    }

    pub fn hlo_text(&self, name: &str) -> anyhow::Result<String> {
        Ok(std::fs::read_to_string(
            self.dir.join(format!("{name}.hlo.txt")),
        )?)
    }
}

/// Open the artifact directory for manifest/HLO reads, or `None`
/// (test skips with a note) when it does not exist.
#[allow(dead_code)]
pub fn manifests() -> Option<ManifestDir> {
    let dir = PathBuf::from(
        std::env::var("MPX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if dir.is_dir() {
        Some(ManifestDir { dir })
    } else {
        eprintln!(
            "skipping: artifact directory {} not found — run `make artifacts`",
            dir.display()
        );
        None
    }
}

/// Open the artifact store on the build's default backend, or `None`
/// when the artifacts have not been built — the caller's test skips
/// with a note.
///
/// Each test builds its own store (and backend): the xla crate's
/// client is Rc-based (!Send), so it cannot live in a shared static
/// across the test harness's threads.
#[allow(dead_code)]
pub fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}
