//! Shared helpers for the integration test binaries (`mod common;`).
//!
//! Two access levels:
//!
//! * [`manifests`] — manifest/HLO-text file reads only.  Needs no
//!   PJRT client and no `xla` feature, so manifest-level cross-checks
//!   (e.g. `memmodel_cross_check`) run even in the host-only
//!   `--no-default-features` build.
//! * [`store`] — the full [`ArtifactStore`] (compiles executables via
//!   PJRT); only exists with the `xla` feature.
//!
//! Both return `None` (with a note) when the artifacts have not been
//! built, so `cargo test` stays meaningful on fresh clones and in CI
//! where `make artifacts` has not run.

use std::path::PathBuf;

use mpx::pytree::Manifest;

#[cfg(feature = "xla")]
use mpx::runtime::ArtifactStore;

/// Manifest-only view of the artifact directory (no PJRT client).
#[allow(dead_code)]
pub struct ManifestDir {
    dir: PathBuf,
}

#[allow(dead_code)]
impl ManifestDir {
    pub fn manifest(&self, name: &str) -> anyhow::Result<Manifest> {
        let path = self.dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)?;
        Manifest::parse(&text)
    }

    pub fn hlo_text(&self, name: &str) -> anyhow::Result<String> {
        Ok(std::fs::read_to_string(
            self.dir.join(format!("{name}.hlo.txt")),
        )?)
    }
}

/// Open the artifact directory for manifest/HLO reads, or `None`
/// (test skips with a note) when it does not exist.
#[allow(dead_code)]
pub fn manifests() -> Option<ManifestDir> {
    let dir = PathBuf::from(
        std::env::var("MPX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if dir.is_dir() {
        Some(ManifestDir { dir })
    } else {
        eprintln!(
            "skipping: artifact directory {} not found — run `make artifacts`",
            dir.display()
        );
        None
    }
}

/// Open the artifact store, or `None` when the artifacts have not
/// been built — the caller's test skips with a note.
///
/// Each test builds its own store (and PJRT client): the xla crate's
/// client is Rc-based (!Send), so it cannot live in a shared static
/// across the test harness's threads.
#[cfg(feature = "xla")]
#[allow(dead_code)]
pub fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}
