//! Data-parallel mode: equivalence with the fused path and shard
//! decomposition invariants.

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::trainer::{DataParallelTrainer, FusedTrainer};

mod common;
use common::store;

fn config(precision: Precision, shards: usize) -> TrainConfig {
    TrainConfig {
        model: "vit_tiny".into(),
        precision,
        batch: 8,
        shards,
        seed: 3,
        log_every: 10_000,
        ..Default::default()
    }
}

#[test]
fn single_shard_ddp_tracks_fused() {
    // Same data, same recipe; one path fuses everything into the HLO
    // graph, the other decomposes (grads exe + Rust all-reduce +
    // Rust AdamW + Rust scaler).  Trajectories must track closely.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);

    let mut fused = FusedTrainer::new(&mut store, config(Precision::MixedF16, 1)).unwrap();
    let mut mf = RunMetrics::new();
    fused.run(&dataset, 15, &mut mf).unwrap();

    let mut ddp =
        DataParallelTrainer::new(&mut store, config(Precision::MixedF16, 1))
            .unwrap();
    let mut md = RunMetrics::new();
    ddp.run(&dataset, 15, &mut md).unwrap();

    for (a, b) in mf.records.iter().zip(&md.records) {
        assert!(
            (a.loss - b.loss).abs() < 0.12 * a.loss.abs().max(1.0),
            "step {}: fused {} vs ddp {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    // both ended converging
    assert!(md.recent_loss(3).unwrap() < md.records[0].loss);
}

#[test]
fn multi_shard_matches_single_shard_gradients() {
    // 4 shards × b2 over the same global batch of 8 must produce the
    // same mean gradient as 1 shard × b8 — verified indirectly: the
    // parameter trajectories stay close for several steps.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);

    // NOTE: grads artifacts exist for per-shard batch 8 only, so the
    // multi-shard run uses global batch 8×shards.  For a strict
    // apples-to-apples check we instead verify that two *identically
    // sharded* runs are bit-identical (determinism) and that sharded
    // training converges.
    let mut a =
        DataParallelTrainer::new(&mut store, config(Precision::MixedF16, 2))
            .unwrap();
    let mut ma = RunMetrics::new();
    a.run(&dataset, 10, &mut ma).unwrap();

    let mut b =
        DataParallelTrainer::new(&mut store, config(Precision::MixedF16, 2))
            .unwrap();
    let mut mb = RunMetrics::new();
    b.run(&dataset, 10, &mut mb).unwrap();

    for (x, y) in ma.records.iter().zip(&mb.records) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "sharded training not deterministic at step {}",
            x.step
        );
    }
    for (x, y) in a.masters.iter().zip(&b.masters) {
        assert_eq!(x, y, "master weights diverged");
    }
    assert!(ma.recent_loss(3).unwrap() < ma.records[0].loss * 0.8);
}

#[test]
fn fp32_ddp_never_skips() {
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);
    let mut t =
        DataParallelTrainer::new(&mut store, config(Precision::Fp32, 2))
            .unwrap();
    let mut m = RunMetrics::new();
    t.run(&dataset, 10, &mut m).unwrap();
    assert_eq!(m.skipped_steps(), 0);
    assert_eq!(t.loss_scale(), 1.0);
}

#[test]
fn scaler_recovers_after_natural_overflow() {
    // f16 with init scale 2^15 typically overflows in the first steps
    // of this model (observed in every run); the trainer must skip
    // those steps, halve the scale, and keep training to convergence.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);
    let mut t =
        DataParallelTrainer::new(&mut store, config(Precision::MixedF16, 1))
            .unwrap();
    let mut m = RunMetrics::new();
    t.run(&dataset, 30, &mut m).unwrap();
    // regardless of whether overflows happened, the invariant is that
    // every recorded loss is finite and the final model improved
    assert!(m.records.iter().all(|r| r.loss.is_finite()));
    assert!(m.recent_loss(5).unwrap() < m.records[0].loss * 0.6);
    if m.skipped_steps() > 0 {
        assert!(t.loss_scale() < 32768.0);
    }
}
