//! Property tests for the deep HLO frontend (`hlo::graph`) — the
//! parser the host backend executes, checked against the checked-in
//! artifacts and against the older shallow census parser.
//!
//! * **Fixpoint**: `parse → print → parse` is the identity on every
//!   `.hlo.txt` the repo ships, and the second print is byte-stable
//!   (printing is a normal form).
//! * **Census agreement**: for every array-shaped entry instruction
//!   the shallow parser sees, the deep parser reports the same dims,
//!   element count, and byte size.
//! * **Shape invariants**: `elems == ∏dims` and `bytes == elems ×
//!   dtype width` over randomly generated shapes (mini-proptest with
//!   shrinking).
//! * **Unknown opcodes** parse (the frontend is schemaless) but are
//!   rejected by the host backend with an error that names the
//!   opcode, so unsupported artifacts fail loudly, not mysteriously.

use mpx::hlo::graph::{GShape, HloProgram};
use mpx::hlo::HloModule;
use mpx::pytree::DType;
use mpx::runtime::host::HostExecutable;
use mpx::util::proptest::forall;
use mpx::util::rng::Rng;

/// Every checked-in artifact HLO text, or empty (with a note) when
/// `make artifacts` has not run.
fn artifact_hlo_texts() -> Vec<(String, String)> {
    let dir = std::env::var("MPX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let Ok(entries) = std::fs::read_dir(&dir) else {
        eprintln!("skipping: artifact directory {dir} not found");
        return Vec::new();
    };
    let mut out = Vec::new();
    for entry in entries.flatten() {
        let path = entry.path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if name.ends_with(".hlo.txt") {
            out.push((name, std::fs::read_to_string(&path).unwrap()));
        }
    }
    out.sort();
    assert!(
        out.is_empty() || out.len() >= 5,
        "artifact dir present but suspiciously sparse"
    );
    out
}

#[test]
fn parse_print_parse_is_a_fixpoint_on_all_artifacts() {
    for (name, text) in artifact_hlo_texts() {
        let p1 = HloProgram::parse(&text)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let printed = p1.print();
        let p2 = HloProgram::parse(&printed)
            .unwrap_or_else(|e| panic!("{name} (reprinted): {e:#}"));
        assert_eq!(p1, p2, "{name}: parse∘print not the identity");
        assert_eq!(
            printed,
            p2.print(),
            "{name}: print is not a normal form"
        );
    }
}

#[test]
fn deep_parser_agrees_with_shallow_census_on_all_artifacts() {
    for (name, text) in artifact_hlo_texts() {
        let deep = HloProgram::parse(&text).unwrap();
        let shallow = HloModule::parse(&text).unwrap();
        let entry = deep.entry().unwrap();
        for si in shallow.entry_instructions() {
            let Some(di) = entry.find(&si.name) else {
                panic!("{name}: shallow sees {} but deep does not", si.name);
            };
            let di = &entry.instrs[di];
            if let (Some(dt), GShape::Array { dtype, dims }) =
                (si.dtype, &di.shape)
            {
                assert_eq!(dt, *dtype, "{name}/{}: dtype", si.name);
                assert_eq!(&si.shape, dims, "{name}/{}: dims", si.name);
                assert_eq!(
                    si.elems(),
                    di.shape.elems(),
                    "{name}/{}: elems",
                    si.name
                );
                assert_eq!(
                    si.bytes(),
                    di.shape.bytes(),
                    "{name}/{}: bytes",
                    si.name
                );
            }
        }
    }
}

#[test]
fn shape_invariants_hold_for_random_shapes() {
    const DTYPES: [DType; 8] = [
        DType::F32,
        DType::F16,
        DType::Bf16,
        DType::S32,
        DType::U32,
        DType::S8,
        DType::U8,
        DType::Pred,
    ];
    forall(
        200,
        |rng: &mut Rng| {
            let rank = rng.below(5) as usize;
            let dims: Vec<usize> =
                (0..rank).map(|_| rng.below(9) as usize).collect();
            (rng.below(DTYPES.len() as u64) as usize, dims)
        },
        |&(dt_idx, ref dims)| {
            let dt = DTYPES[dt_idx % DTYPES.len()];
            // name → parse is the identity on every supported dtype
            let parsed =
                DType::parse(dt.name()).map_err(|e| format!("{e:#}"))?;
            if parsed != dt {
                return Err(format!("{:?} reparsed as {parsed:?}", dt));
            }
            let shape = GShape::Array { dtype: dt, dims: dims.clone() };
            let elems: usize = dims.iter().product();
            if shape.elems() != elems {
                return Err(format!(
                    "elems {} != ∏{dims:?}",
                    shape.elems()
                ));
            }
            if shape.bytes() != elems * dt.bytes() {
                return Err(format!(
                    "bytes {} != {} × {}",
                    shape.bytes(),
                    elems,
                    dt.bytes()
                ));
            }
            // The printed form round-trips through the parser inside
            // a one-instruction program.
            let text = format!(
                "HloModule m\n\nENTRY main {{\n  ROOT p = {} parameter(0)\n}}\n",
                shape.print()
            );
            let p = HloProgram::parse(&text).map_err(|e| format!("{e:#}"))?;
            let root = &p.computations[0].instrs[0];
            if root.shape != shape {
                return Err(format!("{:?} != {shape:?}", root.shape));
            }
            Ok(())
        },
    );
}

#[test]
fn unknown_opcode_parses_but_host_lowering_names_it() {
    let text = "HloModule m\n\nENTRY main {\n  p = f32[4] parameter(0)\n  \
                ROOT q = f32[4] frobnicate(p)\n}\n";
    // The frontend is schemaless — any opcode parses...
    let program = HloProgram::parse(text).unwrap();
    assert_eq!(program.entry().unwrap().instrs[1].opcode, "frobnicate");
    // ...and the host backend rejects it, naming the opcode.
    let err = format!("{:#}", HostExecutable::compile(text).unwrap_err());
    assert!(
        err.contains("frobnicate") && err.contains("unsupported opcode"),
        "error does not name the opcode: {err}"
    );
}
