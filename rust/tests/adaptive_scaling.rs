//! Adaptive per-layer loss scaling, end to end over the real
//! `vit_tiny` artifacts: the acceptance run from the issue.  A
//! deterministic injector lands a recurring gradient spike in one
//! layer group; the per-layer adaptive policy must finish with
//! strictly fewer skipped steps than the global dynamic policy — and
//! train at least as well — because it backs the spiked group off
//! once and pins it there (headroom gate), while global dynamic
//! re-grows into the spike every period.
//!
//! No sleeps, no randomness outside the seeded dataset/injector: both
//! runs are pure functions of (seed, schedule).

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::scaling::{
    AdaptiveTuning, OverflowInjector, PolicyKind, ScalingConfig,
    ScalingPolicy, ScalingSpec,
};
use mpx::trainer::DataParallelTrainer;

mod common;
use common::store;

/// Short growth period so both policies cycle their state machines
/// many times inside a ~90-step run.
fn spec(kind: PolicyKind) -> ScalingSpec {
    ScalingSpec {
        kind,
        base: ScalingConfig { period: 5, ..Default::default() },
        tuning: AdaptiveTuning::default(),
    }
}

fn config(kind: PolicyKind) -> TrainConfig {
    TrainConfig {
        model: "vit_tiny".into(),
        precision: Precision::MixedF16,
        batch: 8,
        shards: 2,
        seed: 3,
        log_every: 10_000,
        scaling: Some(spec(kind)),
        ..Default::default()
    }
}

/// Spike |g| = 64 in `blocks[0]` every 5 steps.  Scale-conditioned:
/// overflows while the group's scale is ≥ 1024 (64·1024 ≥ 65520),
/// harmless at ≤ 512.
fn injector() -> OverflowInjector {
    OverflowInjector::GroupSpike {
        group: "blocks[0]".into(),
        steps: (0..90).step_by(5).collect(),
        magnitude: 64.0,
    }
}

#[test]
fn adaptive_outruns_global_dynamic_under_recurring_spike() {
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);
    let steps = 90;

    let mut dynamic =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Dynamic))
            .unwrap();
    dynamic.set_injector(injector()).unwrap();
    let mut md = RunMetrics::new();
    dynamic.run(&dataset, steps, &mut md).unwrap();

    let mut adaptive =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Adaptive))
            .unwrap();
    adaptive.set_injector(injector()).unwrap();
    let mut ma = RunMetrics::new();
    adaptive.run(&dataset, steps, &mut ma).unwrap();

    // The headline: strictly fewer skipped steps.  Dynamic descends
    // 32768 → 512 (6 skips) and then re-grows into the spike every
    // other interval; adaptive pays the descent once and converges.
    assert!(
        ma.skipped_steps() < md.skipped_steps(),
        "adaptive skipped {} vs dynamic {}",
        ma.skipped_steps(),
        md.skipped_steps()
    );
    // And it trains at least as well (more applied optimizer steps).
    let la = ma.recent_loss(10).unwrap();
    let ld = md.recent_loss(10).unwrap();
    assert!(la.is_finite() && ld.is_finite());
    assert!(
        la <= ld + 0.05 * ld.abs().max(1.0),
        "adaptive final loss {la} worse than dynamic {ld}"
    );
    // The targeted group ended below the spike-overflow boundary; the
    // graph scale follows the most constrained group.
    let b0 = adaptive
        .groups()
        .iter()
        .position(|g| g == "blocks[0]")
        .unwrap();
    assert!(
        adaptive.policy.scale_of(b0) <= 512.0,
        "spiked group at {}",
        adaptive.policy.scale_of(b0)
    );
    assert!(adaptive.loss_scale() <= adaptive.policy.scale_of(b0));
    // Dynamic's single global scale was dragged down for every layer.
    assert_eq!(dynamic.policy.groups().len(), 1);
}

#[test]
fn injector_rejects_unknown_group() {
    let Some(mut store) = store() else { return };
    let mut t =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Adaptive))
            .unwrap();
    let err = t
        .set_injector(OverflowInjector::GroupSpike {
            group: "no_such_layer".into(),
            steps: vec![0],
            magnitude: 64.0,
        })
        .unwrap_err();
    assert!(err.to_string().contains("unknown group"), "{err}");
    // The derived groups are real layer names.
    assert!(t.groups().iter().any(|g| g.starts_with("blocks")), "{:?}", t.groups());
}

#[test]
fn ddp_checkpoint_roundtrip_resumes_bit_identically() {
    // Schema v2 round-trip through the adaptive policy: masters,
    // AdamW moments, and the per-group scaler record all restore, and
    // the resumed trajectory is bit-identical to the uninterrupted one.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);

    let dir = std::env::temp_dir().join("mpx_adaptive_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    let path = path.to_str().unwrap().to_string();

    let mut t =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Adaptive))
            .unwrap();
    let mut m = RunMetrics::new();
    t.run(&dataset, 10, &mut m).unwrap();
    t.save_checkpoint(&path).unwrap();
    let saved_rows = t.scaling_rows();

    // continue the original
    let mut m1 = RunMetrics::new();
    t.run(&dataset, 5, &mut m1).unwrap();

    // restore into a fresh trainer and continue
    let mut t2 =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Adaptive))
            .unwrap();
    t2.resume(&path).unwrap();
    assert_eq!(t2.step_index, 10);
    assert_eq!(t2.scaling_rows().len(), saved_rows.len());
    for ((name, scale, _), (name2, scale2, _)) in
        saved_rows.iter().zip(t2.scaling_rows())
    {
        assert_eq!(*name, name2);
        assert_eq!(scale.to_bits(), scale2.to_bits(), "scale for {name}");
    }
    for (a, b) in t.masters.iter().zip(&t2.masters) {
        // t has advanced 5 steps past the checkpoint; compare t2
        // against the checkpointed state indirectly by replaying.
        assert_eq!(a.len(), b.len());
    }
    let mut m2 = RunMetrics::new();
    t2.run(&dataset, 5, &mut m2).unwrap();
    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "resume diverged at step {}",
            a.step
        );
        assert_eq!(a.loss_scale.to_bits(), b.loss_scale.to_bits());
        assert_eq!(a.grads_finite, b.grads_finite);
    }
    for (a, b) in t.masters.iter().zip(&t2.masters) {
        assert_eq!(a, b, "master weights diverged after resume");
    }
}

#[test]
fn global_scaler_record_fans_out_into_adaptive_on_resume() {
    // The v1-migration path exercised through the trainer: a
    // checkpoint holding a single global scaler record (what a v1
    // file migrates to, and what the dynamic policy writes) resumes
    // into an adaptive run by fanning the global scale out to every
    // layer group.
    let Some(mut store) = store() else { return };
    let preset = model_preset("vit_tiny").unwrap();
    let dataset = SyntheticDataset::new(&preset, 3);

    let dir = std::env::temp_dir().join("mpx_adaptive_ckpt_fanout");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    let path = path.to_str().unwrap().to_string();

    let mut dynamic =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Dynamic))
            .unwrap();
    let mut m = RunMetrics::new();
    dynamic.run(&dataset, 8, &mut m).unwrap();
    let global_scale = dynamic.loss_scale();
    dynamic.save_checkpoint(&path).unwrap();

    let mut adaptive =
        DataParallelTrainer::new(&mut store, config(PolicyKind::Adaptive))
            .unwrap();
    adaptive.resume(&path).unwrap();
    assert_eq!(adaptive.step_index, 8);
    assert!(adaptive.policy.groups().len() > 1);
    for g in 0..adaptive.policy.groups().len() {
        assert_eq!(
            adaptive.policy.scale_of(g).to_bits(),
            global_scale.to_bits(),
            "group {g} did not inherit the global scale"
        );
    }
    // And it keeps training from there.
    let mut m2 = RunMetrics::new();
    adaptive.run(&dataset, 3, &mut m2).unwrap();
    assert!(m2.records.iter().all(|r| r.loss.is_finite()));
}
