//! Ablation — what does dynamic loss scaling cost, and what does the
//! per-layer adaptive policy buy over it?  (DESIGN.md design-choice
//! ablations; not a paper figure.)
//!
//! Series:
//!   1. fused step time across fp32 / mixed_f16 / mixed_bf16 on the
//!      tiny model — bf16 runs the identical graph shape with the
//!      scaling state pinned, so (f16 − bf16) isolates the cost of
//!      live dynamic scaling, and (bf16 − fp32) the cost of casting.
//!   2. the controller itself in isolation (pure state machine) —
//!      confirming its per-step cost is nanoseconds, i.e. the §3.3
//!      heuristic is free at the coordinator level.
//!   3. adaptive vs global dynamic under an identical recurring
//!      scale-conditioned spike — first as a pure policy simulation
//!      (always runs), then end-to-end over the vit_tiny artifacts
//!      with the data-parallel trainer and a `GroupSpike` injector
//!      (skipped when `make artifacts` has not run).
//!
//! Emits `BENCH_ablation_scaling.json` in all cases — the sim entries
//! keep the report meaningful on artifact-less CI runners.

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::scaling::{
    spike_overflows, AdaptivePolicy, AdaptiveTuning, GroupStats, LossScaler,
    OverflowInjector, PolicyKind, ScalingConfig, ScalingPolicy, ScalingSpec,
};
use mpx::trainer::{DataParallelTrainer, FusedTrainer};
use mpx::util::benchkit::{bench, BenchOpts, JsonReport, Table};

const SPIKE_EVERY: u64 = 5;
const SPIKE_MAGNITUDE: f32 = 64.0;

fn short_period() -> ScalingConfig {
    ScalingConfig { period: SPIKE_EVERY as u32, ..Default::default() }
}

/// Policy-level replay of the recurring-spike schedule: one layer
/// group produces |g| = 64 every `SPIKE_EVERY` steps; whether it
/// overflows depends on that group's *current* scale.  Global dynamic
/// re-grows into the spike forever; adaptive pays the descent once.
fn sim_section(report: &mut JsonReport, steps: u64) {
    let mut dynamic = LossScaler::new(short_period());
    let mut dyn_skips = 0u64;
    for step in 0..steps {
        let overflow = step % SPIKE_EVERY == 0
            && spike_overflows(SPIKE_MAGNITUDE, dynamic.scale());
        if !dynamic.adjust(!overflow) {
            dyn_skips += 1;
        }
    }
    report.entry(
        "dynamic_sim",
        &[
            ("steps", steps as f64),
            ("skipped", dyn_skips as f64),
            ("growths", dynamic.growths as f64),
            ("final_scale", dynamic.scale() as f64),
        ],
    );

    let names: Vec<String> =
        (0..3).map(|i| format!("blocks[{i}]")).collect();
    let mut adaptive = AdaptivePolicy::new(
        short_period(),
        AdaptiveTuning::default(),
        names,
    );
    let clean = GroupStats {
        count: 1000,
        max_abs: 1e-3,
        underflow: 0,
        overflow: 0,
        finite: true,
    };
    let mut ada_skips = 0u64;
    for step in 0..steps {
        let mut stats = vec![clean; 3];
        if step % SPIKE_EVERY == 0 {
            stats[1].max_abs = SPIKE_MAGNITUDE;
            stats[1].overflow =
                spike_overflows(SPIKE_MAGNITUDE, adaptive.scale_of(1)) as u64;
        }
        if !adaptive.adjust(true, &stats) {
            ada_skips += 1;
        }
    }
    report.entry(
        "adaptive_sim",
        &[
            ("steps", steps as f64),
            ("skipped", ada_skips as f64),
            ("growths", adaptive.growths() as f64),
            ("final_graph_scale", adaptive.graph_scale() as f64),
            ("spiked_group_scale", adaptive.scale_of(1) as f64),
        ],
    );
    println!(
        "# sim over {steps} steps: dynamic skipped {dyn_skips}, adaptive \
         skipped {ada_skips}"
    );
}

/// End-to-end over the compiled vit_tiny artifacts: same spike
/// schedule through both policies of the data-parallel trainer.
fn artifact_section(report: &mut JsonReport, steps: u64) -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let preset = model_preset("vit_tiny")?;
    let dataset = SyntheticDataset::new(&preset, 3);

    let mut table = Table::new(
        "Ablation: adaptive vs dynamic scaling on vit_tiny (ddp x2, spiked)",
        &["policy", "skipped", "final_loss", "graph_scale"],
    );
    for kind in [PolicyKind::Dynamic, PolicyKind::Adaptive] {
        let cfg = TrainConfig {
            model: "vit_tiny".into(),
            precision: Precision::MixedF16,
            batch: 8,
            shards: 2,
            seed: 3,
            log_every: 10_000,
            scaling: Some(ScalingSpec {
                kind,
                base: short_period(),
                tuning: AdaptiveTuning::default(),
            }),
            ..Default::default()
        };
        let mut trainer = DataParallelTrainer::new(&mut store, cfg)?;
        trainer.set_injector(OverflowInjector::GroupSpike {
            group: "blocks[0]".into(),
            steps: (0..steps).step_by(SPIKE_EVERY as usize).collect(),
            magnitude: SPIKE_MAGNITUDE,
        })?;
        let mut metrics = RunMetrics::new();
        trainer.run(&dataset, steps, &mut metrics)?;
        let final_loss = metrics.recent_loss(10).unwrap_or(f32::NAN);
        table.row(&[
            kind.tag().to_string(),
            metrics.skipped_steps().to_string(),
            format!("{final_loss:.4}"),
            format!("{:.0}", trainer.loss_scale()),
        ]);
        report.entry(
            &format!("{}_vit_tiny", kind.tag()),
            &[
                ("steps", steps as f64),
                ("skipped", metrics.skipped_steps() as f64),
                ("final_loss", final_loss as f64),
                ("graph_scale", trainer.loss_scale() as f64),
            ],
        );
    }
    println!("# wrote {}", table.write_csv()?);

    // Precision-mode table over the fused trainer (the original
    // casting/scaling cost ablation).
    let mut table = Table::new(
        "Ablation: precision modes on vit_tiny (fused step, b8)",
        &["precision", "median_step_ms", "skipped", "final_scale"],
    );
    for precision in
        [Precision::Fp32, Precision::MixedBf16, Precision::MixedF16]
    {
        let cfg = TrainConfig {
            model: "vit_tiny".into(),
            precision,
            batch: 8,
            log_every: 10_000,
            ..Default::default()
        };
        let mut trainer = FusedTrainer::new(&mut store, cfg)?;
        let mut metrics = RunMetrics::new();
        trainer.run(&dataset, 30, &mut metrics)?;
        let mut times: Vec<f64> = metrics
            .records
            .iter()
            .skip(3)
            .map(|r| r.step_time.as_secs_f64())
            .collect();
        times.sort_by(f64::total_cmp);
        table.row(&[
            precision.tag().to_string(),
            format!("{:.3}", times[times.len() / 2] * 1e3),
            metrics.skipped_steps().to_string(),
            format!("{:.0}", trainer.loss_scale()?),
        ]);
    }
    println!("# wrote {}", table.write_csv()?);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1");
    let steps: u64 = if smoke { 30 } else { 90 };
    let mut report = JsonReport::new("ablation_scaling");

    sim_section(&mut report, 200);

    // The artifact-backed sections need `make artifacts`; skip (the
    // sim entries above keep the report valid) when they are absent.
    if let Err(e) = artifact_section(&mut report, steps) {
        println!("# skipping artifact ablation: {e:#}");
    }

    // Controller-in-isolation micro-bench.
    let opts = BenchOpts::from_env(BenchOpts {
        warmup_iters: 2,
        max_iters: 20,
        max_seconds: 2.0,
    });
    let mut scaler = LossScaler::new(ScalingConfig::default());
    let mut i = 0u64;
    let stats = bench(&opts, || {
        // 1M adjust calls per iteration
        for _ in 0..1_000_000 {
            i = i.wrapping_add(1);
            scaler.adjust(i % 1009 != 0);
        }
    });
    let mut micro = Table::new(
        "Ablation: LossScaler.adjust micro-cost",
        &["calls_per_iter", "median_ms_per_1M", "ns_per_call"],
    );
    micro.row(&[
        "1000000".into(),
        format!("{:.2}", stats.median.as_secs_f64() * 1e3),
        format!("{:.2}", stats.median.as_secs_f64() * 1e9 / 1e6),
    ]);
    println!("# wrote {}", micro.write_csv()?);
    report.entry(
        "loss_scaler_adjust",
        &[(
            "ns_per_call",
            stats.median.as_secs_f64() * 1e9 / 1e6,
        )],
    );
    println!("# scaler state: {} growths, {} overflows", scaler.growths,
             scaler.overflows);
    println!("# wrote {}", report.write()?);
    Ok(())
}
