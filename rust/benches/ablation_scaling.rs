//! Ablation — what does dynamic loss scaling cost, and what do the
//! precision modes trade?  (DESIGN.md design-choice ablations; not a
//! paper figure.)
//!
//! Series:
//!   1. fused step time across fp32 / mixed_f16 / mixed_bf16 on the
//!      tiny model — bf16 runs the identical graph shape with the
//!      scaling state pinned, so (f16 − bf16) isolates the cost of
//!      live dynamic scaling, and (bf16 − fp32) the cost of casting.
//!   2. the controller itself in isolation (pure state machine) —
//!      confirming its per-step cost is nanoseconds, i.e. the §3.3
//!      heuristic is free at the coordinator level.

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::scaling::{LossScaler, ScalingConfig};
use mpx::trainer::FusedTrainer;
use mpx::util::benchkit::{bench, BenchOpts, Table};

fn main() -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let preset = model_preset("vit_tiny")?;
    let dataset = SyntheticDataset::new(&preset, 0);

    let mut table = Table::new(
        "Ablation: precision modes on vit_tiny (fused step, b8)",
        &["precision", "median_step_ms", "skipped", "final_scale"],
    );
    for precision in
        [Precision::Fp32, Precision::MixedBf16, Precision::MixedF16]
    {
        let cfg = TrainConfig {
            model: "vit_tiny".into(),
            precision,
            batch: 8,
            log_every: 10_000,
            ..Default::default()
        };
        let mut trainer = FusedTrainer::new(&mut store, cfg)?;
        let mut metrics = RunMetrics::new();
        trainer.run(&dataset, 30, &mut metrics)?;
        let mut times: Vec<f64> = metrics
            .records
            .iter()
            .skip(3)
            .map(|r| r.step_time.as_secs_f64())
            .collect();
        times.sort_by(f64::total_cmp);
        table.row(&[
            precision.tag().to_string(),
            format!("{:.3}", times[times.len() / 2] * 1e3),
            metrics.skipped_steps().to_string(),
            format!("{:.0}", trainer.loss_scale()?),
        ]);
    }
    println!("# wrote {}", table.write_csv()?);

    // Controller-in-isolation micro-bench.
    let mut scaler = LossScaler::new(ScalingConfig::default());
    let mut i = 0u64;
    let stats = bench(
        &BenchOpts { warmup_iters: 2, max_iters: 20, max_seconds: 2.0 },
        || {
            // 1M adjust calls per iteration
            for _ in 0..1_000_000 {
                i = i.wrapping_add(1);
                scaler.adjust(i % 1009 != 0);
            }
        },
    );
    let mut micro = Table::new(
        "Ablation: LossScaler.adjust micro-cost",
        &["calls_per_iter", "median_ms_per_1M", "ns_per_call"],
    );
    micro.row(&[
        "1000000".into(),
        format!("{:.2}", stats.median.as_secs_f64() * 1e3),
        format!("{:.2}", stats.median.as_secs_f64() * 1e9 / 1e6),
    ]);
    println!("# wrote {}", micro.write_csv()?);
    println!("# scaler state: {} growths, {} overflows", scaler.growths,
             scaler.overflows);
    Ok(())
}
