//! Fig. 2 — GPU VRAM vs number of batches, full vs mixed precision.
//!
//! Paper series: desktop PC (RTX4070), ViT-desktop on CIFAR-100,
//! VRAM measured with XLA preallocation off; headline 1.8× reduction.
//!
//! Our testbed has no VRAM, so the figure is regenerated from the two
//! independent estimators (DESIGN.md §memmodel): the analytic
//! activation model and the HLO census of the actual compiled
//! artifacts.  Expected shape: linear in batch; mixed slope ≈ ½;
//! ratio → ~1.8–2.0 at large batch.

use mpx::config::{Precision, VIT_DESKTOP};
use mpx::hlo::HloModule;
use mpx::memmodel::ActivationModel;
use mpx::runtime::ArtifactStore;
use mpx::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let am = ActivationModel::new(VIT_DESKTOP);

    let mut table = Table::new(
        "Fig2: memory vs batch (vit_desktop, analytic model)",
        &[
            "batch",
            "fp32_bytes",
            "mixed_bytes",
            "fp32_MiB",
            "mixed_MiB",
            "ratio",
        ],
    );
    for b in [8usize, 16, 32, 64, 128, 256] {
        let full = am.estimate(Precision::Fp32, b).total_bytes();
        let mixed = am.estimate(Precision::MixedF16, b).total_bytes();
        table.row(&[
            b.to_string(),
            full.to_string(),
            mixed.to_string(),
            format!("{:.1}", full as f64 / (1 << 20) as f64),
            format!("{:.1}", mixed as f64 / (1 << 20) as f64),
            format!("{:.2}", full as f64 / mixed as f64),
        ]);
    }
    let csv = table.write_csv()?;
    println!("# wrote {csv}");

    // Cross-check against the artifacts actually compiled.
    let store = ArtifactStore::open_default()?;
    let mut census = Table::new(
        "Fig2 cross-check: HLO census of compiled step artifacts",
        &["batch", "fp32_ws_bytes", "mixed_ws_bytes", "ratio"],
    );
    for b in [8usize, 16, 32, 64, 128] {
        let f: u64 = HloModule::parse(
            &store.hlo_text(&format!("step_fused_vit_desktop_fp32_b{b}"))?,
        )?
        .workspace_bytes_by_dtype()
        .values()
        .sum();
        let m: u64 = HloModule::parse(
            &store
                .hlo_text(&format!("step_fused_vit_desktop_mixed_f16_b{b}"))?,
        )?
        .workspace_bytes_by_dtype()
        .values()
        .sum();
        census.row(&[
            b.to_string(),
            f.to_string(),
            m.to_string(),
            format!("{:.2}", f as f64 / m as f64),
        ]);
    }
    let csv = census.write_csv()?;
    println!("# wrote {csv}");

    println!(
        "\n# paper Fig2 headline: 1.8x VRAM reduction at the largest batch"
    );
    println!(
        "# model ratio at batch 256: {:.2}x  (census at 128: see table)",
        am.reduction_ratio(256)
    );
    Ok(())
}
