//! L3 runtime overhead — how much of a training step is coordinator
//! (literal packing, tuple decompose, host hops) vs XLA compute?
//!
//! This PJRT build returns one tuple buffer per execution and takes
//! literal inputs, so every step pays: batch literal creation +
//! state literal pass-in + output tuple fetch + decompose.  The bench
//! isolates each cost; §Perf tracks the coordinator share (target:
//! L3 not the bottleneck — well under 10% on the desktop model).

use std::time::Instant;

use mpx::config::{model_preset, Precision, TrainConfig};
use mpx::data::SyntheticDataset;
use mpx::metrics::RunMetrics;
use mpx::runtime::{lit_f32, lit_i32, ArtifactStore};
use mpx::trainer::FusedTrainer;
use mpx::util::benchkit::{bench, BenchOpts, Table};

fn main() -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let opts = BenchOpts::from_env(BenchOpts {
        warmup_iters: 3,
        max_iters: 30,
        max_seconds: 6.0,
    });

    let mut table = Table::new(
        "L3 runtime overhead breakdown",
        &["component", "median_us", "notes"],
    );

    // 1. batch literal creation (vit_desktop b64: 768 KiB images)
    let preset = model_preset("vit_desktop")?;
    let dataset = SyntheticDataset::new(&preset, 0);
    let batch = dataset.batch(0, 64, 0);
    let stats = bench(&opts, || {
        let _ = lit_f32(&[64, 3, 32, 32], &batch.images).unwrap();
        let _ = lit_i32(&[64], &batch.labels).unwrap();
    });
    table.row(&[
        "batch_literals_b64".into(),
        format!("{:.1}", stats.median.as_secs_f64() * 1e6),
        "images+labels memcpy".into(),
    ]);

    // 2. batch generation itself (hidden by the prefetcher in runs)
    let stats = bench(&opts, || {
        let _ = dataset.batch(1, 64, 0);
    });
    table.row(&[
        "synthetic_batch_gen_b64".into(),
        format!("{:.1}", stats.median.as_secs_f64() * 1e6),
        "overlapped by Prefetcher".into(),
    ]);

    // 3. end-to-end tiny step vs its pieces: execute a trivial
    //    artifact (init) to approximate the fixed PJRT dispatch cost.
    let init = store.load("init_vit_tiny_fp32")?;
    let seed = [mpx::runtime::lit_scalar_i32(0)];
    let stats = bench(&opts, || {
        let _ = init.execute(&seed).unwrap();
    });
    table.row(&[
        "init_vit_tiny_exec".into(),
        format!("{:.1}", stats.median.as_secs_f64() * 1e6),
        "dispatch + 123-leaf tuple fetch".into(),
    ]);

    // 4. full fused step (vit_desktop b64 mixed) with component timing
    let cfg = TrainConfig {
        model: "vit_desktop".into(),
        precision: Precision::MixedF16,
        batch: 64,
        log_every: 10_000,
        ..Default::default()
    };
    let mut trainer = FusedTrainer::new(&mut store, cfg)?;
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, 8, &mut metrics)?;
    let step_ms = metrics
        .mean_step_time(2)
        .unwrap()
        .as_secs_f64()
        * 1e3;

    // overhead share estimate: batch literals measured above
    let t0 = Instant::now();
    for _ in 0..10 {
        let _ = lit_f32(&[64, 3, 32, 32], &batch.images).unwrap();
    }
    let lit_ms = t0.elapsed().as_secs_f64() / 10.0 * 1e3;

    table.row(&[
        "fused_step_desktop_b64".into(),
        format!("{:.1}", step_ms * 1e3),
        "whole step (XLA + L3)".into(),
    ]);
    table.row(&[
        "coordinator_share".into(),
        format!("{:.1}", lit_ms * 1e3),
        format!("{:.2}% of step", lit_ms / step_ms * 100.0),
    ]);
    println!("# wrote {}", table.write_csv()?);
    Ok(())
}
