//! Fig. 3 — training-step time vs number of batches, full vs mixed.
//!
//! Paper series:
//!   (a) desktop (RTX4070, ViT-desktop/CIFAR-100): mixed 1.7× faster,
//!       attributed to halved memory traffic (no half-compute speedup
//!       on that GPU);
//!   (b) cluster (4×H100, ViT-Base/ImageNet, data parallel): mixed up
//!       to 1.57× faster.
//!
//! Here each point is measured honestly on the CPU PJRT backend
//! (median of several steps after warmup) and printed next to the
//! roofline projection for the paper's machines.  Absolute numbers
//! differ from the paper (different hardware); the comparison series
//! and who-wins must match.
//!
//! Env knobs: MPX_BENCH_FULL=1 → more iterations + larger batches.

use mpx::config::{
    model_preset, Precision, TrainConfig, MACHINE_CLUSTER, MACHINE_DESKTOP,
};
use mpx::data::SyntheticDataset;
use mpx::memmodel::roofline;
use mpx::metrics::RunMetrics;
use mpx::runtime::ArtifactStore;
use mpx::trainer::{DataParallelTrainer, FusedTrainer};
use mpx::util::benchkit::Table;

/// Median fused-step seconds for (model, precision, batch).
fn measure_fused(
    store: &mut ArtifactStore,
    model: &str,
    precision: Precision,
    batch: usize,
    steps: u64,
) -> anyhow::Result<f64> {
    let cfg = TrainConfig {
        model: model.into(),
        precision,
        batch,
        log_every: 10_000,
        ..Default::default()
    };
    let preset = model_preset(model)?;
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = FusedTrainer::new(store, cfg)?;
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, steps, &mut metrics)?;
    let mut times: Vec<f64> = metrics
        .records
        .iter()
        .skip(2) // warmup: first executions page in the executable
        .map(|r| r.step_time.as_secs_f64())
        .collect();
    times.sort_by(f64::total_cmp);
    Ok(times[times.len() / 2])
}

fn measure_ddp(
    store: &mut ArtifactStore,
    model: &str,
    precision: Precision,
    per_shard_batch: usize,
    shards: usize,
    steps: u64,
) -> anyhow::Result<f64> {
    let cfg = TrainConfig {
        model: model.into(),
        precision,
        batch: per_shard_batch,
        shards,
        log_every: 10_000,
        ..Default::default()
    };
    let preset = model_preset(model)?;
    let dataset = SyntheticDataset::new(&preset, 0);
    let mut trainer = DataParallelTrainer::new(store, cfg)?;
    let mut metrics = RunMetrics::new();
    trainer.run(&dataset, steps, &mut metrics)?;
    let mut times: Vec<f64> = metrics
        .records
        .iter()
        .skip(1)
        .map(|r| r.step_time.as_secs_f64())
        .collect();
    times.sort_by(f64::total_cmp);
    Ok(times[times.len() / 2])
}

fn main() -> anyhow::Result<()> {
    let full_mode = std::env::var("MPX_BENCH_FULL").as_deref() == Ok("1");
    // MPX_FIG3_PART=desktop|cluster|all (default all) — the two parts
    // have very different footprints (vit_base is heavy on CPU).
    let part = std::env::var("MPX_FIG3_PART").unwrap_or_else(|_| "all".into());
    let mut store = ArtifactStore::open_default()?;

    if part == "desktop" || part == "all" {
        run_desktop(&mut store, full_mode)?;
    }
    if part == "cluster" || part == "all" {
        run_cluster(&mut store, full_mode)?;
    }
    Ok(())
}

fn run_desktop(
    store: &mut ArtifactStore,
    full_mode: bool,
) -> anyhow::Result<()> {
    // ---------- (a) desktop ------------------------------------------------
    // default sweep kept CPU-friendly; MPX_BENCH_FULL=1 extends to the
    // paper's larger batch points (EXPERIMENTS.md records a full run)
    let batches: &[usize] =
        if full_mode { &[8, 16, 32, 64, 128] } else { &[8, 16, 32] };
    let steps = if full_mode { 12 } else { 5 };

    let mut table = Table::new(
        "Fig3a: step time vs batch (vit_desktop, measured CPU + projected RTX4070)",
        &[
            "batch",
            "fp32_ms",
            "mixed_ms",
            "speedup",
            "proj4070_fp32_ms",
            "proj4070_mixed_ms",
            "proj_speedup",
        ],
    );
    for &b in batches {
        let t_full =
            measure_fused(store, "vit_desktop", Precision::Fp32, b, steps)?;
        let t_mixed = measure_fused(
            store,
            "vit_desktop",
            Precision::MixedF16,
            b,
            steps,
        )?;
        let preset = model_preset("vit_desktop")?;
        let pf = roofline::projected_step_time(
            &roofline::step_work(&preset, Precision::Fp32, b),
            &MACHINE_DESKTOP,
            Precision::Fp32,
        );
        let pm = roofline::projected_step_time(
            &roofline::step_work(&preset, Precision::MixedF16, b),
            &MACHINE_DESKTOP,
            Precision::MixedF16,
        );
        table.row(&[
            b.to_string(),
            format!("{:.2}", t_full * 1e3),
            format!("{:.2}", t_mixed * 1e3),
            format!("{:.2}", t_full / t_mixed),
            format!("{:.2}", pf * 1e3),
            format!("{:.2}", pm * 1e3),
            format!("{:.2}", pf / pm),
        ]);
    }
    println!("# wrote {}", table.write_csv()?);
    println!("# paper Fig3a headline: mixed 1.7x faster on the desktop");
    Ok(())
}

fn run_cluster(
    store: &mut ArtifactStore,
    full_mode: bool,
) -> anyhow::Result<()> {
    // ---------- (b) cluster ------------------------------------------------
    // ViT-Base on CPU is heavy; per-shard batch 1, 4 shards ≙ 4 H100s.
    let mut cluster = Table::new(
        "Fig3b: step time (vit_base, 4-shard DDP measured CPU + projected H100)",
        &[
            "per_shard_batch",
            "mode",
            "fp32_ms",
            "mixed_ms",
            "speedup",
            "projH100_speedup",
        ],
    );
    let base_steps = if full_mode { 4 } else { 2 };
    let points: &[(usize, usize, &str)] = if full_mode {
        &[(1, 4, "ddp4"), (2, 1, "fused")]
    } else {
        &[(1, 4, "ddp4")]
    };
    for &(b, shards, mode) in points {
        let (t_full, t_mixed) = if shards > 1 {
            (
                measure_ddp(store, "vit_base", Precision::Fp32, b,
                            shards, base_steps)?,
                measure_ddp(store, "vit_base", Precision::MixedF16, b,
                            shards, base_steps)?,
            )
        } else {
            (
                measure_fused(store, "vit_base", Precision::Fp32, b,
                              base_steps)?,
                measure_fused(store, "vit_base", Precision::MixedF16, b,
                              base_steps)?,
            )
        };
        let preset = model_preset("vit_base")?;
        let proj = roofline::projected_speedup(&preset, &MACHINE_CLUSTER,
                                               b * shards * 16);
        cluster.row(&[
            b.to_string(),
            mode.to_string(),
            format!("{:.0}", t_full * 1e3),
            format!("{:.0}", t_mixed * 1e3),
            format!("{:.2}", t_full / t_mixed),
            format!("{:.2}", proj),
        ]);
    }
    println!("# wrote {}", cluster.write_csv()?);
    println!("# paper Fig3b headline: mixed up to 1.57x faster on 4xH100");
    println!("# (roofline projects the 2.0x compute ceiling; the paper's 1.57x");
    println!("#  reflects Amdahl losses the pure roofline upper-bounds)");
    Ok(())
}
