//! Serving throughput — fp32 vs mixed_f16 vs mixed_bf16 at bounded
//! tail latency.
//!
//! Protocol per precision:
//!
//! 1. *Calibrate*: a closed-loop back-to-back run measures the
//!    service capacity (achievable req/s) and its p50.
//! 2. *Sweep*: open-loop Poisson runs at 50/70/90 % of that capacity;
//!    each reports achieved throughput and p50/p95/p99 from the
//!    rank-interpolated histogram.
//! 3. *Headline*: the highest offered load whose p99 stays under
//!    3× the calibrated p50 — "throughput at fixed p99".
//!
//! Precisions whose artifacts are missing (e.g. no bf16 forwards
//! built) are skipped with a note, not failed.

use mpx::config::{Precision, ServeConfig};
use mpx::runtime::ArtifactStore;
use mpx::serve;
use mpx::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let requests: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut table = Table::new(
        "serve throughput by precision",
        &[
            "precision",
            "mode",
            "offered_rps",
            "achieved_rps",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "rejected",
        ],
    );

    for precision in
        [Precision::Fp32, Precision::MixedF16, Precision::MixedBf16]
    {
        let base = ServeConfig {
            precision,
            requests,
            workers: 2,
            arrival_rate: 0.0,
            open_loop: false,
            ..ServeConfig::default()
        };

        // 1. closed-loop calibration
        let cal = match serve::run_with_artifacts(&mut store, &base) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("# skip {}: {e:#}", precision.tag());
                continue;
            }
        };
        let capacity = cal.throughput_rps();
        let Some(cs) = cal.latency.summary() else { continue };
        table.row(&[
            precision.tag().into(),
            "closed".into(),
            "-".into(),
            format!("{capacity:.1}"),
            format!("{:.2}", cs.p50.as_secs_f64() * 1e3),
            format!("{:.2}", cs.p95.as_secs_f64() * 1e3),
            format!("{:.2}", cs.p99.as_secs_f64() * 1e3),
            format!("{}", cal.queue.rejected),
        ]);

        // 2. open-loop sweep at fractions of capacity
        let p99_bound = cs.p50.as_secs_f64() * 3.0;
        let mut headline: Option<(f64, f64)> = None;
        for frac in [0.5, 0.7, 0.9] {
            let cfg = ServeConfig {
                open_loop: true,
                arrival_rate: capacity * frac,
                ..base.clone()
            };
            let rep = serve::run_with_artifacts(&mut store, &cfg)?;
            let Some(s) = rep.latency.summary() else { continue };
            table.row(&[
                precision.tag().into(),
                format!("open@{:.0}%", frac * 100.0),
                format!("{:.1}", cfg.arrival_rate),
                format!("{:.1}", rep.throughput_rps()),
                format!("{:.2}", s.p50.as_secs_f64() * 1e3),
                format!("{:.2}", s.p95.as_secs_f64() * 1e3),
                format!("{:.2}", s.p99.as_secs_f64() * 1e3),
                format!("{}", rep.queue.rejected),
            ]);
            if s.p99.as_secs_f64() <= p99_bound {
                headline = Some((frac, rep.throughput_rps()));
            }
        }

        // 3. headline
        match headline {
            Some((frac, thr)) => println!(
                "# {}: sustains {:.1} req/s at {:.0}% load with p99 ≤ 3×p50",
                precision.tag(),
                thr,
                frac * 100.0
            ),
            None => println!(
                "# {}: no swept load held p99 ≤ 3×p50 ({:.2} ms)",
                precision.tag(),
                p99_bound * 1e3
            ),
        }
    }
    println!("# wrote {}", table.write_csv()?);
    Ok(())
}
