//! Serving throughput — continuous batching vs the form-then-execute
//! loop, and fp32 vs mixed_f16 vs mixed_bf16.
//!
//! Two sections, both recorded into `BENCH_serve.json` via
//! `benchkit::JsonReport`:
//!
//! 1. **Simulated load (always runs, no artifacts, no xla).**  The
//!    deterministic virtual-clock harness (`serve::simulate`) replays
//!    identical Poisson traffic through the real scheduler under both
//!    refill policies at equal worker count.  The service model is
//!    linear (`overhead + per_row × bucket`) with per-row costs in
//!    the paper's §5 cluster proportions (half precision ≈ 2× fp32
//!    math throughput on H100-class parts, bf16 marginally behind f16
//!    for the extra mantissa truncation) — synthetic absolute
//!    numbers, honest *relative* scheduling behaviour, bit-identical
//!    run to run.
//! 2. **Artifact-backed (needs `make artifacts`; runs on whichever
//!    runtime backend the build defaults to).**
//!    Per precision: closed-loop calibration, then an open-loop sweep
//!    at 50/70/90 % of calibrated capacity; headline is the highest
//!    offered load whose p99 stays under 3× the calibrated p50.
//!    Missing artifacts skip with a note, never fail.
//!
//! A third section replays the latency-aware bucket planner against
//! the static bucket list on an interactive-SLO lane and records the
//! comparison (chosen buckets, flush, predicted vs measured p99,
//! padding) into `BENCH_planner.json`.
//!
//! A fourth section measures span-tracing overhead (`mpx::trace`) on
//! the saturated regime — enabled vs disabled, median of repeated
//! replays — into `BENCH_trace.json`, and emits `trace_sim.json`, a
//! deterministic sim-produced Chrome trace that CI re-validates.
//!
//! A fifth section exercises the network transport itself over
//! loopback — one connection per request vs keep-alive reuse vs
//! pipelined windows against the event-driven reactor, with a trivial
//! echo executor so transport costs dominate — into
//! `BENCH_transport.json`.
//!
//! `MPX_BENCH_SMOKE=1` shrinks the simulated request count so CI can
//! emit the report in seconds.

use std::time::Duration;

use mpx::serve::planner::{self, LaneProfile, PlannerConfig, ServiceModel};
use mpx::serve::{
    loadgen, simulate, AutoscalePolicy, BatcherConfig, Calibration, LaneLoad,
    LaneSpec, SchedPolicy, SimReport, SimSpec,
};
use mpx::trace::{chrome, LaneId};
use mpx::util::benchkit::JsonReport;
use mpx::util::json::Json;

use mpx::config::{Precision, ServeConfig};
use mpx::runtime::ArtifactStore;
use mpx::serve;
use mpx::util::benchkit::Table;

const WORKERS: usize = 2;
const BUCKETS: &[usize] = &[1, 2, 4, 8];
const FLUSH: Duration = Duration::from_millis(20);
const OVERHEAD: Duration = Duration::from_micros(300);

/// (tag, per-row service cost) — see the module docs for provenance.
const PRECISIONS: &[(&str, Duration)] = &[
    ("fp32", Duration::from_micros(260)),
    ("mixed_f16", Duration::from_micros(130)),
    ("mixed_bf16", Duration::from_micros(140)),
];

fn lane_spec(name: &str, weight: u64) -> LaneSpec {
    LaneSpec {
        name: name.to_string(),
        weight,
        batcher: BatcherConfig::new(BUCKETS.to_vec(), FLUSH).unwrap(),
        queue_capacity: 4096,
        deadline: Duration::from_millis(100),
    }
}

/// Full-batch service capacity of the fixed pool, in req/s.
fn capacity_rps(per_row: Duration) -> f64 {
    let max = *BUCKETS.last().unwrap() as f64;
    let per_batch = OVERHEAD.as_secs_f64() + per_row.as_secs_f64() * max;
    WORKERS as f64 * max / per_batch
}

/// Latency-bound regime: offered rate below `max_batch/flush`, lanes
/// held open — form-first provably pays flush stalls; continuous
/// dispatches exact-fill buckets the instant a worker frees.
fn run_latency_regime(
    tag: &str,
    per_row: Duration,
    policy: SchedPolicy,
    requests: u64,
    rate: f64,
) -> SimReport {
    simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane_spec(tag, 1),
            arrivals: loadgen::poisson_offsets(requests, rate, 42),
        }],
        policy,
        autoscale: AutoscalePolicy::fixed(WORKERS),
        exec_overhead: OVERHEAD,
        exec_per_row: per_row,
        // Keep lanes open: the tail partial must drain through the
        // flush policy itself, not a close-drain bailout.
        stop_at: Some(Duration::from_secs(3600)),
        record_detail: false,
        trace: false,
        replan: None,
    })
    .expect("simulation failed")
}

/// Saturated regime: back-to-back arrivals, truncated at `stop_at` —
/// both policies dispatch identical full buckets, so completed-by-T
/// proves continuous batching costs nothing at saturation.
fn run_saturated_regime(
    tag: &str,
    per_row: Duration,
    policy: SchedPolicy,
    requests: usize,
) -> SimReport {
    simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: lane_spec(tag, 1),
            arrivals: vec![Duration::ZERO; requests],
        }],
        policy,
        autoscale: AutoscalePolicy::fixed(WORKERS),
        exec_overhead: OVERHEAD,
        exec_per_row: per_row,
        stop_at: Some(Duration::from_millis(250)),
        record_detail: false,
        trace: false,
        replan: None,
    })
    .expect("simulation failed")
}

fn sim_section(report: &mut JsonReport) {
    let requests: u64 =
        if std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1") {
            301
        } else {
            1001
        };
    // Below max_batch/flush_timeout (8 / 20 ms = 400 req/s), so
    // form-first cannot fill a bucket before the flush fires.
    let rate = 250.0;
    println!("\n=== simulated load: continuous vs form_first ===");
    println!(
        "precision,regime,policy,offered_rps,achieved_rps,p50_ms,p99_ms,\
         occupancy"
    );
    for &(tag, per_row) in PRECISIONS {
        let mut thr = Vec::new();
        let mut p50 = Vec::new();
        for policy in [SchedPolicy::FormFirst, SchedPolicy::Continuous] {
            let rep =
                run_latency_regime(tag, per_row, policy, requests, rate);
            assert_eq!(
                rep.completed(),
                requests,
                "sim dropped requests below capacity"
            );
            let s = rep.latency().summary().unwrap();
            let occ = rep.occupancy(WORKERS);
            println!(
                "{tag},latency,{},{rate:.0},{:.1},{:.3},{:.3},{occ:.3}",
                policy.tag(),
                rep.throughput_rps(),
                s.p50.as_secs_f64() * 1e3,
                s.p99.as_secs_f64() * 1e3,
            );
            report.entry(
                &format!("sim_{tag}_{}", policy.tag()),
                &[
                    ("offered_rps", rate),
                    ("offered_utilization", rate / capacity_rps(per_row)),
                    ("achieved_rps", rep.throughput_rps()),
                    ("wall_ms", rep.wall.as_secs_f64() * 1e3),
                    ("p50_ms", s.p50.as_secs_f64() * 1e3),
                    ("p99_ms", s.p99.as_secs_f64() * 1e3),
                    ("occupancy", occ),
                    ("padded_rows", rep.lanes[0].padded as f64),
                ],
            );
            thr.push(rep.throughput_rps());
            p50.push(s.p50.as_secs_f64());
        }
        // thr[0]/p50[0] = form_first, [1] = continuous.
        let ratio = thr[1] / thr[0];
        println!(
            "# {tag}: continuous/form_first throughput {ratio:.4}x, p50 \
             {:.1}x lower",
            p50[0] / p50[1].max(1e-12)
        );

        // Saturation check: continuous completes at least as many
        // requests by the cutoff as form-first at equal workers.
        let sat_f = run_saturated_regime(
            tag,
            per_row,
            SchedPolicy::FormFirst,
            8000,
        );
        let sat_c = run_saturated_regime(
            tag,
            per_row,
            SchedPolicy::Continuous,
            8000,
        );
        println!(
            "# {tag}: saturated completed-by-250ms: continuous {} vs \
             form_first {}",
            sat_c.completed(),
            sat_f.completed()
        );
        report.entry(
            &format!("sim_{tag}_continuous_speedup"),
            &[
                ("throughput_ratio", ratio),
                ("p50_ratio", p50[0] / p50[1].max(1e-12)),
                (
                    "saturated_completed_ratio",
                    sat_c.completed() as f64
                        / (sat_f.completed() as f64).max(1.0),
                ),
            ],
        );
    }

    // Multi-model: fp32 and mixed_f16 lanes sharing the pool at 1:2
    // weights under saturation — service should follow the weights.
    // Fixed count (virtual time is free) so the lanes stay saturated
    // through `stop_at` even in smoke mode.
    let requests_per_lane = 2000usize;
    let rep = simulate(SimSpec {
        lanes: vec![
            LaneLoad {
                spec: lane_spec("fp32", 1),
                arrivals: vec![Duration::ZERO; requests_per_lane],
            },
            LaneLoad {
                spec: lane_spec("mixed_f16", 2),
                arrivals: vec![Duration::ZERO; requests_per_lane],
            },
        ],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(WORKERS),
        exec_overhead: OVERHEAD,
        exec_per_row: Duration::from_micros(180),
        stop_at: Some(Duration::from_millis(250)),
        record_detail: false,
        trace: false,
        replan: None,
    })
    .expect("two-lane simulation failed");
    let a = rep.lanes[0].completed as f64;
    let b = rep.lanes[1].completed as f64;
    println!(
        "# two-lane weighted (1:2): fp32 {a:.0} vs mixed_f16 {b:.0} served \
         (ratio {:.2})",
        b / a.max(1.0)
    );
    report.entry(
        "sim_two_lane_weighted_1_2",
        &[
            ("fp32_served", a),
            ("mixed_f16_served", b),
            ("service_ratio", b / a.max(1.0)),
        ],
    );
}

/// Planner vs static buckets on an interactive-SLO lane, replayed on
/// the virtual clock — the scheduling-side half of the mixed
/// precision story: the fast artifacts only pay off when the batch
/// plan meets the latency budget.  Writes `BENCH_planner.json`.
fn planner_section() -> anyhow::Result<()> {
    let mut report = JsonReport::new("planner");
    let smoke = std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1");
    let requests: u64 = if smoke { 60 } else { 600 };

    // Interactive lane: 20 req/s of lone requests, p99 SLO 12 ms, on
    // the same linear service model the simulation executes
    // (1 ms + 1 ms/row).
    let model = ServiceModel {
        overhead: Duration::from_millis(1),
        per_row: Duration::from_millis(1),
    };
    let deadline = Duration::from_millis(12);
    let rate = 20.0;
    let arrivals = loadgen::poisson_offsets(requests, rate, 42);

    let run = |buckets: &[usize], flush: Duration| -> SimReport {
        simulate(SimSpec {
            lanes: vec![LaneLoad {
                spec: LaneSpec {
                    name: "interactive".into(),
                    weight: 1,
                    batcher: BatcherConfig::new(buckets.to_vec(), flush)
                        .unwrap(),
                    queue_capacity: 4096,
                    deadline,
                },
                arrivals: arrivals.clone(),
            }],
            policy: SchedPolicy::Continuous,
            autoscale: AutoscalePolicy::fixed(1),
            exec_overhead: model.overhead,
            exec_per_row: model.per_row,
            stop_at: Some(Duration::from_secs(3600)),
            record_detail: false,
            trace: false,
            replan: None,
        })
        .expect("planner-section simulation failed")
    };

    // Static deployment: only the throughput buckets compiled, global
    // 20 ms flush — the PR-3 shape.
    let static_rep = run(&[4, 8], Duration::from_millis(20));

    // The planner, fed the offered-load profile and SLO.
    let plan = planner::plan(
        &PlannerConfig {
            candidates: vec![1, 2, 4, 8],
            workers: 1,
            max_compiled: 0,
            safety: 0.9,
            max_flush: Duration::from_millis(20),
        },
        &model,
        &[LaneProfile {
            name: "interactive".into(),
            rate,
            deadline,
            weight: 1,
            size_dist: Vec::new(),
        }],
    )?;
    let lp = &plan.lanes[0];
    assert!(lp.is_feasible(), "bench profile must be plannable");
    let planned_rep = run(&lp.buckets, lp.flush_timeout);

    println!("\n=== bucket planner vs static list (12 ms SLO lane) ===");
    println!("variant,buckets,flush_ms,p99_ms,misses,padding_pct");
    let mut record = |name: &str,
                      buckets: &[usize],
                      flush: Duration,
                      rep: &SimReport| {
        let p99 = rep.latency().quantile(0.99).unwrap();
        let padded = rep.lanes[0].padded;
        let real = rep.lanes[0].completed;
        let pad_frac = padded as f64 / (padded + real).max(1) as f64;
        println!(
            "{name},{buckets:?},{:.2},{:.3},{},{:.1}",
            flush.as_secs_f64() * 1e3,
            p99.as_secs_f64() * 1e3,
            rep.deadline_misses(),
            pad_frac * 100.0,
        );
        report.entry(
            &format!("planner_interactive_{name}"),
            &[
                ("deadline_ms", deadline.as_secs_f64() * 1e3),
                ("offered_rps", rate),
                ("num_buckets", buckets.len() as f64),
                ("max_bucket", buckets.last().copied().unwrap_or(0) as f64),
                ("min_bucket", buckets.first().copied().unwrap_or(0) as f64),
                ("flush_ms", flush.as_secs_f64() * 1e3),
                ("p99_ms", p99.as_secs_f64() * 1e3),
                ("deadline_misses", rep.deadline_misses() as f64),
                ("padding_fraction", pad_frac),
            ],
        );
    };
    record("static", &[4, 8], Duration::from_millis(20), &static_rep);
    record("planned", &lp.buckets, lp.flush_timeout, &planned_rep);
    report.entry(
        "planner_prediction",
        &[
            ("predicted_p99_ms", lp.predicted.p99.as_secs_f64() * 1e3),
            (
                "measured_p99_ms",
                planned_rep.latency().quantile(0.99).unwrap().as_secs_f64()
                    * 1e3,
            ),
            ("predicted_padding_fraction", lp.predicted.padding_fraction),
            ("predicted_utilization", lp.predicted.utilization),
        ],
    );
    println!(
        "# planner: static misses {} of {requests}; planned misses {}",
        static_rep.deadline_misses(),
        planned_rep.deadline_misses()
    );

    // --- Close the loop: calibrate the service model from traced
    // executions, then compare its p99 prediction against a stale
    // config model on the same deployed plan. --------------------
    //
    // The calibration workload must observe *several distinct batch
    // sizes* or the linear fit is unidentifiable.  Each cycle sends a
    // lone "blocker" request (dispatches immediately as a bucket-1
    // batch) and, while the worker is busy with it, a burst of k
    // requests — which the continuous refill then dispatches as one
    // exact-fill bucket-k batch.
    let mut cal_arrivals = Vec::new();
    let mut base = Duration::ZERO;
    for _ in 0..6 {
        for k in [2u64, 4, 8] {
            cal_arrivals.push(base);
            for j in 1..=k {
                cal_arrivals.push(base + Duration::from_micros(100 * j));
            }
            base += Duration::from_millis(25);
        }
    }
    let cal_rep = simulate(SimSpec {
        lanes: vec![LaneLoad {
            spec: LaneSpec {
                name: "interactive".into(),
                weight: 1,
                batcher: BatcherConfig::new(
                    vec![1, 2, 4, 8],
                    Duration::from_millis(5),
                )
                .unwrap(),
                queue_capacity: 4096,
                deadline: Duration::from_secs(1),
            },
            arrivals: cal_arrivals,
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(1),
        exec_overhead: model.overhead,
        exec_per_row: model.per_row,
        stop_at: Some(Duration::from_secs(10)),
        record_detail: false,
        trace: true,
        replan: None,
    })
    .expect("calibration workload failed");
    let ids = [LaneId::new("interactive", "mixed_f16")];
    let samples = mpx::trace::service_samples(&cal_rep.spans, &ids);
    let cal = Calibration::fit(&samples);
    let fit = cal
        .get("interactive", "mixed_f16")
        .ok_or_else(|| anyhow::anyhow!("calibration fit found no lane"))?
        .clone();
    // The sim executes an exactly linear 1 ms + 1 ms/row model; the
    // exact-arithmetic fit must recover it to the microsecond.
    anyhow::ensure!(
        (fit.overhead_us, fit.per_row_us) == (1000, 1000),
        "fit ({}, {}) µs should recover the exact simulated model",
        fit.overhead_us,
        fit.per_row_us,
    );
    cal.write(std::path::Path::new("calibration.json"))?;
    println!(
        "# calibration: fitted {} + {}/row µs from {} samples → \
         calibration.json",
        fit.overhead_us, fit.per_row_us, fit.samples
    );

    // A stale config (the shipped 300 µs + 130 µs/row defaults)
    // understates this model's true cost ~7×.  Its p99 *promise* is
    // one the deployment cannot keep; the calibrated promise is an
    // upper bound the measurement respects.
    let stale = ServiceModel {
        overhead: Duration::from_micros(300),
        per_row: Duration::from_micros(130),
    };
    let pcfg = PlannerConfig {
        candidates: vec![1, 2, 4, 8],
        workers: 1,
        max_compiled: 0,
        safety: 0.9,
        max_flush: Duration::from_millis(20),
    };
    let profile = LaneProfile {
        name: "interactive".into(),
        rate,
        deadline,
        weight: 1,
        size_dist: Vec::new(),
    };
    let stale_plan =
        planner::plan(&pcfg, &stale, std::slice::from_ref(&profile))?;
    let cal_plan =
        planner::plan(&pcfg, &fit.model(), std::slice::from_ref(&profile))?;
    anyhow::ensure!(
        stale_plan.lanes[0].buckets == lp.buckets
            && cal_plan.lanes[0].buckets == lp.buckets,
        "both models should choose the deployed bucket set {:?}",
        lp.buckets,
    );
    let measured = planned_rep.latency().quantile(0.99).unwrap();
    let config_pred = stale_plan.lanes[0].predicted.p99;
    let cal_pred = cal_plan.lanes[0].predicted.p99;
    println!(
        "# calibrated-vs-config p99 on buckets {:?}: measured {:.3} ms, \
         config predicts {:.3} ms (bound {}), calibrated predicts {:.3} ms \
         (bound {})",
        lp.buckets,
        measured.as_secs_f64() * 1e3,
        config_pred.as_secs_f64() * 1e3,
        if measured <= config_pred { "holds" } else { "VIOLATED" },
        cal_pred.as_secs_f64() * 1e3,
        if measured <= cal_pred { "holds" } else { "VIOLATED" },
    );
    report.entry(
        "planner_calibrated_vs_config",
        &[
            ("measured_p99_ms", measured.as_secs_f64() * 1e3),
            ("config_predicted_p99_ms", config_pred.as_secs_f64() * 1e3),
            ("calibrated_predicted_p99_ms", cal_pred.as_secs_f64() * 1e3),
            ("config_bound_holds", (measured <= config_pred) as u8 as f64),
            ("calibrated_bound_holds", (measured <= cal_pred) as u8 as f64),
            ("fitted_overhead_us", fit.overhead_us as f64),
            ("fitted_per_row_us", fit.per_row_us as f64),
            ("fit_samples", fit.samples as f64),
        ],
    );

    println!("# wrote {}", report.write()?);
    Ok(())
}

/// Tracing overhead on the saturated simulated regime — the ISSUE's
/// "< 2% or it can't be always-on" bar — plus a sim-emitted Chrome
/// trace for CI to validate.  Writes `BENCH_trace.json` and
/// `trace_sim.json`.
fn trace_section() -> anyhow::Result<()> {
    let mut report = JsonReport::new("trace");
    let smoke = std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1");
    // Medians over repeated replays: the regimes are deterministic in
    // virtual time, so real-time jitter is the only noise source.
    let (requests, reps) = if smoke { (2000, 5) } else { (8000, 15) };
    let per_row = Duration::from_micros(130);

    let spec = |trace: bool| SimSpec {
        lanes: vec![LaneLoad {
            spec: lane_spec("mixed_f16", 1),
            arrivals: vec![Duration::ZERO; requests],
        }],
        policy: SchedPolicy::Continuous,
        autoscale: AutoscalePolicy::fixed(WORKERS),
        exec_overhead: OVERHEAD,
        exec_per_row: per_row,
        stop_at: Some(Duration::from_millis(250)),
        record_detail: false,
        trace,
        replan: None,
    };

    let median_secs = |trace: bool| -> (f64, SimReport) {
        let mut times = Vec::with_capacity(reps);
        let mut last = None;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let rep = simulate(spec(trace))
                .expect("trace-section simulation failed");
            times.push(t0.elapsed().as_secs_f64());
            last = Some(rep);
        }
        times.sort_by(f64::total_cmp);
        (times[times.len() / 2], last.unwrap())
    };

    // One warm-up each, unmeasured, so allocator/cache state doesn't
    // bias whichever variant runs first.
    let _ = simulate(spec(false));
    let _ = simulate(spec(true));
    let (off_s, base) = median_secs(false);
    let (on_s, traced) = median_secs(true);
    let overhead = on_s / off_s.max(1e-12) - 1.0;

    // Tracing must observe the run, never perturb it: identical
    // virtual-clock outcomes either way.
    assert_eq!(base.completed(), traced.completed());
    assert_eq!(base.wall, traced.wall);
    assert!(!traced.spans.is_empty(), "traced run recorded no spans");

    println!("\n=== tracing overhead (saturated regime) ===");
    println!(
        "# trace off {:.3} ms, on {:.3} ms → overhead {:+.2}% \
         ({} spans kept, {} dropped)",
        off_s * 1e3,
        on_s * 1e3,
        overhead * 100.0,
        traced.spans.len(),
        traced.trace_dropped,
    );
    report.entry(
        "trace_overhead_saturated",
        &[
            ("requests", requests as f64),
            ("reps", reps as f64),
            ("median_off_ms", off_s * 1e3),
            ("median_on_ms", on_s * 1e3),
            ("overhead_fraction", overhead),
            ("budget_fraction", 0.02),
            ("spans", traced.spans.len() as f64),
            ("dropped", traced.trace_dropped as f64),
        ],
    );

    // The trace itself, as CI validates it: parses back through the
    // crate's own JSON parser with every B matched by an E.
    let doc = chrome::chrome_trace(&traced.spans, traced.trace_dropped);
    let parsed = Json::parse(&doc.dump())
        .map_err(|e| anyhow::anyhow!("chrome trace does not re-parse: {e}"))?;
    anyhow::ensure!(parsed == doc, "chrome trace round-trip changed the doc");
    let pairs = chrome::check_nesting(&parsed)?;
    chrome::write_chrome_trace(
        std::path::Path::new("trace_sim.json"),
        &traced.spans,
        traced.trace_dropped,
    )?;
    println!(
        "# wrote trace_sim.json ({} spans, {pairs} B/E pairs)",
        traced.spans.len()
    );
    println!("# wrote {}", report.write()?);
    Ok(())
}

/// Real-socket regimes against the reactor transport: one connection
/// per request vs keep-alive reuse vs pipelined windows, over
/// loopback with an echo executor so the transport dominates the
/// cost.  Writes `BENCH_transport.json`; fails if keep-alive does not
/// beat one-connection-per-request on requests/sec.
fn transport_section() -> anyhow::Result<()> {
    use mpx::config::TransportConfig;
    use mpx::serve::transport::client::{infer_body_json, Client};
    use mpx::serve::transport::Server;
    use mpx::serve::BatchExecutor;

    struct EchoExec;
    impl BatchExecutor for EchoExec {
        fn execute(
            &mut self,
            images: &[f32],
            _batch: usize,
        ) -> anyhow::Result<Vec<f32>> {
            Ok(images.to_vec())
        }
    }

    let mut report = JsonReport::new("transport");
    let smoke = std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1");
    let threads = 6usize;
    let per_thread = if smoke { 40 } else { 334 };
    let total = threads * per_thread;
    const ELEMS: usize = 8;
    const WINDOW: usize = 8;

    let cfg = TransportConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: 256,
        read_timeout_ms: 5_000,
        request_deadline_ms: 10_000,
        idle_timeout_ms: 30_000,
        max_pipelined: WINDOW,
        drain_deadline_ms: 5_000,
    };
    let server = Server::bind(&cfg)?;
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let lanes = vec![LaneSpec {
        name: "bench/chat".into(),
        weight: 1,
        batcher: BatcherConfig::new(
            BUCKETS.to_vec(),
            Duration::from_millis(1),
        )?,
        queue_capacity: 4096,
        deadline: Duration::from_secs(5),
    }];
    let join = std::thread::spawn(move || {
        server.run(lanes, WORKERS, SchedPolicy::Continuous, ELEMS, |_, _| {
            Ok(EchoExec)
        })
    });
    let img: Vec<f32> = (0..ELEMS).map(|i| i as f32).collect();

    // Run one regime across `threads` closed-loop clients; returns
    // (requests/s, p50 ms, p99 ms) over every request.
    let run = |mode: &'static str| -> anyhow::Result<(f64, f64, f64)> {
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for _ in 0..threads {
            let addr = addr.clone();
            let img = img.clone();
            let work = move || -> anyhow::Result<Vec<f64>> {
                let timeout = Duration::from_secs(10);
                let client = Client::new(addr).with_timeout(timeout);
                let mut lat = Vec::with_capacity(per_thread);
                match mode {
                    "one_shot" => {
                        for _ in 0..per_thread {
                            let q0 = std::time::Instant::now();
                            let reply = client.infer("chat", &img)?;
                            anyhow::ensure!(reply.finite, "bad logits");
                            lat.push(q0.elapsed().as_secs_f64());
                        }
                    }
                    "keep_alive" => {
                        let mut conn = client.connect_keep_alive()?;
                        for _ in 0..per_thread {
                            let q0 = std::time::Instant::now();
                            let reply = conn.infer("chat", &img)?;
                            anyhow::ensure!(reply.finite, "bad logits");
                            lat.push(q0.elapsed().as_secs_f64());
                        }
                    }
                    _ => {
                        let mut conn = client.connect_keep_alive()?;
                        let body = infer_body_json("chat", &img);
                        let mut left = per_thread;
                        while left > 0 {
                            let k = left.min(WINDOW);
                            let q0 = std::time::Instant::now();
                            for _ in 0..k {
                                conn.send(
                                    "POST",
                                    "/v1/infer",
                                    "application/json",
                                    &[],
                                    body.as_bytes(),
                                )?;
                            }
                            for _ in 0..k {
                                let resp = conn.read_response()?;
                                anyhow::ensure!(
                                    resp.status == 200,
                                    "pipelined status {}",
                                    resp.status
                                );
                            }
                            let per = q0.elapsed().as_secs_f64() / k as f64;
                            for _ in 0..k {
                                lat.push(per);
                            }
                            left -= k;
                        }
                    }
                }
                Ok(lat)
            };
            joins.push(std::thread::spawn(work));
        }
        let mut lat: Vec<f64> = Vec::with_capacity(total);
        for j in joins {
            lat.extend(j.join().expect("bench client thread panicked")?);
        }
        let wall = t0.elapsed().as_secs_f64();
        lat.sort_by(f64::total_cmp);
        let q = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize] * 1e3;
        Ok((total as f64 / wall.max(1e-9), q(0.50), q(0.99)))
    };

    println!("\n=== transport: one-shot vs keep-alive vs pipelined ===");
    println!("regime,requests,connections,requests_per_s,p50_ms,p99_ms");
    let (os_rps, os_p50, os_p99) = run("one_shot")?;
    println!("one_shot,{total},{total},{os_rps:.0},{os_p50:.3},{os_p99:.3}");
    let (ka_rps, ka_p50, ka_p99) = run("keep_alive")?;
    println!(
        "keep_alive,{total},{threads},{ka_rps:.0},{ka_p50:.3},{ka_p99:.3}"
    );
    let (pl_rps, pl_p50, pl_p99) = run("pipelined")?;
    println!(
        "pipelined,{total},{threads},{pl_rps:.0},{pl_p50:.3},{pl_p99:.3}"
    );

    handle.shutdown();
    join.join().expect("bench server thread panicked")?;

    report.entry(
        "transport_one_shot",
        &[
            ("requests", total as f64),
            ("connections", total as f64),
            ("connections_per_s", os_rps),
            ("requests_per_s", os_rps),
            ("p50_ms", os_p50),
            ("p99_ms", os_p99),
        ],
    );
    report.entry(
        "transport_keep_alive",
        &[
            ("requests", total as f64),
            ("connections", threads as f64),
            ("requests_per_s", ka_rps),
            ("p50_ms", ka_p50),
            ("p99_ms", ka_p99),
        ],
    );
    report.entry(
        "transport_pipelined",
        &[
            ("requests", total as f64),
            ("connections", threads as f64),
            ("window", WINDOW as f64),
            ("requests_per_s", pl_rps),
            ("p50_ms", pl_p50),
            ("p99_ms", pl_p99),
        ],
    );
    let speedup = ka_rps / os_rps.max(1e-9);
    report.entry(
        "transport_keepalive_speedup",
        &[
            ("requests_per_s_ratio", speedup),
            ("pipelined_ratio", pl_rps / os_rps.max(1e-9)),
        ],
    );
    println!(
        "# keep-alive {speedup:.2}x one-shot on requests/s; pipelined \
         {:.2}x",
        pl_rps / os_rps.max(1e-9)
    );
    anyhow::ensure!(
        speedup > 1.0,
        "keep-alive ({ka_rps:.0} req/s) must beat one connection per \
         request ({os_rps:.0} req/s)"
    );
    println!("# wrote {}", report.write()?);
    Ok(())
}

fn artifact_section(report: &mut JsonReport) -> anyhow::Result<()> {
    let mut store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("# skip artifact section: {e:#}");
            return Ok(());
        }
    };
    let requests: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut table = Table::new(
        "serve throughput by precision",
        &[
            "precision",
            "mode",
            "offered_rps",
            "achieved_rps",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "rejected",
        ],
    );

    for precision in
        [Precision::Fp32, Precision::MixedF16, Precision::MixedBf16]
    {
        let base = ServeConfig {
            precision,
            requests,
            workers: 2,
            arrival_rate: 0.0,
            open_loop: false,
            ..ServeConfig::default()
        };

        // 1. closed-loop calibration
        let cal = match serve::run_with_artifacts(&mut store, &base) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("# skip {}: {e:#}", precision.tag());
                continue;
            }
        };
        let capacity = cal.throughput_rps();
        let Some(cs) = cal.latency.summary() else { continue };
        table.row(&[
            precision.tag().into(),
            "closed".into(),
            "-".into(),
            format!("{capacity:.1}"),
            format!("{:.2}", cs.p50.as_secs_f64() * 1e3),
            format!("{:.2}", cs.p95.as_secs_f64() * 1e3),
            format!("{:.2}", cs.p99.as_secs_f64() * 1e3),
            format!("{}", cal.queue.rejected),
        ]);
        report.entry(
            &format!("artifact_{}_closed", precision.tag()),
            &[
                ("achieved_rps", capacity),
                ("p50_ms", cs.p50.as_secs_f64() * 1e3),
                ("p99_ms", cs.p99.as_secs_f64() * 1e3),
            ],
        );

        // 2. open-loop sweep at fractions of capacity
        let p99_bound = cs.p50.as_secs_f64() * 3.0;
        let mut headline: Option<(f64, f64)> = None;
        for frac in [0.5, 0.7, 0.9] {
            let cfg = ServeConfig {
                open_loop: true,
                arrival_rate: capacity * frac,
                ..base.clone()
            };
            let rep = serve::run_with_artifacts(&mut store, &cfg)?;
            let Some(s) = rep.latency.summary() else { continue };
            table.row(&[
                precision.tag().into(),
                format!("open@{:.0}%", frac * 100.0),
                format!("{:.1}", cfg.arrival_rate),
                format!("{:.1}", rep.throughput_rps()),
                format!("{:.2}", s.p50.as_secs_f64() * 1e3),
                format!("{:.2}", s.p95.as_secs_f64() * 1e3),
                format!("{:.2}", s.p99.as_secs_f64() * 1e3),
                format!("{}", rep.queue.rejected),
            ]);
            report.entry(
                &format!(
                    "artifact_{}_open{:.0}",
                    precision.tag(),
                    frac * 100.0
                ),
                &[
                    ("offered_rps", cfg.arrival_rate),
                    ("achieved_rps", rep.throughput_rps()),
                    ("p99_ms", s.p99.as_secs_f64() * 1e3),
                    ("rejected", rep.queue.rejected as f64),
                ],
            );
            if s.p99.as_secs_f64() <= p99_bound {
                headline = Some((frac, rep.throughput_rps()));
            }
        }

        // 3. headline
        match headline {
            Some((frac, thr)) => println!(
                "# {}: sustains {:.1} req/s at {:.0}% load with p99 ≤ 3×p50",
                precision.tag(),
                thr,
                frac * 100.0
            ),
            None => println!(
                "# {}: no swept load held p99 ≤ 3×p50 ({:.2} ms)",
                precision.tag(),
                p99_bound * 1e3
            ),
        }
    }
    println!("# wrote {}", table.write_csv()?);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut report = JsonReport::new("serve");
    sim_section(&mut report);
    planner_section()?;
    trace_section()?;
    transport_section()?;
    artifact_section(&mut report)?;
    println!("# wrote {}", report.write()?);
    Ok(())
}
