//! L1 kernel micro-benchmarks, two layers:
//!
//! 1. **Host kernels** (always run, no artifacts needed): the
//!    vectorized `hostkernel` layer vs the scalar `numerics`
//!    baselines — batch f32↔f16/bf16 casts, the fused unscale+stats
//!    gradient scan vs the unscale-then-`tensor_stats` double walk,
//!    and the chunk-parallel tree all-reduce vs the sequential
//!    original.  Results (median ns, element throughput, speedup) are
//!    recorded in `BENCH_kernel_micro.json` via `util::benchkit` so
//!    the perf trajectory is diffable across PRs.
//! 2. **Runtime backends** (skipped with a note when the AOT
//!    artifacts are absent): one artifact executed end-to-end on the
//!    pure-Rust host interpreter and — when the `xla` feature is
//!    compiled in — on PJRT, with the host/xla latency ratio recorded
//!    (`backend_step_*` entries in `BENCH_kernel_micro.json`; schema
//!    in docs/BENCHMARKS.md).
//! 3. **Pallas kernels** (skipped with a note when the AOT artifacts
//!    are absent): the mixed-precision kernels vs their jnp
//!    references, plus the structural VMEM table — on the CPU PJRT
//!    backend the Pallas grid runs in interpret mode, so structure,
//!    not wall-clock, is the optimization target (DESIGN.md
//!    §Hardware-Adaptation).

use std::hint::black_box;

use mpx::collective::{all_reduce_mean, sequential_all_reduce_reference};
use mpx::hostkernel::{cast, scan};
use mpx::numerics::{tensor_stats, Bf16, F16};
use mpx::pytree::{DType, LeafSpec};
use mpx::runtime::{
    lit_f32, lit_from_bytes, lit_i32, ArtifactStore, BackendKind, Value,
};
use mpx::util::benchkit::{bench, BenchOpts, JsonReport, Table};
use mpx::util::rng::Rng;

/// 1M elements — the acceptance-criteria buffer size.
const N: usize = 1 << 20;

/// Gradient-shaped data: lognormal magnitudes, both signs, a sprinkle
/// of exact zeros — exercises the subnormal and normal cast paths the
/// way a real late-training gradient buffer does.
fn gradient_buffer(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.below(64) == 0 {
                0.0
            } else {
                let log10 = rng.normal_f32(-4.0, 2.0);
                let mag = 10f32.powf(log10);
                if rng.below(2) == 0 {
                    mag
                } else {
                    -mag
                }
            }
        })
        .collect()
}

struct HostBench<'a> {
    opts: &'a BenchOpts,
    table: Table,
    report: &'a mut JsonReport,
}

impl HostBench<'_> {
    /// Bench `scalar` vs `vectorized` over `elems` elements; prints a
    /// table row and records a JSON entry.
    fn case(
        &mut self,
        name: &str,
        elems: usize,
        mut scalar: impl FnMut(),
        mut vectorized: impl FnMut(),
    ) {
        let s = bench(self.opts, &mut scalar);
        let v = bench(self.opts, &mut vectorized);
        let s_ns = s.median.as_nanos() as f64;
        let v_ns = v.median.as_nanos() as f64;
        let speedup = s_ns / v_ns.max(1.0);
        let gelems = elems as f64 / v_ns.max(1.0); // ns → Gelem/s
        self.table.row(&[
            name.to_string(),
            format!("{:.2}", s_ns / 1e6),
            format!("{:.2}", v_ns / 1e6),
            format!("{gelems:.2}"),
            format!("{speedup:.1}x"),
        ]);
        self.report.entry(
            name,
            &[
                ("elems", elems as f64),
                ("scalar_median_ns", s_ns),
                ("vectorized_median_ns", v_ns),
                ("vectorized_gelems_per_s", gelems),
                ("speedup_vs_scalar", speedup),
            ],
        );
    }
}

fn host_kernels(
    opts: &BenchOpts,
    report: &mut JsonReport,
) -> anyhow::Result<()> {
    let mut hb = HostBench {
        opts,
        table: Table::new(
            "host kernels: scalar numerics vs vectorized hostkernel (1M elems)",
            &["kernel", "scalar_ms", "vector_ms", "gelems_s", "speedup"],
        ),
        report,
    };

    let src = gradient_buffer(N, 1);
    // Separate destination buffers per arm — the two closures of a
    // `case` coexist, so they cannot share one `&mut` buffer.
    let mut dst16_s = vec![0u16; N];
    let mut dst16_v = vec![0u16; N];
    let mut dst32_s = vec![0f32; N];
    let mut dst32_v = vec![0f32; N];

    // -- batch casts --------------------------------------------------
    hb.case(
        "cast_f32_to_f16",
        N,
        || {
            for (o, x) in dst16_s.iter_mut().zip(&src) {
                *o = F16::from_f32(*x).0;
            }
            black_box(&dst16_s);
        },
        || {
            cast::f32_to_f16_slice(&src, &mut dst16_v);
            black_box(&dst16_v);
        },
    );
    let halves16 = {
        let mut h = vec![0u16; N];
        cast::f32_to_f16_slice(&src, &mut h);
        h
    };
    hb.case(
        "cast_f16_to_f32",
        N,
        || {
            for (o, h) in dst32_s.iter_mut().zip(&halves16) {
                *o = F16(*h).to_f32();
            }
            black_box(&dst32_s);
        },
        || {
            cast::f16_to_f32_slice(&halves16, &mut dst32_v);
            black_box(&dst32_v);
        },
    );
    hb.case(
        "cast_f32_to_bf16",
        N,
        || {
            for (o, x) in dst16_s.iter_mut().zip(&src) {
                *o = Bf16::from_f32(*x).0;
            }
            black_box(&dst16_s);
        },
        || {
            cast::f32_to_bf16_slice(&src, &mut dst16_v);
            black_box(&dst16_v);
        },
    );
    let halvesbf = {
        let mut h = vec![0u16; N];
        cast::f32_to_bf16_slice(&src, &mut h);
        h
    };
    hb.case(
        "cast_bf16_to_f32",
        N,
        || {
            for (o, b) in dst32_s.iter_mut().zip(&halvesbf) {
                *o = Bf16(*b).to_f32();
            }
            black_box(&dst32_s);
        },
        || {
            cast::bf16_to_f32_slice(&halvesbf, &mut dst32_v);
            black_box(&dst32_v);
        },
    );

    // -- fused gradient scan ------------------------------------------
    // inv_scale of exactly 1.0 (opaque to the optimizer) keeps the
    // buffer's values fixed across iterations while both arms still
    // perform the full multiply-and-store per element.
    let mut grads_s = gradient_buffer(N, 2);
    let mut grads_v = grads_s.clone();
    let inv = black_box(1.0f32);
    hb.case(
        "fused_unscale_stats",
        N,
        || {
            // today's double walk: unscale pass, then stats pass
            for x in grads_s.iter_mut() {
                *x *= inv;
            }
            black_box(tensor_stats(&grads_s));
        },
        || {
            black_box(scan::fused_unscale_stats(&mut grads_v, inv));
        },
    );

    // -- tree all-reduce ----------------------------------------------
    // 4 "devices" with a 1M-element gradient each, like the paper's
    // cluster run.  The baseline is the pre-hostkernel sequential
    // reduction (identical association, single-threaded adds).
    let mut shards_a: Vec<Vec<Vec<f32>>> = (0..4)
        .map(|s| vec![gradient_buffer(N / 4, 3 + s as u64)])
        .collect();
    let mut shards_b = shards_a.clone();
    hb.case(
        "all_reduce_mean_4x",
        N,
        || {
            sequential_all_reduce_reference(&mut shards_a);
            black_box(&shards_a);
        },
        || {
            all_reduce_mean(&mut shards_b);
            black_box(&shards_b);
        },
    );

    println!("# wrote {}", hb.table.write_csv()?);
    Ok(())
}

/// Manifest-typed pseudo-random input: normal f32 data for the float
/// dtypes (rounded through the batch casts for f16/bf16), zeros for
/// the integer/pred leaves (labels, counters — values the graphs only
/// index or accumulate with).
fn random_input(spec: &LeafSpec, rng: &mut Rng) -> anyhow::Result<Value> {
    let n = spec.elems();
    let normals = |rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    match spec.dtype {
        DType::F32 => lit_f32(&spec.shape, &normals(rng)),
        DType::F16 => {
            let mut bytes = Vec::new();
            cast::f32_to_f16_bytes(&normals(rng), &mut bytes);
            lit_from_bytes(spec, &bytes)
        }
        DType::Bf16 => {
            let mut bytes = Vec::new();
            cast::f32_to_bf16_bytes(&normals(rng), &mut bytes);
            lit_from_bytes(spec, &bytes)
        }
        DType::S32 => lit_i32(&spec.shape, &vec![0; n]),
        _ => lit_from_bytes(spec, &vec![0u8; spec.bytes()]),
    }
}

/// One artifact executed end-to-end per runtime backend — the latency
/// cost of the pure-Rust interpreter next to PJRT on the same graph.
/// Picks the cheapest forward artifact on disk (fused step as a
/// fallback) so the host run stays in benchmark territory.
fn backend_section(
    opts: &BenchOpts,
    report: &mut JsonReport,
) -> anyhow::Result<()> {
    let probe = ArtifactStore::open_default_with(BackendKind::Host)?;
    let names = probe.list()?;
    let cheapest = |prefix: &str| -> Option<String> {
        names
            .iter()
            .filter(|n| n.starts_with(prefix))
            .filter_map(|n| {
                let m = probe.manifest(n).ok()?;
                let bytes: usize = m.inputs.iter().map(|s| s.bytes()).sum();
                Some((bytes, n.clone()))
            })
            .min()
            .map(|(_, n)| n)
    };
    let Some(name) = cheapest("fwd_").or_else(|| cheapest("step_fused_"))
    else {
        anyhow::bail!("no fwd_*/step_fused_* artifacts on disk");
    };

    let mut table = Table::new(
        "runtime backends: one artifact execution (median)",
        &["artifact", "backend", "median_ms"],
    );
    let mut medians = Vec::new();
    for kind in [BackendKind::Host, BackendKind::Xla] {
        if !kind.available() {
            continue;
        }
        let mut store = ArtifactStore::open_default_with(kind)?;
        let art = store.load(&name)?;
        let mut rng = Rng::new(7);
        let inputs: Vec<Value> = art
            .manifest
            .inputs
            .iter()
            .map(|spec| random_input(spec, &mut rng))
            .collect::<anyhow::Result<_>>()?;
        let stats = bench(opts, || {
            art.execute(&inputs).expect("backend execute");
        });
        let median_s = stats.median.as_secs_f64();
        table.row(&[
            name.clone(),
            kind.name().to_string(),
            format!("{:.3}", median_s * 1e3),
        ]);
        report.entry(
            &format!("backend_step_{kind}"),
            &[("median_ns", stats.median.as_nanos() as f64)],
        );
        medians.push(median_s);
    }
    if let [host, xla] = medians[..] {
        let ratio = host / xla.max(1e-12);
        report.entry("backend_host_vs_xla", &[("host_over_xla", ratio)]);
        println!("# host interpreter vs xla on {name}: {ratio:.1}x");
    }
    println!("# wrote {}", table.write_csv()?);
    Ok(())
}

fn run_kernel(
    store: &mut ArtifactStore,
    name: &str,
    opts: &BenchOpts,
) -> anyhow::Result<f64> {
    let art = store.load(name)?;
    let mut rng = Rng::new(1);
    let inputs: Vec<Value> = art
        .manifest
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> =
                (0..spec.elems()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            lit_f32(&spec.shape, &data)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let stats = bench(opts, || {
        art.execute(&inputs).expect("kernel execute");
    });
    Ok(stats.median.as_secs_f64())
}

fn pjrt_kernels(opts: &BenchOpts) -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let mut table = Table::new(
        &format!(
            "L1 kernels: Pallas (interpret) vs jnp reference ({} backend)",
            store.backend_kind()
        ),
        &["kernel", "pallas_ms", "ref_ms", "interp_overhead"],
    );
    for half in ["f16", "bf16"] {
        let pallas =
            run_kernel(&mut store, &format!("kernel_matmul_{half}_512"), opts)?;
        let reference = run_kernel(
            &mut store,
            &format!("kernel_matmul_ref_{half}_512"),
            opts,
        )?;
        table.row(&[
            format!("matmul_{half}_512^3"),
            format!("{:.2}", pallas * 1e3),
            format!("{:.2}", reference * 1e3),
            format!("{:.1}x", pallas / reference),
        ]);
    }
    for name in ["kernel_attention_f16_vit", "kernel_layernorm_f16_vit"] {
        let t = run_kernel(&mut store, name, opts)?;
        table.row(&[
            name.to_string(),
            format!("{:.2}", t * 1e3),
            "-".into(),
            "-".into(),
        ]);
    }
    println!("# wrote {}", table.write_csv()?);

    // Structural (real-TPU) quantities — what the block shapes imply.
    let mut structure = Table::new(
        "L1 matmul kernel: VMEM working set by block shape (TPU budget 16 MiB)",
        &["bm", "bn", "bk", "vmem_KiB", "fits_16MiB"],
    );
    for &(bm, bn, bk) in
        &[(64usize, 64usize, 64usize), (128, 128, 128), (256, 256, 128),
          (512, 512, 256)]
    {
        // mirror python/compile/kernels/matmul.py::vmem_bytes (half in,
        // f32 accumulator)
        let bytes = bm * bk * 2 + bk * bn * 2 + bm * bn * 4;
        structure.row(&[
            bm.to_string(),
            bn.to_string(),
            bk.to_string(),
            format!("{:.0}", bytes as f64 / 1024.0),
            (bytes < 16 << 20).to_string(),
        ]);
    }
    println!("# wrote {}", structure.write_csv()?);
    println!("# default 128^3 blocks: f32 scratch + half tiles ≈ 128 KiB ≪ 16 MiB VMEM,");
    println!("# leaving room for double-buffering the HBM↔VMEM pipeline.");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env(BenchOpts {
        warmup_iters: 2,
        max_iters: 12,
        max_seconds: 8.0,
    });

    let mut report = JsonReport::new("kernel_micro");
    host_kernels(&opts, &mut report)?;

    // The artifact-backed sections need `make artifacts`; a fresh
    // clone / CI smoke run still gets the host-kernel numbers above.
    if let Err(e) = backend_section(&opts, &mut report) {
        println!("# skipping backend benches: {e:#}");
    }
    if let Err(e) = pjrt_kernels(&opts) {
        println!("# skipping Pallas kernel benches: {e:#}");
    }
    let path = report.write()?;
    println!("# wrote {path}");
    Ok(())
}
