//! L1 kernel micro-benchmarks: the Pallas mixed-precision kernels vs
//! their jnp reference implementations, executed through the same
//! AOT→PJRT path the training steps use.
//!
//! On this CPU backend the Pallas kernels run in interpret mode (the
//! grid lowers to an XLA while-loop), so *wall-clock is not the
//! optimization target* — structure is (DESIGN.md §Hardware-
//! Adaptation).  The bench therefore reports both wall-clock AND the
//! structural quantities that determine real-TPU performance: VMEM
//! working set and MXU-feeding tile shapes.

use mpx::runtime::{lit_f32, ArtifactStore};
use mpx::util::benchkit::{bench, BenchOpts, Table};
use mpx::util::rng::Rng;

fn run_kernel(
    store: &mut ArtifactStore,
    name: &str,
    opts: &BenchOpts,
) -> anyhow::Result<f64> {
    let art = store.load(name)?;
    let mut rng = Rng::new(1);
    let inputs: Vec<xla::Literal> = art
        .manifest
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> =
                (0..spec.elems()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            lit_f32(&spec.shape, &data)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let stats = bench(opts, || {
        art.execute(&inputs).expect("kernel execute");
    });
    Ok(stats.median.as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let mut store = ArtifactStore::open_default()?;
    let opts = BenchOpts::from_env(BenchOpts {
        warmup_iters: 2,
        max_iters: 10,
        max_seconds: 8.0,
    });

    let mut table = Table::new(
        "L1 kernels: Pallas (interpret) vs jnp reference via PJRT",
        &["kernel", "pallas_ms", "ref_ms", "interp_overhead"],
    );
    for half in ["f16", "bf16"] {
        let pallas =
            run_kernel(&mut store, &format!("kernel_matmul_{half}_512"), &opts)?;
        let reference = run_kernel(
            &mut store,
            &format!("kernel_matmul_ref_{half}_512"),
            &opts,
        )?;
        table.row(&[
            format!("matmul_{half}_512^3"),
            format!("{:.2}", pallas * 1e3),
            format!("{:.2}", reference * 1e3),
            format!("{:.1}x", pallas / reference),
        ]);
    }
    for name in ["kernel_attention_f16_vit", "kernel_layernorm_f16_vit"] {
        let t = run_kernel(&mut store, name, &opts)?;
        table.row(&[
            name.to_string(),
            format!("{:.2}", t * 1e3),
            "-".into(),
            "-".into(),
        ]);
    }
    println!("# wrote {}", table.write_csv()?);

    // Structural (real-TPU) quantities — what the block shapes imply.
    let mut structure = Table::new(
        "L1 matmul kernel: VMEM working set by block shape (TPU budget 16 MiB)",
        &["bm", "bn", "bk", "vmem_KiB", "fits_16MiB"],
    );
    for &(bm, bn, bk) in
        &[(64usize, 64usize, 64usize), (128, 128, 128), (256, 256, 128),
          (512, 512, 256)]
    {
        // mirror python/compile/kernels/matmul.py::vmem_bytes (half in,
        // f32 accumulator)
        let bytes = bm * bk * 2 + bk * bn * 2 + bm * bn * 4;
        structure.row(&[
            bm.to_string(),
            bn.to_string(),
            bk.to_string(),
            format!("{:.0}", bytes as f64 / 1024.0),
            (bytes < 16 << 20).to_string(),
        ]);
    }
    println!("# wrote {}", structure.write_csv()?);
    println!("# default 128^3 blocks: f32 scratch + half tiles ≈ 128 KiB ≪ 16 MiB VMEM,");
    println!("# leaving room for double-buffering the HBM↔VMEM pipeline.");
    Ok(())
}
