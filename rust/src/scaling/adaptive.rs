//! Adaptive per-layer loss scaling (Zhao et al., *Adaptive Loss
//! Scaling for Mixed Precision Training*, 1910.12385).
//!
//! One dynamic-scaling state machine per pytree-leaf group instead of
//! one global scale.  Each group's scale moves from *its own*
//! statistics:
//!
//! * **Backoff** — the group saw an overflow (an element that would
//!   saturate f16 at the group's scale, or a non-finite gradient):
//!   `S_g ← max(S_g/factor, min)`, counter reset.  The group also
//!   books a skip, because overflow anywhere still skips the global
//!   optimizer step (finiteness gates the update for every policy).
//! * **Growth** — after `period` consecutive clean steps, `S_g`
//!   grows — but only if the *headroom gate* allows:
//!   `S_g·factor·max|g|_seen ≤ headroom·F16_SATURATE`.  The running
//!   `max|g|` is the largest finite gradient magnitude the group has
//!   ever produced, so a group that once spiked to `m` will never be
//!   re-grown into a scale where `m` overflows again — this is what
//!   lets adaptive stop paying for a recurring spike after a single
//!   backoff run, while global dynamic re-grows into it every
//!   `period` steps.
//! * **Underflow pressure** — while the group's underflow fraction
//!   (elements flushing to ±0 in f16 at the current scale) exceeds
//!   `underflow_target`, the effective growth period shrinks to
//!   `max(1, period/4)`: a group losing gradient mass to flush
//!   recovers its scale quickly instead of waiting the full global
//!   period.
//!
//! Everything is integer counts, f32 pow2 arithmetic, and
//! shard-order-deterministic folds — the trajectory is a pure
//! function of the gradient trace, asserted by replay tests in
//! `scaling_parity.rs`.

use super::{GroupState, GroupStats, PolicyKind, ScalingConfig, ScalingPolicy};
use crate::hostkernel::scan::F16_SATURATE;

/// Adaptive-only knobs, layered on top of the shared
/// [`ScalingConfig`] base.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveTuning {
    /// Growth is blocked unless the grown scale keeps the group's
    /// largest-ever |g| below this fraction of the f16 saturation
    /// boundary.  In (0, 1]; 1 disables the safety margin.
    pub headroom: f32,
    /// Underflow fraction above which a group grows on the fast
    /// period (`max(1, period/4)`).  In [0, 1).
    pub underflow_target: f64,
}

impl Default for AdaptiveTuning {
    fn default() -> Self {
        AdaptiveTuning { headroom: 0.5, underflow_target: 1e-3 }
    }
}

/// Per-group dynamic loss scaling behind the [`ScalingPolicy`] trait.
pub struct AdaptivePolicy {
    base: ScalingConfig,
    tuning: AdaptiveTuning,
    names: Vec<String>,
    scales: Vec<f32>,
    counters: Vec<u32>,
    /// Largest finite |g| each group has ever produced (the headroom
    /// gate's memory).
    seen_max: Vec<f32>,
    skips: Vec<u64>,
    steps: u64,
    overflows: u64,
    growths: u64,
}

impl AdaptivePolicy {
    pub fn new(
        base: ScalingConfig,
        tuning: AdaptiveTuning,
        names: Vec<String>,
    ) -> AdaptivePolicy {
        assert!(!names.is_empty(), "adaptive policy needs ≥ 1 group");
        let n = names.len();
        AdaptivePolicy {
            scales: vec![base.init_scale; n],
            counters: vec![0; n],
            seen_max: vec![0.0; n],
            skips: vec![0; n],
            base,
            tuning,
            names,
            steps: 0,
            overflows: 0,
            growths: 0,
        }
    }

    /// Restore from a checkpointed record.  A single-group record is
    /// the v1 migration: the global scale fans out to every group.  A
    /// full record must match the derived group names exactly.
    pub fn restore(
        base: ScalingConfig,
        tuning: AdaptiveTuning,
        names: Vec<String>,
        saved: &[GroupState],
    ) -> anyhow::Result<AdaptivePolicy> {
        let mut p = AdaptivePolicy::new(base, tuning, names);
        if saved.len() == 1 && p.names.len() != 1 {
            // v1 fan-out: one global (scale, counter) seeds them all.
            for g in 0..p.names.len() {
                p.scales[g] = saved[0].scale;
                p.counters[g] = saved[0].counter;
            }
            return Ok(p);
        }
        if saved.len() != p.names.len() {
            anyhow::bail!(
                "scaler record has {} group(s) but the model derives {}",
                saved.len(),
                p.names.len()
            );
        }
        for (g, s) in saved.iter().enumerate() {
            if s.name != p.names[g] {
                anyhow::bail!(
                    "scaler record group {} is {:?}, model derives {:?} — \
                     checkpoint belongs to a different model layout",
                    g,
                    s.name,
                    p.names[g]
                );
            }
            p.scales[g] = s.scale;
            p.counters[g] = s.counter;
        }
        Ok(p)
    }

    fn clamp(&self, g: usize) -> usize {
        g.min(self.names.len() - 1)
    }
}

impl ScalingPolicy for AdaptivePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Adaptive
    }

    fn graph_scale(&self) -> f32 {
        // The artifact takes one scalar scale; the most overflow-prone
        // group dictates it.  Per-group resolution happens host-side
        // by re-scaling each group's gradients from this common base.
        self.scales.iter().copied().fold(f32::INFINITY, f32::min)
    }

    fn groups(&self) -> &[String] {
        &self.names
    }

    fn scale_of(&self, g: usize) -> f32 {
        self.scales[self.clamp(g)]
    }

    fn counter_of(&self, g: usize) -> u32 {
        self.counters[self.clamp(g)]
    }

    fn skips_of(&self, g: usize) -> u64 {
        self.skips[self.clamp(g)]
    }

    fn adjust(&mut self, grads_finite: bool, groups: &[GroupStats]) -> bool {
        assert_eq!(
            groups.len(),
            self.names.len(),
            "stats/group arity mismatch"
        );
        self.steps += 1;
        let mut any_overflow = false;
        for (g, st) in groups.iter().enumerate() {
            // Fold this step's largest finite |g| into the headroom
            // gate's memory (infs are excluded by construction: the
            // census reports max_abs over finite elements only).
            if st.max_abs.is_finite() && st.max_abs > self.seen_max[g] {
                self.seen_max[g] = st.max_abs;
            }
            let overflowed = st.overflow > 0 || !st.finite;
            if overflowed {
                any_overflow = true;
                self.scales[g] =
                    (self.scales[g] / self.base.factor).max(self.base.min_scale);
                self.counters[g] = 0;
                self.skips[g] += 1;
                continue;
            }
            // Clean step: grow on the (possibly shortened) period.
            let under_frac = if st.count > 0 {
                st.underflow as f64 / st.count as f64
            } else {
                0.0
            };
            let period = if under_frac > self.tuning.underflow_target {
                (self.base.period / 4).max(1)
            } else {
                self.base.period
            };
            if self.counters[g] >= period.saturating_sub(1) {
                let grown =
                    (self.scales[g] * self.base.factor).min(self.base.max_scale);
                let safe = grown as f64 * self.seen_max[g] as f64
                    <= self.tuning.headroom as f64 * F16_SATURATE as f64;
                if safe && grown > self.scales[g] {
                    self.scales[g] = grown;
                    self.counters[g] = 0;
                    self.growths += 1;
                }
                // Blocked growth (or at the cap) holds the counter at
                // the boundary — the gate is re-checked every step.
            } else {
                self.counters[g] += 1;
            }
        }
        if any_overflow {
            self.overflows += 1;
        }
        // Global-AND finiteness gates the optimizer step, exactly as
        // for the global policies: one poisoned group skips the step.
        grads_finite && !any_overflow
    }

    fn snapshot(&self) -> Vec<GroupState> {
        self.names
            .iter()
            .zip(&self.scales)
            .zip(&self.counters)
            .map(|((name, &scale), &counter)| GroupState {
                name: name.clone(),
                scale,
                counter,
            })
            .collect()
    }

    fn steps(&self) -> u64 {
        self.steps
    }

    fn overflows(&self) -> u64 {
        self.overflows
    }

    fn growths(&self) -> u64 {
        self.growths
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scaling::spike_overflows;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("blocks[{i}]")).collect()
    }

    fn clean(count: u64, max_abs: f32) -> GroupStats {
        GroupStats { count, max_abs, underflow: 0, overflow: 0, finite: true }
    }

    fn cfg(init: f32, period: u32) -> ScalingConfig {
        ScalingConfig { init_scale: init, period, ..Default::default() }
    }

    #[test]
    fn per_group_backoff_leaves_others_untouched() {
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 4), AdaptiveTuning::default(), names(3));
        let stats = vec![
            clean(10, 0.1),
            GroupStats { count: 10, max_abs: 0.1, underflow: 0, overflow: 2, finite: true },
            clean(10, 0.1),
        ];
        assert!(!p.adjust(true, &stats)); // overflow anywhere skips
        assert_eq!(p.scale_of(0), 1024.0);
        assert_eq!(p.scale_of(1), 512.0);
        assert_eq!(p.scale_of(2), 1024.0);
        assert_eq!(p.skips_of(1), 1);
        assert_eq!(p.skips_of(0), 0);
    }

    #[test]
    fn growth_after_period_per_group() {
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 3), AdaptiveTuning::default(), names(2));
        let stats = vec![clean(10, 0.1), clean(10, 0.1)];
        for _ in 0..3 {
            assert!(p.adjust(true, &stats));
        }
        assert_eq!(p.scale_of(0), 2048.0);
        assert_eq!(p.scale_of(1), 2048.0);
        assert_eq!(p.growths(), 2);
    }

    #[test]
    fn headroom_gate_blocks_regrowth_after_spike() {
        // A group that once produced |g| = 64 must never be re-grown
        // into a scale where 64 overflows: 512·2·64 = 65536 >
        // 0.5·65520.
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 2), AdaptiveTuning::default(), names(2));
        let spike = GroupStats {
            count: 10,
            max_abs: 64.0,
            underflow: 0,
            overflow: 1,
            finite: true,
        };
        assert!(!p.adjust(true, &[spike, clean(10, 1e-3)]));
        assert_eq!(p.scale_of(0), 512.0);
        // Many clean steps: group 1 (tiny gradients — the gate never
        // binds below the cap) grows to the cap, group 0 stays pinned
        // at 512 by the headroom gate's memory of the 64.0 spike.
        let stats = vec![clean(10, 0.5), clean(10, 1e-3)];
        for _ in 0..100 {
            assert!(p.adjust(true, &stats));
        }
        assert_eq!(p.scale_of(0), 512.0);
        assert_eq!(p.scale_of(1), 16_777_216.0);
        // And 64 indeed no longer overflows at 512 while it does at
        // 1024 — the gate is doing real work.
        assert!(!spike_overflows(64.0, 512.0));
        assert!(spike_overflows(64.0, 1024.0));
    }

    #[test]
    fn underflow_pressure_shortens_the_period() {
        let mut p =
            AdaptivePolicy::new(cfg(2.0, 8), AdaptiveTuning::default(), names(1));
        // 10% of elements flushing ⇒ fast period = 8/4 = 2.
        let pressured = GroupStats {
            count: 100,
            max_abs: 1e-6,
            underflow: 10,
            overflow: 0,
            finite: true,
        };
        assert!(p.adjust(true, &[pressured]));
        assert!(p.adjust(true, &[pressured]));
        assert_eq!(p.scale_of(0), 4.0, "grew after 2 steps, not 8");
        // Without pressure the same schedule would still be counting.
        let mut q =
            AdaptivePolicy::new(cfg(2.0, 8), AdaptiveTuning::default(), names(1));
        assert!(q.adjust(true, &[clean(100, 1e-6)]));
        assert!(q.adjust(true, &[clean(100, 1e-6)]));
        assert_eq!(q.scale_of(0), 2.0);
    }

    #[test]
    fn graph_scale_is_min_group_scale() {
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 4), AdaptiveTuning::default(), names(2));
        assert_eq!(p.graph_scale(), 1024.0);
        let stats = vec![
            GroupStats { count: 1, max_abs: 0.1, underflow: 0, overflow: 1, finite: true },
            clean(1, 0.1),
        ];
        p.adjust(true, &stats);
        assert_eq!(p.graph_scale(), 512.0);
    }

    #[test]
    fn nonfinite_group_backs_off_and_skips() {
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 4), AdaptiveTuning::default(), names(1));
        let poisoned = GroupStats {
            count: 10,
            max_abs: 0.1,
            underflow: 0,
            overflow: 0,
            finite: false,
        };
        assert!(!p.adjust(false, &[poisoned]));
        assert_eq!(p.scale_of(0), 512.0);
        assert_eq!(p.skips_of(0), 1);
        assert_eq!(p.overflows(), 1);
    }

    #[test]
    fn snapshot_round_trips_exactly() {
        let mut p =
            AdaptivePolicy::new(cfg(1024.0, 3), AdaptiveTuning::default(), names(3));
        let stats = vec![
            clean(10, 0.5),
            GroupStats { count: 10, max_abs: 2.0, underflow: 0, overflow: 1, finite: true },
            clean(10, 0.1),
        ];
        p.adjust(true, &stats);
        p.adjust(true, &[clean(10, 0.5), clean(10, 2.0), clean(10, 0.1)]);
        let snap = p.snapshot();
        let q = AdaptivePolicy::restore(
            cfg(1024.0, 3),
            AdaptiveTuning::default(),
            names(3),
            &snap,
        )
        .unwrap();
        for g in 0..3 {
            assert_eq!(q.scale_of(g), p.scale_of(g));
            assert_eq!(q.counter_of(g), p.counter_of(g));
        }
    }

    #[test]
    fn v1_single_group_record_fans_out() {
        let saved = vec![GroupState {
            name: "global".to_string(),
            scale: 256.0,
            counter: 7,
        }];
        let p = AdaptivePolicy::restore(
            cfg(1024.0, 3),
            AdaptiveTuning::default(),
            names(4),
            &saved,
        )
        .unwrap();
        for g in 0..4 {
            assert_eq!(p.scale_of(g), 256.0);
            assert_eq!(p.counter_of(g), 7);
        }
    }

    #[test]
    fn mismatched_record_is_rejected() {
        let saved = vec![
            GroupState { name: "a".into(), scale: 1.0, counter: 0 },
            GroupState { name: "b".into(), scale: 1.0, counter: 0 },
        ];
        let err = AdaptivePolicy::restore(
            cfg(1024.0, 3),
            AdaptiveTuning::default(),
            names(2),
            &saved,
        )
        .unwrap_err();
        assert!(err.to_string().contains("different model layout"), "{err}");
    }
}
