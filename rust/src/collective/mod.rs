//! Collectives for the simulated multi-device data-parallel mode.
//!
//! The paper's cluster experiment divides each batch across 4 H100s
//! and all-reduces gradients (standard data parallelism).  Our
//! "devices" are shard slots on the one CPU PJRT client; the gradient
//! all-reduce happens here, in deterministic tree order, so results
//! are bit-identical run-to-run and independent of shard completion
//! order — a property the equivalence tests rely on and real
//! frameworks (NCCL with deterministic algorithms) aim for.
//!
//! The elementwise adds and the final mean scale run through the
//! chunk-parallel [`crate::hostkernel::reduce`] kernels: large
//! tensors are reduced over contiguous chunk ranges across worker
//! threads, while the pairwise *association* — which shard is added
//! into which, in which order — stays exactly the fixed tree below.
//! Per-element arithmetic is unchanged by the chunking, so the result
//! is still bitwise-deterministic across runs **and across thread
//! counts** (property-tested here and in
//! `rust/tests/hostkernel_props.rs`).

use crate::hostkernel::reduce::{add_assign, scale_in_place};

/// Mean-reduce shard gradient vectors in place into shard 0's buffer.
///
/// Deterministic pairwise tree reduction: `(g0+g1) + (g2+g3)` — the
/// same association every call, regardless of thread timing.
pub fn all_reduce_mean(shards: &mut Vec<Vec<Vec<f32>>>) {
    let n = shards.len();
    assert!(n > 0, "no shards");
    if n == 1 {
        return;
    }
    let num_tensors = shards[0].len();
    for s in shards.iter() {
        assert_eq!(s.len(), num_tensors, "shard tensor arity mismatch");
    }

    // Tree reduction over shard indices with fixed association; the
    // elementwise work inside each pair fans out over threads for
    // large tensors (hostkernel determinism contract).
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            // add shard i+stride into shard i
            let (left, right) = shards.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                debug_assert_eq!(d.len(), s.len());
                add_assign(d, s);
            }
            i += stride * 2;
        }
        stride *= 2;
    }

    let inv = 1.0 / n as f32;
    for t in shards[0].iter_mut() {
        scale_in_place(t, inv);
    }
}

/// The pre-`hostkernel` scalar tree reduction: identical fixed
/// pairwise association, single-threaded elementwise adds.  This is
/// the *semantic reference* [`all_reduce_mean`] must match bitwise —
/// kept in one place so the property tests and the `kernel_micro`
/// bench baseline can never drift apart.
#[doc(hidden)]
pub fn sequential_all_reduce_reference(shards: &mut [Vec<Vec<f32>>]) {
    let n = shards.len();
    assert!(n > 0, "no shards");
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = shards.split_at_mut(i + stride);
            for (d, s) in left[i].iter_mut().zip(right[0].iter()) {
                for (x, y) in d.iter_mut().zip(s.iter()) {
                    *x += *y;
                }
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    let inv = 1.0 / n as f32;
    for t in shards[0].iter_mut() {
        for x in t.iter_mut() {
            *x *= inv;
        }
    }
}

/// AND-reduce the per-shard finiteness flags (a single non-finite
/// shard poisons the global step — paper §2.1 step 6a applies to the
/// *global* gradient).  Panics on an empty shard list, like
/// [`all_reduce_mean`]: "no shards" must never read as "all finite".
pub fn all_reduce_finite(flags: &[bool]) -> bool {
    assert!(!flags.is_empty(), "no shards");
    flags.iter().all(|&f| f)
}

/// Merge per-shard, per-group gradient census records into the global
/// per-group view every rank agrees on (the input to
/// [`crate::scaling::ScalingPolicy::adjust`]).
///
/// Deterministic by construction: counts are exact integer sums,
/// `max_abs` is an exact commutative max, `finite` an AND — folded in
/// shard-index order, so the result is bitwise-identical regardless
/// of shard completion order or count (a 2-shard run and an 8-shard
/// run over the same global batch agree exactly on the counts).
pub fn all_reduce_group_stats(
    shards: &[Vec<crate::scaling::GroupStats>],
) -> Vec<crate::scaling::GroupStats> {
    assert!(!shards.is_empty(), "no shards");
    let num_groups = shards[0].len();
    for s in shards.iter() {
        assert_eq!(s.len(), num_groups, "shard group arity mismatch");
    }
    let mut out = vec![crate::scaling::GroupStats::finite_empty(); num_groups];
    for shard in shards {
        for (acc, st) in out.iter_mut().zip(shard.iter()) {
            acc.count += st.count;
            acc.underflow += st.underflow;
            acc.overflow += st.overflow;
            if st.max_abs > acc.max_abs {
                acc.max_abs = st.max_abs;
            }
            acc.finite &= st.finite;
        }
    }
    out
}

/// Mean-reduce per-shard losses (logging only).
pub fn mean_loss(losses: &[f32]) -> f32 {
    if losses.is_empty() {
        return f32::NAN;
    }
    losses.iter().sum::<f32>() / losses.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn make_shards(n: usize, vals: &[f32]) -> Vec<Vec<Vec<f32>>> {
        (0..n)
            .map(|s| vec![vals.iter().map(|v| v + s as f32).collect()])
            .collect()
    }

    #[test]
    fn mean_of_two() {
        let mut sh = make_shards(2, &[1.0, 3.0]);
        all_reduce_mean(&mut sh);
        assert_eq!(sh[0][0], vec![1.5, 3.5]);
    }

    #[test]
    fn mean_of_four_matches_naive() {
        let mut sh = make_shards(4, &[2.0]);
        all_reduce_mean(&mut sh);
        assert_eq!(sh[0][0], vec![2.0 + (0.0 + 1.0 + 2.0 + 3.0) / 4.0]);
    }

    #[test]
    fn odd_shard_count() {
        let mut sh = make_shards(3, &[0.0]);
        all_reduce_mean(&mut sh);
        assert!((sh[0][0][0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn single_shard_noop() {
        let mut sh = make_shards(1, &[5.0]);
        all_reduce_mean(&mut sh);
        assert_eq!(sh[0][0], vec![5.0]);
    }

    #[test]
    fn finite_flags() {
        assert!(all_reduce_finite(&[true, true]));
        assert!(!all_reduce_finite(&[true, false, true]));
    }

    #[test]
    #[should_panic(expected = "no shards")]
    fn finite_flags_empty_panics() {
        all_reduce_finite(&[]);
    }

    #[test]
    fn property_tree_matches_sequential_sum() {
        forall(
            100,
            |r: &mut Rng| {
                let n = 1 + r.below(8) as usize;
                let len = 1 + r.below(16) as usize;
                let shards: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        (0..len).map(|_| r.normal_f32(0.0, 1.0)).collect()
                    })
                    .collect();
                shards
            },
            |shards| {
                let n = shards.len();
                let len = shards[0].len();
                let mut wrapped: Vec<Vec<Vec<f32>>> =
                    shards.iter().map(|s| vec![s.clone()]).collect();
                all_reduce_mean(&mut wrapped);
                for i in 0..len {
                    let naive: f32 = shards.iter().map(|s| s[i]).sum::<f32>()
                        / n as f32;
                    let got = wrapped[0][0][i];
                    if (naive - got).abs() > 1e-4 * naive.abs().max(1.0) {
                        return Err(format!(
                            "elem {i}: tree {got} vs naive {naive}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn group_stats_reduce_sums_counts_and_maxes() {
        use crate::scaling::GroupStats;
        let shard = |c, m, u, o, f| GroupStats {
            count: c,
            max_abs: m,
            underflow: u,
            overflow: o,
            finite: f,
        };
        let shards = vec![
            vec![shard(10, 0.5, 1, 0, true), shard(4, 2.0, 0, 0, true)],
            vec![shard(10, 0.7, 2, 1, true), shard(4, 1.0, 0, 0, false)],
        ];
        let merged = all_reduce_group_stats(&shards);
        assert_eq!(merged[0], shard(20, 0.7, 3, 1, true));
        assert_eq!(merged[1], shard(8, 2.0, 0, 0, false));
        // Fold order is shard-index order: reversing the shard list
        // still yields identical results (all ops commutative/exact).
        let rev: Vec<_> = shards.iter().rev().cloned().collect();
        let merged_rev = all_reduce_group_stats(&rev);
        assert_eq!(merged[0].max_abs.to_bits(), merged_rev[0].max_abs.to_bits());
        assert_eq!(merged, merged_rev);
    }

    #[test]
    #[should_panic(expected = "no shards")]
    fn group_stats_reduce_empty_panics() {
        all_reduce_group_stats(&[]);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = make_shards(5, &[0.1, 0.2, 0.3]);
        let mut b = make_shards(5, &[0.1, 0.2, 0.3]);
        all_reduce_mean(&mut a);
        all_reduce_mean(&mut b);
        assert_eq!(a[0][0], b[0][0]); // bitwise
    }
}
