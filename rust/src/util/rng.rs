//! Deterministic PRNG (splitmix64 + xoshiro256**) — the `rand` crate
//! is unavailable offline.  Used by the synthetic datasets, the
//! mini-proptest generators and overflow-injection schedules; all
//! consumers seed explicitly so runs are reproducible bit-for-bit.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to expand the seed — avoids the all-zero state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-shard / per-epoch rngs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift with rejection for exactness.
        if n == 0 {
            return 0;
        }
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
