//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, gen, check)` draws seeded random inputs from `gen`
//! and asserts `check`; on failure it performs greedy shrinking via
//! the `Shrink` trait before panicking with the minimal
//! counter-example and the reproducing seed.
//!
//! Used on the coordinator's invariants: loss-scaling state machine,
//! f16/bf16 conversions, all-reduce determinism, dataset sharding.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate simplifications, in decreasing order of aggression.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            return Vec::new();
        }
        // geometric approach toward 0 and toward self (boundary hunt)
        let mut out = vec![0, self / 2];
        let mut delta = self / 4;
        while delta > 0 {
            out.push(self - delta);
            delta /= 2;
        }
        out.push(self - 1);
        out.dedup();
        out
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2, self - self.signum()]
        }
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            Vec::new()
        } else {
            vec![0.0, self / 2.0, self.trunc()]
        }
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            Vec::new()
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // shrink one element
        for (i, x) in self.iter().enumerate().take(4) {
            for smaller in x.shrink() {
                let mut v = self.clone();
                v[i] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `check` on `cases` random inputs; panic with a shrunk
/// counter-example on failure.  Seed comes from `MPX_PROPTEST_SEED`
/// (default 0xC0FFEE) so failures are reproducible.
pub fn forall<T, G, C>(cases: usize, mut gen: G, mut check: C)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let seed = std::env::var("MPX_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut rng = Rng::new(seed);

    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            // Greedy shrink: walk to a local minimum.
            let mut best = (input, msg);
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in best.0.shrink() {
                    budget -= 1;
                    if let Err(m) = check(&cand) {
                        best = (cand, m);
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            200,
            |r| r.below(1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall(
                500,
                |r| r.below(10_000),
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err(format!("{x} too big"))
                    }
                },
            );
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        // greedy shrink should land on exactly the boundary value 50
        assert!(msg.contains("input: 50"), "got: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5u64, 6, 7, 8];
        assert!(v.shrink().iter().any(|s| s.len() < v.len()));
    }
}
