//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Full RFC 8259 value model with the subset of escapes the AOT
//! manifests use.  Parsing is recursive-descent over bytes; numbers
//! are kept as `f64` plus an `i64` fast path (manifest shapes are
//! integers and must round-trip exactly).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            // exact below 2^53 (f64 integer-precision limit)
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9.007199254740992e15 =>
            {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly (stable key order — Obj is a BTreeMap).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` to `out` as a JSON string literal (quotes included,
/// specials escaped) — the one escaping implementation, shared by
/// [`Json::dump`] and hand-built JSON emitters (the serve transport).
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates in manifests never appear; map
                            // unpaired surrogates to the replacement char.
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "inputs": [
            {"name": "params.blocks[0].weight", "dtype": "f32",
             "shape": [64, 192], "group": "params", "trainable": true}
          ],
          "meta": {"batch": 8, "lr": 0.0003, "kernels": null}
        }"#;
        let v = Json::parse(doc).unwrap();
        let inp = &v.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[1].as_i64(),
                   Some(192));
        assert_eq!(inp.get("trainable").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("meta").unwrap().get("lr").unwrap().as_f64(),
                   Some(3e-4));
        assert_eq!(v.get("meta").unwrap().get("kernels"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ←""#).unwrap();
        assert_eq!(v.as_str(), Some("café ←"));
    }

    #[test]
    fn integer_roundtrip_exact() {
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_i64(), Some(9007199254740991));
    }

    #[test]
    fn nested_depth() {
        let doc = "[".repeat(50) + &"]".repeat(50);
        assert!(Json::parse(&doc).is_ok());
    }
}
