//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / median / p10 / p90, CSV
//! output under `bench_out/`, and a fixed text format the paper-figure
//! benches print so `bench_output.txt` reads like the paper's series.

use std::io::Write;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Stats {
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let ns: Vec<u64> = samples
            .iter()
            .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
            .collect();
        let pct = |p: f64| quantile_ns(&ns, p);
        Stats {
            iters: n,
            mean: total / n as u32,
            median: pct(0.5),
            p10: pct(0.1),
            p90: pct(0.9),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Rank-interpolated quantile over ascending integer-nanosecond
/// samples (Hyndman–Fan type 7, NumPy's `"linear"`): the rank of
/// quantile `q` over `n` samples is `h = q·(n-1)` and the value
/// interpolates between `x[⌊h⌋]` and `x[⌊h⌋+1]`.  Truncating `h`
/// instead under-reports upper tails on small samples.  Shared by
/// [`Stats`] and `metrics::LatencyHistogram` so the repo has exactly
/// one quantile definition.
///
/// `sorted_ns` must be non-empty and ascending.
pub fn quantile_ns(sorted_ns: &[u64], q: f64) -> Duration {
    let q = q.clamp(0.0, 1.0);
    let n = sorted_ns.len();
    let h = q * (n - 1) as f64;
    let lo = h.floor() as usize;
    let hi = (lo + 1).min(n - 1);
    let frac = h - lo as f64;
    let v = sorted_ns[lo] as f64
        + frac * (sorted_ns[hi] as f64 - sorted_ns[lo] as f64);
    Duration::from_nanos(v.round() as u64)
}

/// Benchmark configuration: bounded both by iteration count and by
/// wall-clock budget (heavy train steps run few iters, micro ops many).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 2, max_iters: 20, max_seconds: 10.0 }
    }
}

impl BenchOpts {
    pub fn quick() -> Self {
        BenchOpts { warmup_iters: 1, max_iters: 5, max_seconds: 5.0 }
    }

    /// Honour `MPX_BENCH_FULL=1` for longer, more stable runs and
    /// `MPX_BENCH_SMOKE=1` for the CI smoke job (compile + a couple
    /// of iterations, just enough to emit the report files).
    pub fn from_env(default: BenchOpts) -> BenchOpts {
        if std::env::var("MPX_BENCH_SMOKE").as_deref() == Ok("1") {
            BenchOpts { warmup_iters: 1, max_iters: 2, max_seconds: 2.0 }
        } else if std::env::var("MPX_BENCH_FULL").as_deref() == Ok("1") {
            BenchOpts {
                warmup_iters: default.warmup_iters.max(3),
                max_iters: default.max_iters * 3,
                max_seconds: default.max_seconds * 4.0,
            }
        } else {
            default
        }
    }
}

/// Time `f` under `opts`; `f` is the full operation (no batching).
pub fn bench<F: FnMut()>(opts: &BenchOpts, mut f: F) -> Stats {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let budget = Duration::from_secs_f64(opts.max_seconds);
    let start = Instant::now();
    let mut samples = Vec::with_capacity(opts.max_iters);
    for _ in 0..opts.max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > budget && !samples.is_empty() {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Collector that prints aligned rows and writes a CSV at the end.
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        println!("\n=== {title} ===");
        println!("{}", columns.join(","));
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        println!("{}", cells.join(","));
        self.rows.push(cells.to_vec());
    }

    /// Write `bench_out/<slug>.csv`; returns the path.
    pub fn write_csv(&self) -> std::io::Result<String> {
        std::fs::create_dir_all("bench_out")?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = format!("bench_out/{slug}.csv");
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// Machine-readable bench report: a flat list of named entries with
/// numeric metrics, written as `BENCH_<bench>.json` so the perf
/// trajectory of every kernel is diffable across PRs.
///
/// Hand-rolled writer (serde is unavailable offline), mirrored by the
/// parser in [`crate::util::json`]; non-finite metric values are
/// clamped to 0 so the output is always valid JSON.
pub struct JsonReport {
    bench: String,
    entries: Vec<(String, Vec<(String, f64)>)>,
}

impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Add one named entry with `(metric, value)` pairs.
    pub fn entry(&mut self, name: &str, metrics: &[(&str, f64)]) {
        self.entries.push((
            name.to_string(),
            metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    /// Write `BENCH_<bench>.json` in the current directory; returns
    /// the path.
    pub fn write(&self) -> std::io::Result<String> {
        let path = format!("BENCH_{}.json", self.bench);
        self.write_to(&path)?;
        Ok(path)
    }

    /// Write the report to an explicit path.
    pub fn write_to(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"bench\": \"{}\",", self.bench)?;
        writeln!(f, "  \"entries\": [")?;
        for (i, (name, metrics)) in self.entries.iter().enumerate() {
            let fields: Vec<String> = metrics
                .iter()
                .map(|(k, v)| {
                    let v = if v.is_finite() { *v } else { 0.0 };
                    format!("\"{k}\": {v:.6}")
                })
                .collect();
            writeln!(
                f,
                "    {{\"name\": \"{name}\", {}}}{}",
                fields.join(", "),
                if i + 1 < self.entries.len() { "," } else { "" }
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
            Duration::from_millis(4),
            Duration::from_millis(100),
        ]);
        assert_eq!(s.median, Duration::from_millis(3));
        assert!(s.p90 >= s.median && s.median >= s.p10);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(100));
    }

    #[test]
    fn bench_respects_iter_cap() {
        let opts = BenchOpts { warmup_iters: 0, max_iters: 7, max_seconds: 60.0 };
        let mut count = 0;
        let s = bench(&opts, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 7);
    }

    #[test]
    fn json_report_parses_back() {
        let mut rep = JsonReport::new("unit_test");
        rep.entry("cast_f16", &[("median_ns", 123.5), ("speedup", 4.2)]);
        rep.entry("scan", &[("median_ns", f64::NAN)]); // clamped to 0
        let path = std::env::temp_dir().join("BENCH_unit_test.json");
        let path = path.to_str().unwrap();
        rep.write_to(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("unit_test"));
        let entries = doc.get("entries").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(
            entries[0].get("name").and_then(|j| j.as_str()),
            Some("cast_f16")
        );
        assert_eq!(
            entries[0].get("speedup").and_then(|j| j.as_f64()),
            Some(4.2)
        );
        assert_eq!(entries[1].get("median_ns").and_then(|j| j.as_f64()), Some(0.0));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_respects_time_budget() {
        let opts = BenchOpts {
            warmup_iters: 0,
            max_iters: 1_000_000,
            max_seconds: 0.05,
        };
        let s = bench(&opts, || std::thread::sleep(Duration::from_millis(5)));
        assert!(s.iters < 1000);
    }
}
