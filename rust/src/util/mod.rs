//! Offline substrates: the crates we would normally pull from
//! crates.io (serde_json, rand, criterion, proptest) are unavailable
//! in this environment, so this module provides the minimal versions
//! the framework needs, built from scratch and unit-tested.

pub mod benchkit;
pub mod json;
pub mod proptest;
pub mod rng;

/// Format a byte count human-readably (`12.3 MiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in adaptive units (`1.23 ms`).
pub fn human_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_duration_units() {
        use std::time::Duration;
        assert_eq!(human_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(human_duration(Duration::from_micros(1500)), "1.500 ms");
        assert_eq!(human_duration(Duration::from_nanos(800)), "0.8 µs");
    }
}
