//! Software IEEE binary16 and bfloat16 — the numeric-format substrate.
//!
//! The Rust coordinator needs to reason about half-precision values
//! without executing XLA: checkpoint inspection, gradient statistics,
//! the memory model's dtype accounting, and — crucially — host-side
//! verification that the compiled graphs' casts behave like the paper
//! assumes (round-to-nearest-even, gradual underflow, saturation to
//! ±inf).  This module implements both 16-bit formats bit-exactly from
//! scratch (no `half` crate offline) and is property-tested against
//! the behaviour of the XLA-compiled casts in `rust/tests/`.
//!
//! Format parameters:
//!
//! | format   | sign | exponent | mantissa | max finite | min subnormal |
//! |----------|------|----------|----------|------------|---------------|
//! | binary16 | 1    | 5 (bias 15)  | 10   | 65504      | 5.96e-8       |
//! | bfloat16 | 1    | 8 (bias 127) | 7    | ~3.39e38   | ~9.18e-41     |
//!
//! float16's narrow exponent is *why* the paper needs loss scaling;
//! bfloat16 shares float32's exponent range, which is why it usually
//! does not (paper §3.1 / DESIGN.md).

pub mod f16;
pub mod bf16;

pub use bf16::Bf16;
pub use f16::F16;

/// Floating formats the pipeline moves data in (manifest `dtype`s).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FloatFormat {
    F32,
    F16,
    Bf16,
}

impl FloatFormat {
    pub fn bytes(self) -> usize {
        match self {
            FloatFormat::F32 => 4,
            FloatFormat::F16 | FloatFormat::Bf16 => 2,
        }
    }

    /// Largest finite value — the overflow threshold loss scaling
    /// must keep scaled gradients under.
    pub fn max_finite(self) -> f64 {
        match self {
            FloatFormat::F32 => f32::MAX as f64,
            FloatFormat::F16 => 65504.0,
            FloatFormat::Bf16 => 3.3895313892515355e38,
        }
    }

    /// Smallest positive subnormal — the underflow floor that makes
    /// tiny gradients vanish (paper §2.1).
    pub fn min_subnormal(self) -> f64 {
        match self {
            FloatFormat::F32 => f32::from_bits(1) as f64,
            FloatFormat::F16 => 5.960464477539063e-8,
            FloatFormat::Bf16 => {
                // exponent 0, mantissa 1 → 2^-126 * 2^-7
                2f64.powi(-133)
            }
        }
    }

    /// Round-trip an f32 through this format (identity for F32).
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            FloatFormat::F32 => x,
            FloatFormat::F16 => F16::from_f32(x).to_f32(),
            FloatFormat::Bf16 => Bf16::from_f32(x).to_f32(),
        }
    }
}

/// Statistics of a gradient/parameter buffer, computed in one pass —
/// used by the trainer's logging and the loss-scaling diagnostics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorStats {
    pub count: usize,
    pub finite: bool,
    pub min_abs_nonzero: f32,
    pub max_abs: f32,
    pub mean_abs: f32,
    pub zeros: usize,
    pub infs: usize,
    pub nans: usize,
}

pub fn tensor_stats(xs: &[f32]) -> TensorStats {
    let mut s = TensorStats {
        count: xs.len(),
        finite: true,
        min_abs_nonzero: f32::INFINITY,
        ..Default::default()
    };
    let mut sum_abs = 0f64;
    for &x in xs {
        if x.is_nan() {
            s.nans += 1;
            s.finite = false;
            continue;
        }
        if x.is_infinite() {
            s.infs += 1;
            s.finite = false;
            continue;
        }
        let a = x.abs();
        if a == 0.0 {
            s.zeros += 1;
        } else if a < s.min_abs_nonzero {
            s.min_abs_nonzero = a;
        }
        if a > s.max_abs {
            s.max_abs = a;
        }
        sum_abs += a as f64;
    }
    if s.count > 0 {
        s.mean_abs = (sum_abs / s.count as f64) as f32;
    }
    s
}

/// Fraction of elements a cast to `fmt` would flush to zero — the
/// underflow diagnostic behind the paper's Fig. 1 motivation.
/// Counts via the batch-cast kernels ([`crate::hostkernel::cast`]),
/// which are bit-identical to the scalar [`FloatFormat::quantize`].
pub fn underflow_fraction(xs: &[f32], fmt: FloatFormat) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let (lost, _over) = under_overflow_counts(xs, fmt);
    lost as f64 / xs.len() as f64
}

/// How many finite elements overflow to ±inf when cast to `fmt`?
/// Batch-kernel-backed like [`underflow_fraction`].
pub fn overflow_count(xs: &[f32], fmt: FloatFormat) -> usize {
    under_overflow_counts(xs, fmt).1
}

/// One fused counting pass over `xs`: (nonzero values that flush to
/// ±0, finite values that saturate to ±inf) under a cast to `fmt`.
pub fn under_overflow_counts(xs: &[f32], fmt: FloatFormat) -> (usize, usize) {
    match fmt {
        // f32→f32 is the identity: nothing flushes or saturates.
        FloatFormat::F32 => (0, 0),
        FloatFormat::F16 => crate::hostkernel::cast::f16_under_overflow_counts(xs),
        FloatFormat::Bf16 => crate::hostkernel::cast::bf16_under_overflow_counts(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parameters() {
        assert_eq!(FloatFormat::F16.bytes(), 2);
        assert_eq!(FloatFormat::F16.max_finite(), 65504.0);
        assert!(FloatFormat::Bf16.max_finite() > 1e38);
        assert!(FloatFormat::F16.min_subnormal() > 5.9e-8);
    }

    #[test]
    fn stats_basics() {
        let s = tensor_stats(&[0.0, 1.0, -2.0, f32::INFINITY]);
        assert_eq!(s.count, 4);
        assert!(!s.finite);
        assert_eq!(s.zeros, 1);
        assert_eq!(s.infs, 1);
        assert_eq!(s.max_abs, 2.0);
        assert_eq!(s.min_abs_nonzero, 1.0);
    }

    #[test]
    fn underflow_diagnostics() {
        // 1e-8 vanishes in f16 but not bf16 (bf16 has f32's exponent).
        let xs = [1e-8f32, 1.0];
        assert_eq!(underflow_fraction(&xs, FloatFormat::F16), 0.5);
        assert_eq!(underflow_fraction(&xs, FloatFormat::Bf16), 0.0);
    }

    #[test]
    fn overflow_diagnostics() {
        let xs = [70000.0f32, 1.0];
        assert_eq!(overflow_count(&xs, FloatFormat::F16), 1);
        assert_eq!(overflow_count(&xs, FloatFormat::Bf16), 0);
        assert_eq!(overflow_count(&xs, FloatFormat::F32), 0);
    }
}
