//! bfloat16, bit-exact, from scratch.
//!
//! bfloat16 is the top 16 bits of an IEEE float32 (1-8-7): same
//! exponent range as f32, 7-bit mantissa.  Conversion from f32 is a
//! truncation of the low 16 mantissa bits with round-to-nearest-even;
//! conversion to f32 is exact (shift left 16).  Because the exponent
//! range matches f32, gradients almost never under/overflow in bf16 —
//! the reason the paper's dynamic loss scaling is only essential for
//! float16 (DESIGN.md substitution table).

/// A bfloat16 value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const INFINITY: Bf16 = Bf16(0x7F80);
    pub const NEG_INFINITY: Bf16 = Bf16(0xFF80);
    /// Largest finite: ≈ 3.3895e38.
    pub const MAX: Bf16 = Bf16(0x7F7F);
    pub const NAN: Bf16 = Bf16(0x7FC0);

    /// f32 → bf16 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet the nan, preserve sign + payload top bits
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x8000u32;
        let lower = bits & 0xFFFF;
        let mut upper = (bits >> 16) as u16;
        if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
            upper = upper.wrapping_add(1); // may carry to inf — correct
        }
        Bf16(upper)
    }

    /// bf16 → f32, exact.
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7F80
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7F80) != 0x7F80
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn known_values() {
        assert_eq!(Bf16::from_f32(0.0).0, 0x0000);
        assert_eq!(Bf16::from_f32(1.0).0, 0x3F80);
        assert_eq!(Bf16::from_f32(-2.0).0, 0xC000);
        // 3.0 = 0x4040 0000
        assert_eq!(Bf16::from_f32(3.0).0, 0x4040);
    }

    #[test]
    fn roundtrip_exact_for_all_finite_bf16() {
        for bits in 0u16..=0xFFFF {
            let b = Bf16(bits);
            if b.is_nan() {
                assert!(Bf16::from_f32(b.to_f32()).is_nan());
            } else {
                assert_eq!(Bf16::from_f32(b.to_f32()).0, bits);
            }
        }
    }

    #[test]
    fn keeps_f32_exponent_range() {
        // 1e38 survives bf16 (would be inf in f16)
        assert!(Bf16::from_f32(1e38).is_finite());
        // 1e-38 survives too (would be 0 in f16)
        assert!(Bf16::from_f32(1e-38).to_f32() != 0.0);
        // but beyond f32 max it saturates
        assert!(Bf16::from_f32(f32::MAX).is_infinite()); // rounds up to inf
    }

    #[test]
    fn rounding_to_nearest_even() {
        // 1 + 2^-8 is halfway between 1.0 and 1+2^-7 → even (1.0)
        assert_eq!(Bf16::from_f32(1.0 + 2f32.powi(-8)).0, 0x3F80);
        // 1 + 3·2^-8 halfway → rounds to even neighbour 1+2^-6
        assert_eq!(Bf16::from_f32(1.0 + 3.0 * 2f32.powi(-8)).0, 0x3F82);
        // above halfway rounds up
        assert_eq!(Bf16::from_f32(1.0 + 2f32.powi(-8) + 1e-6).0, 0x3F81);
    }

    #[test]
    fn precision_is_coarser_than_f16_in_unit_range() {
        // bf16 ulp at 1.0 is 2^-7; f16's is 2^-10.
        let x = 1.0 + 2f32.powi(-9);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        assert_ne!(crate::numerics::F16::from_f32(x).to_f32(), 1.0);
    }

    #[test]
    fn nan_propagates() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert!(Bf16::NAN.to_f32().is_nan());
    }

    #[test]
    fn property_matches_truncation_semantics() {
        forall(
            2000,
            |r: &mut Rng| r.normal_f32(0.0, 1e3),
            |&x| {
                let q = Bf16::from_f32(x).to_f32();
                let rel = if x != 0.0 { ((x - q) / x).abs() } else { 0.0 };
                // 7 mantissa bits ⇒ relative error ≤ 2^-8
                if rel <= 2f32.powi(-8) {
                    Ok(())
                } else {
                    Err(format!("rel error {rel} too big for {x} → {q}"))
                }
            },
        );
    }

    #[test]
    fn property_monotone() {
        forall(
            2000,
            |r: &mut Rng| (r.normal_f32(0.0, 1e6), r.normal_f32(0.0, 1e6)),
            |&(a, b)| {
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                if Bf16::from_f32(lo).to_f32() <= Bf16::from_f32(hi).to_f32() {
                    Ok(())
                } else {
                    Err("monotonicity violated".into())
                }
            },
        );
    }
}
