//! IEEE 754 binary16, bit-exact, from scratch.
//!
//! Conversions implement round-to-nearest-even (the rounding XLA and
//! the paper's GPUs use), gradual underflow into subnormals, and
//! saturation to ±inf beyond 65504 — the exact overflow behaviour
//! dynamic loss scaling probes for (paper §2.1).

/// A binary16 value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

#[allow(dead_code)]
const EXP_BITS: u32 = 5;
const MAN_BITS: u32 = 10;
const EXP_BIAS: i32 = 15;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const NEG_ZERO: F16 = F16(0x8000);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    /// Largest finite value: 65504.
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive normal: 2^-14.
    pub const MIN_POSITIVE: F16 = F16(0x0400);
    /// Smallest positive subnormal: 2^-24.
    pub const MIN_SUBNORMAL: F16 = F16(0x0001);
    /// A quiet NaN.
    pub const NAN: F16 = F16(0x7E00);

    /// Convert from f32 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp32 = ((bits >> 23) & 0xFF) as i32;
        let man32 = bits & 0x7F_FFFF;

        if exp32 == 0xFF {
            // inf / nan — preserve nan-ness with a quiet mantissa bit.
            return if man32 == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00 | ((man32 >> 13) as u16 & 0x03FF))
            };
        }

        // unbiased exponent of the f32 value
        let e = exp32 - 127;

        if e > EXP_BIAS {
            // overflow → ±inf (65520 and above round away; values in
            // (65504, 65520) round to 65504 — handled by the rounding
            // path below only when e == 15, so check the boundary):
            if e == EXP_BIAS + 1 && man32 == 0 {
                // exactly 65536 → inf
                return F16(sign | 0x7C00);
            }
            return F16(sign | 0x7C00);
        }

        if e >= -14 {
            // normal range: assemble with rounding
            let exp16 = (e + EXP_BIAS) as u32; // 1..=30
            let man_shifted = man32 >> 13; // keep 10 bits
            let round_bits = man32 & 0x1FFF; // dropped 13 bits
            let mut h = (sign as u32) | (exp16 << MAN_BITS) | man_shifted;
            // round to nearest even
            if round_bits > 0x1000 || (round_bits == 0x1000 && (h & 1) == 1) {
                h += 1; // may carry into exponent — that is correct
                        // (mantissa overflow bumps the exponent, and
                        // 65504+ulp/2 correctly becomes inf)
            }
            return F16(h as u16);
        }

        if e >= -14 - (MAN_BITS as i32) - 1 {
            // subnormal range: implicit leading 1 becomes explicit
            let full_man = man32 | 0x80_0000; // 24-bit significand
            let shift = (-14 - e) as u32 + 13; // ≥ 14
            let man = full_man >> shift;
            let round_mask = 1u32 << (shift - 1);
            let rem = full_man & ((1 << shift) - 1);
            let mut h = (sign as u32) | man;
            if rem > round_mask || (rem == round_mask && (h & 1) == 1) {
                h += 1;
            }
            return F16(h as u16);
        }

        // underflow to (signed) zero
        F16(sign)
    }

    /// Convert to f32 (exact — every f16 is representable in f32).
    pub fn to_f32(self) -> f32 {
        let h = self.0 as u32;
        let sign = (h & 0x8000) << 16;
        let exp = (h >> MAN_BITS) & 0x1F;
        let man = h & 0x03FF;

        let bits = if exp == 0 {
            if man == 0 {
                sign // ±0
            } else {
                // subnormal: value = man · 2^-24 = 1.f · 2^(p-24) where
                // p is the position of man's leading 1 (lz = 10 - p).
                let lz = man.leading_zeros() - (32 - MAN_BITS - 1); // 1..=10
                let exp32 = 113 - lz; // (p - 24) + 127
                let man_norm = (man << lz) & 0x03FF;
                sign | (exp32 << 23) | (man_norm << 13)
            }
        } else if exp == 0x1F {
            if man == 0 {
                sign | 0x7F80_0000 // ±inf
            } else {
                sign | 0x7FC0_0000 | (man << 13) // nan
            }
        } else {
            let exp32 = exp + 127 - 15;
            sign | (exp32 << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }

    pub fn is_subnormal(self) -> bool {
        (self.0 & 0x7C00) == 0 && (self.0 & 0x03FF) != 0
    }

    /// Units in the last place distance (bit-pattern metric for tests).
    pub fn ulp_distance(self, other: F16) -> u32 {
        let a = Self::monotone_bits(self.0);
        let b = Self::monotone_bits(other.0);
        a.abs_diff(b)
    }

    fn monotone_bits(b: u16) -> i32 {
        // map sign-magnitude to a monotone integer line
        if b & 0x8000 != 0 {
            -((b & 0x7FFF) as i32)
        } else {
            (b & 0x7FFF) as i32
        }
    }
}

/// Quantize an f32 slice through f16 in place (fast path for tests
/// and the checkpoint inspector).
pub fn quantize_slice(xs: &mut [f32]) {
    for x in xs {
        *x = F16::from_f32(*x).to_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn known_values() {
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(0.5).0, 0x3800);
        assert_eq!(F16::from_f32(0.099975586).0, 0x2E66); // ≈0.1
    }

    #[test]
    fn roundtrip_exact_for_all_finite_f16() {
        // Exhaustive: every finite f16 bit pattern survives f32 round-trip.
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            if h.is_nan() {
                assert!(F16::from_f32(h.to_f32()).is_nan());
            } else {
                assert_eq!(F16::from_f32(h.to_f32()).0, bits,
                           "bits={bits:#06x} f32={}", h.to_f32());
            }
        }
    }

    #[test]
    fn overflow_saturates_to_inf() {
        assert!(F16::from_f32(65520.0).is_infinite());
        assert!(F16::from_f32(1e9).is_infinite());
        assert_eq!(F16::from_f32(-1e9).0, 0xFC00);
        // 65504 + less than half ulp rounds back down
        assert_eq!(F16::from_f32(65519.0).0, 0x7BFF);
    }

    #[test]
    fn underflow_flushes_to_zero() {
        assert_eq!(F16::from_f32(1e-8).0, 0x0000);
        assert_eq!(F16::from_f32(-1e-8).0, 0x8000);
        // half the smallest subnormal rounds to zero (ties-to-even)
        assert_eq!(F16::from_f32(2.9802322e-8).0, 0x0000);
        // just above half rounds up to the smallest subnormal
        assert_eq!(F16::from_f32(3.1e-8).0, 0x0001);
    }

    #[test]
    fn subnormals_gradual() {
        let min_sub = F16(0x0001).to_f32();
        assert!((min_sub - 5.9604645e-8).abs() < 1e-12);
        assert!(F16(0x0001).is_subnormal());
        assert!(!F16(0x0400).is_subnormal()); // smallest normal
        assert_eq!(F16::from_f32(min_sub * 3.0).0, 0x0003);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 → even (1.0)
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11)).0, 0x3C00);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9 → even (1+2^-9... )
        assert_eq!(F16::from_f32(1.0 + 3.0 * 2f32.powi(-11)).0, 0x3C02);
        // slightly above halfway rounds up
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11) + 1e-7).0, 0x3C01);
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::NAN.to_f32().is_nan());
    }

    #[test]
    fn property_roundtrip_is_nearest(){
        // For random f32 in f16's range, |x - q(x)| ≤ ulp/2 around x.
        forall(
            2000,
            |r: &mut Rng| (r.next_f32() * 2.0 - 1.0) * 60000.0,
            |&x| {
                let q = F16::from_f32(x).to_f32();
                // neighbouring f16 values around q
                let up = F16(F16::from_f32(x).0.wrapping_add(1)).to_f32();
                let dn = F16(F16::from_f32(x).0.wrapping_sub(1)).to_f32();
                let d = (x - q).abs();
                if d <= (x - up).abs() + 1e-9 && d <= (x - dn).abs() + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("q={q} not nearest for {x} (up={up}, dn={dn})"))
                }
            },
        );
    }

    #[test]
    fn property_monotone() {
        forall(
            2000,
            |r: &mut Rng| {
                let a = (r.next_f32() * 2.0 - 1.0) * 70000.0;
                let b = (r.next_f32() * 2.0 - 1.0) * 70000.0;
                (a, b)
            },
            |&(a, b)| {
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                let qlo = F16::from_f32(lo).to_f32();
                let qhi = F16::from_f32(hi).to_f32();
                if qlo <= qhi {
                    Ok(())
                } else {
                    Err(format!("monotonicity violated: q({lo})={qlo} > q({hi})={qhi}"))
                }
            },
        );
    }
}
