//! Deep HLO-text parser: the full program structure — computations,
//! instruction operands, attributes, constants — as an executable
//! graph, not just the per-line census [`super::HloModule`] keeps.
//!
//! This is the frontend of the host interpreter backend
//! (`runtime::host`): it parses exactly the dialect `xla_extension`
//! 0.5.1 prints for the AOT artifacts (names without `%` sigils,
//! `/*index=N*/` comments inside tuple shapes, region computations
//! named `region_K.N` / `None.N`), and it can print a program back
//! out. Printing normalizes away layout annotations (`{1,0}`) and
//! comments, so `parse → print → parse` is a fixpoint — the property
//! `hlo_props.rs` pins on every checked-in artifact.
//!
//! Attribute values are kept as raw text in source order (so printing
//! is faithful) with typed accessors (`attr_usize_list`, …) that the
//! interpreter's lowering uses.

use anyhow::{bail, Context, Result};

use crate::pytree::DType;

/// An array or tuple shape. Layout annotations are not represented:
/// every artifact buffer is dense row-major (descending layout), which
/// is what the manifest byte contract and the interpreter assume.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GShape {
    Array { dtype: DType, dims: Vec<usize> },
    Tuple(Vec<GShape>),
}

impl GShape {
    pub fn elems(&self) -> usize {
        match self {
            GShape::Array { dims, .. } => dims.iter().product::<usize>().max(1),
            GShape::Tuple(parts) => parts.iter().map(|p| p.elems()).sum(),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            GShape::Array { dtype, .. } => dtype.bytes() * self.elems(),
            GShape::Tuple(parts) => parts.iter().map(|p| p.bytes()).sum(),
        }
    }

    /// Array dtype; errors on tuples.
    pub fn dtype(&self) -> Result<DType> {
        match self {
            GShape::Array { dtype, .. } => Ok(*dtype),
            GShape::Tuple(_) => bail!("tuple shape has no single dtype"),
        }
    }

    /// Array dims; errors on tuples.
    pub fn dims(&self) -> Result<&[usize]> {
        match self {
            GShape::Array { dims, .. } => Ok(dims),
            GShape::Tuple(_) => bail!("tuple shape has no single dims"),
        }
    }

    pub fn rank(&self) -> usize {
        match self {
            GShape::Array { dims, .. } => dims.len(),
            GShape::Tuple(parts) => parts.len(),
        }
    }

    fn print_into(&self, out: &mut String) {
        match self {
            GShape::Array { dtype, dims } => {
                out.push_str(dtype.name());
                out.push('[');
                for (i, d) in dims.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&d.to_string());
                }
                out.push(']');
            }
            GShape::Tuple(parts) => {
                out.push('(');
                for (i, p) in parts.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    p.print_into(out);
                }
                out.push(')');
            }
        }
    }

    pub fn print(&self) -> String {
        let mut s = String::new();
        self.print_into(&mut s);
        s
    }
}

/// One fully parsed instruction.
#[derive(Debug, Clone, PartialEq)]
pub struct GInstr {
    pub name: String,
    pub opcode: String,
    pub shape: GShape,
    /// Operand instruction names (empty for `parameter`/`constant`).
    pub operands: Vec<String>,
    /// `key=value` attributes, raw value text, in source order.
    pub attrs: Vec<(String, String)>,
    /// `constant(...)` payload or `parameter(N)` index, raw.
    pub payload: Option<String>,
    pub is_root: bool,
}

impl GInstr {
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn attr_required(&self, key: &str) -> Result<&str> {
        self.attr(key).with_context(|| {
            format!("{} {}: missing attribute {key}", self.opcode, self.name)
        })
    }

    /// Parse a `{a, b, c}` (or bare `N`) attribute into integers.
    pub fn attr_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        parse_usize_list(self.attr_required(key)?)
            .with_context(|| format!("{}: attribute {key}", self.name))
    }

    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        let v = self.attr_required(key)?;
        v.trim()
            .parse::<usize>()
            .with_context(|| format!("{}: attribute {key}={v}", self.name))
    }

    /// `parameter(N)` index.
    pub fn param_index(&self) -> Result<usize> {
        let p = self.payload.as_deref().with_context(|| {
            format!("parameter {} has no index payload", self.name)
        })?;
        p.trim()
            .parse::<usize>()
            .with_context(|| format!("parameter {}: bad index {p}", self.name))
    }

    fn print_into(&self, out: &mut String) {
        out.push_str("  ");
        if self.is_root {
            out.push_str("ROOT ");
        }
        out.push_str(&self.name);
        out.push_str(" = ");
        self.shape.print_into(out);
        out.push(' ');
        out.push_str(&self.opcode);
        out.push('(');
        if let Some(p) = &self.payload {
            out.push_str(p);
        } else {
            for (i, o) in self.operands.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(o);
            }
        }
        out.push(')');
        for (k, v) in &self.attrs {
            out.push_str(", ");
            out.push_str(k);
            out.push('=');
            out.push_str(v);
        }
        out.push('\n');
    }
}

/// One computation (`ENTRY main.N { … }` or a region).
#[derive(Debug, Clone, PartialEq)]
pub struct GComputation {
    pub name: String,
    pub is_entry: bool,
    pub instrs: Vec<GInstr>,
}

impl GComputation {
    /// Index of the ROOT instruction (last instruction if unmarked).
    pub fn root_index(&self) -> Result<usize> {
        if let Some(i) = self.instrs.iter().position(|i| i.is_root) {
            return Ok(i);
        }
        if self.instrs.is_empty() {
            bail!("computation {} has no instructions", self.name);
        }
        Ok(self.instrs.len() - 1)
    }

    pub fn find(&self, name: &str) -> Option<usize> {
        self.instrs.iter().position(|i| i.name == name)
    }

    /// Parameter instruction indices ordered by parameter number.
    pub fn params(&self) -> Result<Vec<usize>> {
        let mut ps: Vec<(usize, usize)> = Vec::new();
        for (i, instr) in self.instrs.iter().enumerate() {
            if instr.opcode == "parameter" {
                ps.push((instr.param_index()?, i));
            }
        }
        ps.sort();
        for (slot, (num, _)) in ps.iter().enumerate() {
            if *num != slot {
                bail!(
                    "computation {}: parameter numbers not dense ({num} at slot {slot})",
                    self.name
                );
            }
        }
        Ok(ps.into_iter().map(|(_, i)| i).collect())
    }
}

/// A parsed HLO module: named computations plus the entry.
#[derive(Debug, Clone, PartialEq)]
pub struct HloProgram {
    pub module_name: String,
    pub computations: Vec<GComputation>,
}

impl HloProgram {
    pub fn parse(text: &str) -> Result<HloProgram> {
        let mut program = HloProgram {
            module_name: String::new(),
            computations: Vec::new(),
        };
        let mut current: Option<GComputation> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comments(raw);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(rest) = trimmed.strip_prefix("HloModule ") {
                program.module_name = rest
                    .split([',', ' '])
                    .next()
                    .unwrap_or("")
                    .to_string();
                continue;
            }
            if trimmed == "}" {
                let comp = current.take().with_context(|| {
                    format!("line {}: unmatched closing brace", lineno + 1)
                })?;
                program.computations.push(comp);
                continue;
            }
            if let Some(header) = trimmed.strip_suffix('{') {
                // `region_0.104 {` or `ENTRY main.164 {`
                let header = header.trim();
                let (is_entry, name) = match header.strip_prefix("ENTRY ") {
                    Some(n) => (true, n.trim()),
                    None => (false, header),
                };
                // A shape-y header (`f32[… {` from a wrapped line)
                // would be malformed; computation names are idents.
                if current.is_some() {
                    bail!("line {}: nested computation {name}", lineno + 1);
                }
                current = Some(GComputation {
                    name: name.trim_start_matches('%').to_string(),
                    is_entry,
                    instrs: Vec::new(),
                });
                continue;
            }
            let comp = current.as_mut().with_context(|| {
                format!("line {}: instruction outside computation", lineno + 1)
            })?;
            let instr = parse_instr(trimmed).with_context(|| {
                format!("line {}: {trimmed}", lineno + 1)
            })?;
            comp.instrs.push(instr);
        }
        if current.is_some() {
            bail!("unterminated computation at end of module");
        }
        if program.computations.is_empty() {
            bail!("no computations parsed — not HLO text?");
        }
        Ok(program)
    }

    pub fn entry(&self) -> Result<&GComputation> {
        self.computations
            .iter()
            .find(|c| c.is_entry)
            .context("module has no ENTRY computation")
    }

    pub fn computation(&self, name: &str) -> Option<&GComputation> {
        self.computations.iter().find(|c| c.name == name)
    }

    pub fn computation_index(&self, name: &str) -> Option<usize> {
        self.computations.iter().position(|c| c.name == name)
    }

    /// Print the program back to HLO text (layouts and comments
    /// normalized away). `parse(print(p)) == p`.
    pub fn print(&self) -> String {
        let mut out = String::new();
        out.push_str("HloModule ");
        out.push_str(&self.module_name);
        out.push('\n');
        for comp in &self.computations {
            out.push('\n');
            if comp.is_entry {
                out.push_str("ENTRY ");
            }
            out.push_str(&comp.name);
            out.push_str(" {\n");
            for instr in &comp.instrs {
                instr.print_into(&mut out);
            }
            out.push_str("}\n");
        }
        out
    }
}

/// Remove `/* … */` comments (the printer's `/*index=N*/` markers).
fn strip_comments(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut rest = line;
    while let Some(start) = rest.find("/*") {
        out.push_str(&rest[..start]);
        match rest[start..].find("*/") {
            Some(end) => rest = &rest[start + end + 2..],
            None => return out, // unterminated: drop the tail
        }
    }
    out.push_str(rest);
    out
}

/// Split on `sep` at nesting depth zero w.r.t. `()[]{}`.
fn split_top_level(s: &str, sep: char) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth = depth.saturating_sub(1),
            c2 if c2 == sep && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Parse `{a, b, c}`, `{}`, or a bare integer into a list.
pub fn parse_usize_list(v: &str) -> Result<Vec<usize>> {
    let v = v.trim();
    let inner = v
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .unwrap_or(v);
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(
            tok.parse::<usize>()
                .with_context(|| format!("bad integer {tok:?} in {v:?}"))?,
        );
    }
    Ok(out)
}

/// Parse a shape starting at `s`; returns the shape and the rest of
/// the string after it (layout annotation consumed).
fn parse_shape_prefix(s: &str) -> Result<(GShape, &str)> {
    let s = s.trim_start();
    if let Some(rest) = s.strip_prefix('(') {
        // tuple: shapes separated by top-level commas up to ')'
        let mut depth = 1usize;
        let mut end = None;
        for (i, c) in rest.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = Some(i);
                        break;
                    }
                }
                _ => {}
            }
        }
        let end = end.context("unterminated tuple shape")?;
        let inner = &rest[..end];
        let mut parts = Vec::new();
        for piece in split_top_level(inner, ',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (shape, tail) = parse_shape_prefix(piece)?;
            if !tail.trim().is_empty() {
                bail!("trailing text {tail:?} after tuple element shape");
            }
            parts.push(shape);
        }
        return Ok((GShape::Tuple(parts), &rest[end + 1..]));
    }
    // array: dtype[dims]{layout}?
    let bracket = s.find('[').context("shape has no '['")?;
    let dtype = DType::parse(s[..bracket].trim())
        .with_context(|| format!("bad dtype in shape {s:?}"))?;
    let rest = &s[bracket + 1..];
    let close = rest.find(']').context("shape has no ']'")?;
    let dims_str = &rest[..close];
    let mut dims = Vec::new();
    for d in dims_str.split(',') {
        let d = d.trim();
        if d.is_empty() {
            continue;
        }
        dims.push(
            d.parse::<usize>()
                .with_context(|| format!("bad dim {d:?} in shape {s:?}"))?,
        );
    }
    let mut after = &rest[close + 1..];
    // consume layout `{…}` if present (may be nested, e.g. tiling)
    let trimmed = after.trim_start();
    if let Some(body) = trimmed.strip_prefix('{') {
        let mut depth = 1usize;
        let mut end = None;
        for (i, c) in body.char_indices() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = Some(i);
                        break;
                    }
                }
                _ => {}
            }
        }
        let end = end.context("unterminated layout annotation")?;
        after = &body[end + 1..];
    }
    Ok((GShape::Array { dtype, dims }, after))
}

/// Parse one instruction body line.
fn parse_instr(line: &str) -> Result<GInstr> {
    let (is_root, body) = match line.strip_prefix("ROOT ") {
        Some(b) => (true, b),
        None => (false, line),
    };
    let (lhs, rhs) = body
        .split_once(" = ")
        .context("instruction line has no ' = '")?;
    let name = lhs.trim().trim_start_matches('%').to_string();

    let (shape, rest) = parse_shape_prefix(rhs)?;
    let rest = rest.trim_start();
    let paren = rest.find('(').context("instruction has no operand list")?;
    let opcode = rest[..paren].trim().to_string();
    if opcode.is_empty() || !opcode.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.') {
        bail!("bad opcode token {:?}", &rest[..paren]);
    }
    // balanced-paren operand list (constants never nest parens, but
    // stay safe anyway)
    let args_body = &rest[paren + 1..];
    let mut depth = 1usize;
    let mut end = None;
    for (i, c) in args_body.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    end = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let end = end.context("unterminated operand list")?;
    let args = args_body[..end].trim();
    let tail = args_body[end + 1..].trim_start();

    let mut operands = Vec::new();
    let mut payload = None;
    if opcode == "constant" || opcode == "parameter" {
        payload = Some(args.to_string());
    } else if !args.is_empty() {
        for op in split_top_level(args, ',') {
            operands.push(op.trim().trim_start_matches('%').to_string());
        }
    }

    let mut attrs = Vec::new();
    if let Some(tail) = tail.strip_prefix(',') {
        for piece in split_top_level(tail, ',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (k, v) = piece
                .split_once('=')
                .with_context(|| format!("attribute {piece:?} has no '='"))?;
            attrs.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    Ok(GInstr { name, opcode, shape, operands, attrs, payload, is_root })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_step, entry_computation_layout={(s32[])->(f32[8,10]{1,0}, /*index=1*/pred[])}

region_0.10 {
  Arg_0.11 = f32[] parameter(0)
  Arg_1.12 = f32[] parameter(1)
  ROOT add.13 = f32[] add(Arg_0.11, Arg_1.12)
}

ENTRY main.42 {
  Arg_0.1 = f32[8,64]{1,0} parameter(0)
  constant.3 = f32[] constant(-inf)
  constant.4 = s32[2]{0} constant({13, 15})
  slice.5 = f32[8,32]{1,0} slice(Arg_0.1), slice={[0:8], [0:32]}
  reduce.6 = f32[8]{0} reduce(Arg_0.1, constant.3), dimensions={1}, to_apply=region_0.10
  compare.7 = pred[8]{0} compare(reduce.6, reduce.6), direction=GE
  ROOT tuple.8 = (f32[8]{0}, pred[8]{0}) tuple(reduce.6, compare.7)
}
"#;

    #[test]
    fn parses_structure() {
        let p = HloProgram::parse(SAMPLE).unwrap();
        assert_eq!(p.module_name, "jit_step");
        assert_eq!(p.computations.len(), 2);
        let entry = p.entry().unwrap();
        assert_eq!(entry.name, "main.42");
        assert_eq!(entry.instrs.len(), 7);
        assert_eq!(entry.root_index().unwrap(), 6);
        let region = p.computation("region_0.10").unwrap();
        assert_eq!(region.params().unwrap().len(), 2);
    }

    #[test]
    fn operands_and_attrs() {
        let p = HloProgram::parse(SAMPLE).unwrap();
        let entry = p.entry().unwrap();
        let reduce = &entry.instrs[entry.find("reduce.6").unwrap()];
        assert_eq!(reduce.operands, vec!["Arg_0.1", "constant.3"]);
        assert_eq!(reduce.attr_usize_list("dimensions").unwrap(), vec![1]);
        assert_eq!(reduce.attr("to_apply"), Some("region_0.10"));
        let slice = &entry.instrs[entry.find("slice.5").unwrap()];
        // nested brackets survive top-level attr splitting
        assert_eq!(slice.attr("slice"), Some("{[0:8], [0:32]}"));
        let cmp = &entry.instrs[entry.find("compare.7").unwrap()];
        assert_eq!(cmp.attr("direction"), Some("GE"));
    }

    #[test]
    fn tuple_shapes_and_comments() {
        let p = HloProgram::parse(SAMPLE).unwrap();
        let entry = p.entry().unwrap();
        let root = &entry.instrs[entry.root_index().unwrap()];
        match &root.shape {
            GShape::Tuple(parts) => {
                assert_eq!(parts.len(), 2);
                assert_eq!(parts[0].dims().unwrap(), &[8]);
                assert_eq!(parts[1].dtype().unwrap(), DType::Pred);
            }
            other => panic!("root not tuple: {other:?}"),
        }
    }

    #[test]
    fn constants_keep_payload() {
        let p = HloProgram::parse(SAMPLE).unwrap();
        let entry = p.entry().unwrap();
        let c3 = &entry.instrs[entry.find("constant.3").unwrap()];
        assert_eq!(c3.payload.as_deref(), Some("-inf"));
        let c4 = &entry.instrs[entry.find("constant.4").unwrap()];
        assert_eq!(c4.payload.as_deref(), Some("{13, 15}"));
    }

    #[test]
    fn print_parse_fixpoint() {
        let p1 = HloProgram::parse(SAMPLE).unwrap();
        let text = p1.print();
        let p2 = HloProgram::parse(&text).unwrap();
        assert_eq!(p1, p2);
        // and printing is itself stable
        assert_eq!(text, p2.print());
    }

    #[test]
    fn rejects_garbage() {
        assert!(HloProgram::parse("not hlo").is_err());
        assert!(HloProgram::parse("ENTRY e {\n  x = f32[2] bogus\n}\n").is_err());
    }
}
