//! HLO-text parser — enough structure for the buffer census and flop
//! counting the memory model needs (not a general HLO frontend).
//!
//! An artifact's `.hlo.txt` contains computations whose body lines
//! look like:
//!
//! ```text
//!   %dot.42 = f32[64,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, ...
//!   %p.3 = f32[8,3,32,32]{3,2,1,0} parameter(3)
//! ```
//!
//! We extract per-instruction: name, opcode, output dtype/shape — and
//! for `dot`/`convolution` the operand shapes (flop estimation).  The
//! census then aggregates bytes by dtype and by opcode class, which is
//! the Fig. 2 cross-check: XLA materializes exactly these buffers.

pub mod graph;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::pytree::DType;

/// One parsed instruction.
#[derive(Debug, Clone)]
pub struct Instruction {
    pub name: String,
    pub opcode: String,
    pub dtype: Option<DType>,
    pub shape: Vec<usize>,
    /// Is this inside the entry computation (vs a fusion/sub-comp)?
    pub in_entry: bool,
}

impl Instruction {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.dtype.map(|d| d.bytes() * self.elems()).unwrap_or(0)
    }
}

/// Parsed module: instruction list + entry-computation flag.
#[derive(Debug, Default)]
pub struct HloModule {
    pub instructions: Vec<Instruction>,
}

impl HloModule {
    pub fn parse(text: &str) -> Result<HloModule> {
        let mut out = HloModule::default();
        let mut in_entry = false;

        for line in text.lines() {
            let trimmed = line.trim_start();
            // computation headers: `ENTRY main.123 {` (xla_extension
            // 0.5.1 prints names without the % sigil)
            if trimmed.starts_with("ENTRY ") {
                in_entry = true;
                continue;
            }
            if trimmed == "}" {
                in_entry = false;
                continue;
            }
            if trimmed.starts_with("HloModule") || !trimmed.contains(" = ") {
                continue;
            }
            if let Some(instr) = parse_instruction(trimmed, in_entry)? {
                out.instructions.push(instr);
            }
        }
        if out.instructions.is_empty() {
            bail!("no instructions parsed — not HLO text?");
        }
        Ok(out)
    }

    pub fn entry_instructions(&self) -> impl Iterator<Item = &Instruction> {
        self.instructions.iter().filter(|i| i.in_entry)
    }

    /// Bytes of parameter buffers in the entry computation.
    pub fn parameter_bytes(&self) -> u64 {
        self.entry_instructions()
            .filter(|i| i.opcode == "parameter")
            .map(|i| i.bytes() as u64)
            .sum()
    }

    /// Bytes by dtype over all non-parameter entry instructions — an
    /// upper bound on XLA's workspace (before buffer reuse).
    pub fn workspace_bytes_by_dtype(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        for i in self.entry_instructions() {
            if i.opcode == "parameter" {
                continue;
            }
            if let Some(d) = i.dtype {
                *m.entry(d.name()).or_insert(0) += i.bytes() as u64;
            }
        }
        m
    }

    /// Count of instructions per opcode (graph-shape diagnostics).
    pub fn opcode_histogram(&self) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for i in &self.instructions {
            *m.entry(i.opcode.clone()).or_insert(0) += 1;
        }
        m
    }

    /// Rough matmul flops: 2·∏(output dims)·K summed over `dot`s.
    /// K is not recoverable from the output shape alone, so the census
    /// stores dots' *output* sizes; flop totals come from the analytic
    /// model. This helper reports total dot output elements instead.
    pub fn dot_output_elems(&self) -> u64 {
        self.instructions
            .iter()
            .filter(|i| i.opcode == "dot")
            .map(|i| i.elems() as u64)
            .sum()
    }
}

/// Parse one instruction line, `None` for lines we deliberately skip
/// (tuple-shaped results — the root tuple aliases the real buffers).
fn parse_instruction(line: &str, in_entry: bool) -> Result<Option<Instruction>> {
    let body = line.strip_prefix("ROOT ").unwrap_or(line);
    let Some((lhs, rhs)) = body.split_once(" = ") else {
        return Ok(None);
    };
    let name = lhs.trim().trim_start_matches('%').to_string();
    let rhs = rhs.trim_start();

    // tuple-shaped: starts with '('
    if rhs.starts_with('(') {
        // opcode comes after the closing paren; we only need it for
        // the histogram — record with no dtype/shape.
        let opcode = rhs
            .split(") ")
            .nth(1)
            .and_then(|r| r.split(['(', ' ']).next())
            .unwrap_or("tuple")
            .to_string();
        return Ok(Some(Instruction {
            name,
            opcode,
            dtype: None,
            shape: Vec::new(),
            in_entry,
        }));
    }

    // `f32[8,3,32,32]{3,2,1,0} opcode(...)` or `f32[] opcode(...)`
    let Some(bracket) = rhs.find('[') else {
        return Ok(None);
    };
    let dtype_str = &rhs[..bracket];
    let rest = &rhs[bracket + 1..];
    let Some(close) = rest.find(']') else {
        return Ok(None);
    };
    let dims_str = &rest[..close];
    let after = rest[close + 1..].trim_start();
    // skip layout `{...}` if present
    let after = if let Some(stripped) = after.strip_prefix('{') {
        match stripped.find('}') {
            Some(i) => stripped[i + 1..].trim_start(),
            None => return Ok(None),
        }
    } else {
        after
    };
    let opcode = after
        .split(['(', ' '])
        .next()
        .unwrap_or("")
        .to_string();
    if opcode.is_empty() {
        return Ok(None);
    }

    let dtype = DType::parse(dtype_str).ok();
    let shape = if dims_str.trim().is_empty() {
        Vec::new()
    } else {
        dims_str
            .split(',')
            .map(|d| d.trim().parse::<usize>().unwrap_or(0))
            .collect()
    };

    Ok(Some(Instruction { name, opcode, dtype, shape, in_entry }))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_step, entry_computation_layout={(f32[8,3,32,32])->(f32[], pred[])}

fused_computation.1 {
  param_0 = f32[64]{0} parameter(0)
  ROOT add.1 = f32[64]{0} add(param_0, param_0)
}

ENTRY main.42 {
  Arg_0.1 = f32[8,3,32,32]{3,2,1,0} parameter(0)
  Arg_1.2 = s32[8]{0} parameter(1)
  constant.3 = f32[] constant(1024)
  dot.7 = f32[8,64]{1,0} dot(reshape.5, p.6), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  convert.9 = f16[8,64]{1,0} convert(dot.7)
  ROOT tuple.10 = (f32[], pred[]) tuple(constant.3, pred.8)
}
"#;

    #[test]
    fn parses_instructions() {
        let m = HloModule::parse(SAMPLE).unwrap();
        let ops = m.opcode_histogram();
        assert_eq!(ops["parameter"], 3); // 2 entry + 1 fusion
        assert_eq!(ops["dot"], 1);
        assert_eq!(ops["convert"], 1);
    }

    #[test]
    fn entry_vs_subcomputation() {
        let m = HloModule::parse(SAMPLE).unwrap();
        assert_eq!(m.entry_instructions().count(), 6);
        // parameter_bytes counts entry params only
        let want = (8 * 3 * 32 * 32 * 4 + 8 * 4) as u64;
        assert_eq!(m.parameter_bytes(), want);
    }

    #[test]
    fn shapes_and_bytes() {
        let m = HloModule::parse(SAMPLE).unwrap();
        let dot = m
            .instructions
            .iter()
            .find(|i| i.opcode == "dot")
            .unwrap();
        assert_eq!(dot.shape, vec![8, 64]);
        assert_eq!(dot.bytes(), 8 * 64 * 4);
        let cvt = m
            .instructions
            .iter()
            .find(|i| i.opcode == "convert")
            .unwrap();
        assert_eq!(cvt.bytes(), 8 * 64 * 2); // f16
    }

    #[test]
    fn workspace_by_dtype() {
        let m = HloModule::parse(SAMPLE).unwrap();
        let ws = m.workspace_bytes_by_dtype();
        assert_eq!(ws["f16"], (8 * 64 * 2) as u64);
        assert!(ws["f32"] >= (8 * 64 * 4) as u64);
    }

    #[test]
    fn scalar_shapes() {
        let m = HloModule::parse(SAMPLE).unwrap();
        let c = m
            .instructions
            .iter()
            .find(|i| i.opcode == "constant")
            .unwrap();
        assert_eq!(c.elems(), 1);
        assert_eq!(c.bytes(), 4);
    }

    #[test]
    fn rejects_non_hlo() {
        assert!(HloModule::parse("not hlo at all").is_err());
    }

    #[test]
    fn parses_real_artifact_if_present() {
        // integration smoke (skipped when artifacts are not built)
        let path = "artifacts/init_vit_tiny_fp32.hlo.txt";
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = HloModule::parse(&text).unwrap();
            assert!(m.instructions.len() > 50);
            assert!(m.opcode_histogram().contains_key("parameter"));
        }
    }
}
