//! Synthetic datasets + batch pipeline.
//!
//! The paper trains on CIFAR-100 (desktop) and ImageNet-1k (cluster);
//! neither ships with this environment, so we substitute deterministic
//! *class-conditional Gaussian* image datasets with matching shapes
//! (DESIGN.md substitution table): each class `c` has a fixed random
//! prototype image; a sample is `prototype[c] + noise`.  The task is
//! genuinely learnable (the E2E example drives the loss down and
//! accuracy up), step time and memory are independent of pixel
//! content, and generation is fast enough to never bottleneck the
//! trainer (a prefetch thread hides it regardless).

use std::sync::mpsc;
use std::thread;

use crate::config::ModelPreset;
use crate::hostkernel::BufferPool;
use crate::util::rng::Rng;

/// One host-side batch, layout matching the artifact inputs:
/// images `f32[batch, C, H, W]` (flattened row-major), labels `i32[batch]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub image_elems: usize,
}

impl Batch {
    /// Return the backing buffers to the shared [`BufferPool`] once
    /// the batch is packed into literals — the step loops cycle the
    /// same buffers instead of allocating per step.
    pub fn recycle(self) {
        let pool = BufferPool::global();
        pool.put_f32(self.images);
        pool.put_i32(self.labels);
    }
}

/// Deterministic class-conditional Gaussian image dataset.
#[derive(Clone)]
pub struct SyntheticDataset {
    prototypes: Vec<f32>, // [classes, image_elems]
    image_elems: usize,
    num_classes: usize,
    noise_std: f32,
    signal_std: f32,
}

impl SyntheticDataset {
    /// `seed` fixes the prototypes; samples additionally depend on the
    /// per-batch stream.
    pub fn new(preset: &ModelPreset, seed: u64) -> SyntheticDataset {
        Self::with_noise(preset, seed, 0.5)
    }

    pub fn with_noise(
        preset: &ModelPreset,
        seed: u64,
        noise_std: f32,
    ) -> SyntheticDataset {
        let image_elems =
            preset.channels * preset.image_size * preset.image_size;
        let mut rng = Rng::new(seed ^ 0xDA7A_5E0D);
        let signal_std = 1.0;
        let prototypes: Vec<f32> = (0..preset.num_classes * image_elems)
            .map(|_| rng.normal_f32(0.0, signal_std))
            .collect();
        SyntheticDataset {
            prototypes,
            image_elems,
            num_classes: preset.num_classes,
            noise_std,
            signal_std,
        }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn image_elems(&self) -> usize {
        self.image_elems
    }

    /// Expected Bayes-optimal achievability indicator (for tests): the
    /// signal-to-noise ratio per pixel.
    pub fn snr(&self) -> f32 {
        self.signal_std / self.noise_std
    }

    /// Generate batch `index` of size `batch` deterministically:
    /// same (seed, index, batch) ⇒ bit-identical batch, regardless of
    /// which shard or thread asks.
    pub fn batch(&self, index: u64, batch: usize, stream_seed: u64) -> Batch {
        let mut rng = Rng::new(
            stream_seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(index),
        );
        let pool = BufferPool::global();
        let mut images = pool.take_f32(batch * self.image_elems);
        let mut labels = pool.take_i32(batch);
        for _ in 0..batch {
            let label = rng.below(self.num_classes as u64) as usize;
            labels.push(label as i32);
            let proto = &self.prototypes
                [label * self.image_elems..(label + 1) * self.image_elems];
            for &p in proto {
                images.push(p + rng.normal_f32(0.0, self.noise_std));
            }
        }
        Batch { images, labels, batch, image_elems: self.image_elems }
    }

    /// Shard a global batch: shard `s` of `n` gets rows
    /// `[s·b/n, (s+1)·b/n)` of the same deterministic global batch —
    /// the data-parallel equivalence tests rely on this.
    pub fn shard_batch(
        &self,
        index: u64,
        global_batch: usize,
        stream_seed: u64,
        shard: usize,
        num_shards: usize,
    ) -> Batch {
        assert!(global_batch % num_shards == 0,
                "global batch {global_batch} not divisible by {num_shards}");
        let global = self.batch(index, global_batch, stream_seed);
        let per = global_batch / num_shards;
        let img_lo = shard * per * self.image_elems;
        let img_hi = (shard + 1) * per * self.image_elems;
        let pool = BufferPool::global();
        let mut images = pool.take_f32(per * self.image_elems);
        images.extend_from_slice(&global.images[img_lo..img_hi]);
        let mut labels = pool.take_i32(per);
        labels.extend_from_slice(
            &global.labels[shard * per..(shard + 1) * per],
        );
        global.recycle();
        Batch { images, labels, batch: per, image_elems: self.image_elems }
    }
}

/// Prefetching loader: a background thread keeps `depth` batches
/// ready so generation overlaps the train step (the paper excludes
/// data-loading time from its measurements; we overlap it instead).
pub struct Prefetcher {
    rx: Option<mpsc::Receiver<Batch>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Prefetcher {
    pub fn new(
        dataset: SyntheticDataset,
        batch: usize,
        stream_seed: u64,
        depth: usize,
    ) -> Prefetcher {
        Self::with_start(dataset, batch, stream_seed, depth, 0)
    }

    /// Start streaming from batch index `start` (checkpoint resume).
    pub fn with_start(
        dataset: SyntheticDataset,
        batch: usize,
        stream_seed: u64,
        depth: usize,
        start: u64,
    ) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = thread::spawn(move || {
            let mut index = start;
            loop {
                let b = dataset.batch(index, batch, stream_seed);
                if tx.send(b).is_err() {
                    return; // consumer dropped
                }
                index += 1;
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    pub fn next(&self) -> Batch {
        self.rx
            .as_ref()
            .expect("prefetcher closed")
            .recv()
            .expect("prefetch thread died")
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Dropping the receiver makes the producer's next send fail,
        // so it exits; then join.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VIT_TINY;
    use crate::util::proptest::forall;

    #[test]
    fn deterministic_batches() {
        let ds = SyntheticDataset::new(&VIT_TINY, 1);
        let a = ds.batch(3, 8, 42);
        let b = ds.batch(3, 8, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticDataset::new(&VIT_TINY, 1);
        assert_ne!(ds.batch(0, 8, 42).images, ds.batch(1, 8, 42).images);
    }

    #[test]
    fn shapes() {
        let ds = SyntheticDataset::new(&VIT_TINY, 1);
        let b = ds.batch(0, 4, 0);
        assert_eq!(b.images.len(), 4 * 3 * 32 * 32);
        assert_eq!(b.labels.len(), 4);
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn class_signal_present() {
        // Same-class samples correlate; different-class do not.
        let ds = SyntheticDataset::with_noise(&VIT_TINY, 1, 0.1);
        let b = ds.batch(0, 64, 7);
        let dot = |i: usize, j: usize| -> f32 {
            let (a, b_) = (
                &b.images[i * ds.image_elems()..(i + 1) * ds.image_elems()],
                &b.images[j * ds.image_elems()..(j + 1) * ds.image_elems()],
            );
            let num: f32 = a.iter().zip(b_).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b_.iter().map(|x| x * x).sum::<f32>().sqrt();
            num / (na * nb)
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..16 {
            for j in (i + 1)..16 {
                if b.labels[i] == b.labels[j] {
                    same.push(dot(i, j));
                } else {
                    diff.push(dot(i, j));
                }
            }
        }
        if !same.is_empty() {
            let mean_same: f32 = same.iter().sum::<f32>() / same.len() as f32;
            let mean_diff: f32 = diff.iter().sum::<f32>() / diff.len() as f32;
            assert!(mean_same > mean_diff + 0.5,
                    "same={mean_same} diff={mean_diff}");
        }
    }

    #[test]
    fn sharding_partitions_global_batch() {
        let ds = SyntheticDataset::new(&VIT_TINY, 1);
        let global = ds.batch(5, 8, 9);
        let mut rebuilt_imgs = Vec::new();
        let mut rebuilt_labels = Vec::new();
        for s in 0..4 {
            let sh = ds.shard_batch(5, 8, 9, s, 4);
            assert_eq!(sh.batch, 2);
            rebuilt_imgs.extend(sh.images);
            rebuilt_labels.extend(sh.labels);
        }
        assert_eq!(rebuilt_imgs, global.images);
        assert_eq!(rebuilt_labels, global.labels);
    }

    #[test]
    fn property_shard_determinism_across_orders() {
        let ds = SyntheticDataset::new(&VIT_TINY, 3);
        forall(
            30,
            |r| (r.below(100), r.below(4) as usize),
            |&(index, shard)| {
                let a = ds.shard_batch(index, 8, 1, shard, 4);
                let b = ds.shard_batch(index, 8, 1, shard, 4);
                if a.images == b.images && a.labels == b.labels {
                    Ok(())
                } else {
                    Err("shard not deterministic".into())
                }
            },
        );
    }

    #[test]
    fn prefetcher_streams_in_order() {
        let ds = SyntheticDataset::new(&VIT_TINY, 1);
        let expect0 = ds.batch(0, 4, 11);
        let expect1 = ds.batch(1, 4, 11);
        let pf = Prefetcher::new(ds, 4, 11, 2);
        assert_eq!(pf.next().images, expect0.images);
        assert_eq!(pf.next().images, expect1.images);
    }
}
