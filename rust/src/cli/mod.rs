//! Argument parsing (clap is unavailable offline).
//!
//! Convention: `mpx <subcommand> [--flag value]... [--switch]...`.
//! Flags are declared by the caller via the typed getters; unknown
//! flags are rejected by [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut it = argv.into_iter().peekable();

        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument {arg:?}");
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it
                .peek()
                .map(|n| !n.starts_with("--"))
                .unwrap_or(false)
            {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args {
            subcommand,
            flags,
            switches,
            consumed: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.get_str(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name} wants an integer, got {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        Ok(self.get_u64(name)?.map(|v| v as usize))
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get_str(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name} wants a number, got {v:?}")),
        }
    }

    /// Comma-separated integer list (`--batches 8,16,32`).
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>> {
        match self.get_str(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--{name}: bad integer {p:?}")
                    })
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.switches.iter().any(|s| s == name)
    }

    /// Call after all getters: rejects flags nobody consumed.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.flags.keys() {
            if !consumed.iter().any(|c| c == k) {
                bail!("unknown flag --{k}");
            }
        }
        for s in &self.switches {
            if !consumed.iter().any(|c| c == s) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --model vit_tiny --batch 8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_str("model"), Some("vit_tiny"));
        assert_eq!(a.get_usize("batch").unwrap(), Some(8));
        assert!(a.has_switch("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --batches=8,16,32");
        assert_eq!(
            a.get_usize_list("batches").unwrap(),
            Some(vec![8, 16, 32])
        );
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("train --tpyo 3");
        let _ = a.get_str("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse("train --batch pony");
        assert!(a.get_usize("batch").is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_switch("help"));
    }

    #[test]
    fn float_flags() {
        let a = parse("sim --prob 0.05");
        assert_eq!(a.get_f64("prob").unwrap(), Some(0.05));
    }
}
