//! Memory model — the Fig. 2 substrate.
//!
//! The paper measures GPU VRAM for full vs mixed precision as the
//! batch count grows (desktop ViT / CIFAR-100) and reports a 1.8×
//! reduction.  XLA-CPU has no VRAM to measure, so we *model* it with
//! two independent estimators and cross-check them:
//!
//! 1. [`ActivationModel`] — analytic per-layer accounting of what a
//!    training step must keep live: parameters, gradients, optimizer
//!    moments, master weights, and the forward activations stored for
//!    the backward pass.  The activation term is the one that scales
//!    with batch and whose dtype the paper's method halves.
//! 2. `hlo_census` (via [`crate::hlo`]) — parse the actual artifact
//!    and sum the buffers XLA materializes, by dtype.
//!
//! Both reproduce the figure's *shape*: memory linear in batch, mixed
//! slope ≈ half, constant offset from the fp32 master state.

pub mod roofline;

use crate::config::{ModelPreset, Precision};

/// Bytes that do NOT scale with batch (state) and that DO (per-sample
/// activations), for one precision mode.
#[derive(Debug, Clone, Copy)]
pub struct MemoryEstimate {
    pub params_bytes: u64,
    pub grads_bytes: u64,
    pub optimizer_bytes: u64,
    /// Half-precision copy of the weights (mixed mode only).
    pub half_params_bytes: u64,
    pub activation_bytes_per_sample: u64,
    pub batch: usize,
}

impl MemoryEstimate {
    pub fn state_bytes(&self) -> u64 {
        self.params_bytes
            + self.grads_bytes
            + self.optimizer_bytes
            + self.half_params_bytes
    }

    pub fn activation_bytes(&self) -> u64 {
        self.activation_bytes_per_sample * self.batch as u64
    }

    pub fn total_bytes(&self) -> u64 {
        self.state_bytes() + self.activation_bytes()
    }
}

/// Analytic ViT training-memory model.
pub struct ActivationModel {
    pub preset: ModelPreset,
}

impl ActivationModel {
    pub fn new(preset: ModelPreset) -> ActivationModel {
        ActivationModel { preset }
    }

    /// Trainable parameter count (mirrors `model.param_count`; exact
    /// against the manifest — asserted in `rust/tests/memmodel.rs`).
    pub fn param_count(&self) -> u64 {
        let p = &self.preset;
        let d = p.feature_dim as u64;
        let m = p.mlp_dim as u64;
        let patch_dim = (p.channels * p.patch_size * p.patch_size) as u64;
        let seq = p.seq_len() as u64;

        let patch_embed = patch_dim * d + d;
        let pos_embed = seq * d;
        let cls = d;
        // per attention block: 4 dense (d·d+d) + LN (2d)
        let attn = 4 * (d * d + d) + 2 * d;
        // per MLP block: d·m+m, m·d+d, LN 2d
        let mlp = d * m + m + m * d + d + 2 * d;
        let final_ln = 2 * d;
        let head = d * (p.num_classes as u64) + p.num_classes as u64;

        patch_embed
            + pos_embed
            + cls
            + (attn + mlp) * p.depth as u64
            + final_ln
            + head
    }

    /// Activations stored for backward, per sample, in *elements*.
    ///
    /// Standard reverse-mode accounting for the pre-LN ViT (per
    /// block, per token): LN output D, Q/K/V 3D, attention probs
    /// heads·seq (the (seq×seq) score rows), context D, proj output D,
    /// then MLP: LN out D, hidden M, GELU out M, out D.  Plus the
    /// embedding output once.  Constant factors deliberately follow
    /// what jax.grad's default (no-remat) policy materializes.
    pub fn activation_elems_per_sample(&self) -> u64 {
        let p = &self.preset;
        let d = p.feature_dim as u64;
        let m = p.mlp_dim as u64;
        let seq = p.seq_len() as u64;
        let heads = p.num_heads as u64;

        let attn_block = seq * (6 * d) + heads * seq * seq;
        let mlp_block = seq * (2 * d + 2 * m);
        let embed = seq * d;
        embed + (attn_block + mlp_block) * p.depth as u64
    }

    /// Full estimate for one (precision, batch) point.
    ///
    /// fp32: params + grads + 2 Adam moments, activations f32.
    /// mixed: adds a transient half copy of the weights, activations
    /// in f16 (the batch-scaling term halves — the paper's effect).
    pub fn estimate(
        &self,
        precision: Precision,
        batch: usize,
    ) -> MemoryEstimate {
        let n = self.param_count();
        let act_elems = self.activation_elems_per_sample();
        let act_bytes_per_elem = match precision {
            Precision::Fp32 => 4,
            Precision::MixedF16 | Precision::MixedBf16 => 2,
        };
        MemoryEstimate {
            params_bytes: 4 * n,
            grads_bytes: 4 * n,
            optimizer_bytes: 8 * n, // Adam mu + nu, f32
            half_params_bytes: match precision {
                Precision::Fp32 => 0,
                _ => 2 * n,
            },
            activation_bytes_per_sample: act_elems * act_bytes_per_elem,
            batch,
        }
    }

    /// The headline ratio at a batch point: fp32 total / mixed total.
    pub fn reduction_ratio(&self, batch: usize) -> f64 {
        let full = self.estimate(Precision::Fp32, batch).total_bytes();
        let mixed = self.estimate(Precision::MixedF16, batch).total_bytes();
        full as f64 / mixed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{VIT_BASE, VIT_DESKTOP, VIT_TINY};

    #[test]
    fn param_count_vit_tiny_matches_python() {
        // python: model.param_count(vit_tiny) == 81226 (measured in
        // the smoke run; kept as a cross-language regression).
        assert_eq!(ActivationModel::new(VIT_TINY).param_count(), 81226);
    }

    #[test]
    fn param_counts_plausible() {
        let desk = ActivationModel::new(VIT_DESKTOP).param_count();
        assert!((3_000_000..6_000_000).contains(&desk), "{desk}");
        let base = ActivationModel::new(VIT_BASE).param_count();
        assert!((85_000_000..90_000_000).contains(&base), "{base}");
    }

    #[test]
    fn memory_linear_in_batch() {
        let m = ActivationModel::new(VIT_DESKTOP);
        let e8 = m.estimate(Precision::MixedF16, 8).total_bytes();
        let e16 = m.estimate(Precision::MixedF16, 16).total_bytes();
        let e32 = m.estimate(Precision::MixedF16, 32).total_bytes();
        // doubling the batch increment doubles the memory increment
        assert_eq!(e32 - e16, 2 * (e16 - e8));
    }

    #[test]
    fn mixed_halves_activation_slope() {
        let m = ActivationModel::new(VIT_DESKTOP);
        let f = m.estimate(Precision::Fp32, 1).activation_bytes_per_sample;
        let h = m
            .estimate(Precision::MixedF16, 1)
            .activation_bytes_per_sample;
        assert_eq!(f, 2 * h);
    }

    #[test]
    fn reduction_ratio_approaches_2x_at_large_batch() {
        // Paper Fig. 2: 1.8× at the largest measured batch — state
        // bytes keep the ratio below the asymptotic 2×.
        let m = ActivationModel::new(VIT_DESKTOP);
        let r_small = m.reduction_ratio(8);
        let r_big = m.reduction_ratio(256);
        assert!(r_big > r_small);
        assert!(r_big > 1.6 && r_big < 2.0, "r_big={r_big}");
    }

    #[test]
    fn mixed_state_is_larger_constant() {
        // mixed keeps fp32 masters AND a half copy ⇒ bigger constant
        let m = ActivationModel::new(VIT_DESKTOP);
        assert!(
            m.estimate(Precision::MixedF16, 1).state_bytes()
                > m.estimate(Precision::Fp32, 1).state_bytes()
        );
    }
}
