//! Roofline projection — the Fig. 3 explanatory model.
//!
//! The paper's measured speedups (desktop 1.7×, cluster ≤1.57×) come
//! from two mechanisms it names explicitly in §5: halved memory
//! traffic (both machines) and doubled half-precision compute (H100
//! only).  We *measure* CPU step times honestly in the benches; this
//! module projects the same workloads onto the paper's machines so
//! the bench output can display measured-vs-paper-vs-model side by
//! side: `t = max(flops / peak_flops, bytes / bandwidth)`.

use crate::config::{MachineProfile, ModelPreset, Precision};
use crate::memmodel::ActivationModel;

/// Work performed by one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepWork {
    pub flops: f64,
    pub bytes: f64,
}

/// HBM crossings per stored-activation element per training step.
///
/// A stored activation is not touched just twice (fwd write + bwd
/// read): in an unfused XLA schedule every fusion boundary re-reads
/// and re-writes it — fwd producer write, fwd consumer read, bwd
/// cotangent write/read, elementwise epilogues.  7 crossings/element
/// reproduces the paper's desktop observation (memory-bound at fp32,
/// mixed 1.7× faster with no half-compute advantage); the value and
/// its calibration are recorded in EXPERIMENTS.md §Fig3.
pub const ACTIVATION_TRAFFIC_FACTOR: f64 = 7.0;

/// Estimate one train step's work for a ViT at (precision, batch).
///
/// FLOPs: matmul-dominated — forward ≈ 2·N·T (N = matmul params,
/// T = tokens), backward ≈ 2× forward ⇒ 6·N·T total, plus the
/// attention score/context matmuls 2·(2·s²·d)·heads·depth per sample,
/// tripled for backward.
///
/// Bytes: every stored activation is written once (fwd) and read once
/// (bwd); parameters+grads+moments are read/written once per step;
/// the working precision sets the activation element size.
pub fn step_work(
    preset: &ModelPreset,
    precision: Precision,
    batch: usize,
) -> StepWork {
    let model = ActivationModel::new(*preset);
    let n = model.param_count() as f64;
    let seq = preset.seq_len() as f64;
    let d = preset.feature_dim as f64;
    let heads = preset.num_heads as f64;
    let depth = preset.depth as f64;
    let b = batch as f64;

    let dense_flops = 6.0 * n * seq * b;
    let head_dim = d / heads;
    let attn_flops =
        3.0 * 2.0 * 2.0 * seq * seq * head_dim * heads * depth * b;
    let flops = dense_flops + attn_flops;

    let act_elem_bytes = match precision {
        Precision::Fp32 => 4.0,
        _ => 2.0,
    };
    let act_bytes = model.activation_elems_per_sample() as f64
        * b
        * act_elem_bytes
        * ACTIVATION_TRAFFIC_FACTOR;
    let state_bytes = (4.0 + 4.0 + 8.0) * n // params+grads+moments r/w
        + match precision {
            Precision::Fp32 => 0.0,
            _ => 2.0 * n, // half copy of weights
        };
    StepWork { flops, bytes: act_bytes + state_bytes }
}

/// Projected step time on a machine profile (per device).
pub fn projected_step_time(
    work: &StepWork,
    machine: &MachineProfile,
    precision: Precision,
) -> f64 {
    let peak = machine.tflops_f32
        * 1e12
        * match precision {
            Precision::Fp32 => 1.0,
            _ => machine.half_speedup,
        };
    let t_compute = work.flops / peak;
    let t_memory = work.bytes / (machine.bandwidth_gbs * 1e9);
    t_compute.max(t_memory)
}

/// Projected fp32/mixed speedup for a (model, machine, batch) point —
/// the number Fig. 3's caption reports.
pub fn projected_speedup(
    preset: &ModelPreset,
    machine: &MachineProfile,
    batch: usize,
) -> f64 {
    let full = projected_step_time(
        &step_work(preset, Precision::Fp32, batch),
        machine,
        Precision::Fp32,
    );
    let mixed = projected_step_time(
        &step_work(preset, Precision::MixedF16, batch),
        machine,
        Precision::MixedF16,
    );
    full / mixed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MACHINE_CLUSTER, MACHINE_DESKTOP, VIT_BASE, VIT_DESKTOP};

    #[test]
    fn work_scales_with_batch() {
        let w1 = step_work(&VIT_DESKTOP, Precision::Fp32, 8);
        let w2 = step_work(&VIT_DESKTOP, Precision::Fp32, 16);
        assert!(w2.flops > 1.9 * w1.flops && w2.flops < 2.1 * w1.flops);
    }

    #[test]
    fn mixed_moves_fewer_bytes() {
        let f = step_work(&VIT_DESKTOP, Precision::Fp32, 64);
        let h = step_work(&VIT_DESKTOP, Precision::MixedF16, 64);
        assert!(h.bytes < 0.62 * f.bytes, "{} vs {}", h.bytes, f.bytes);
        assert_eq!(h.flops, f.flops); // same math
    }

    #[test]
    fn cluster_roofline_upper_bounds_paper() {
        // With a 2× half-compute ceiling the pure roofline saturates
        // at 2.0×; the paper measured 1.57× (Amdahl: non-matmul
        // kernels).  The projection must stay a (finite) upper bound.
        let s = projected_speedup(&VIT_BASE, &MACHINE_CLUSTER, 64);
        assert!(s >= 1.57 && s <= 2.0, "cluster projection {s}");
    }

    #[test]
    fn desktop_speedup_in_paper_band() {
        // Paper: 1.7× on the RTX4070, driven purely by memory traffic
        // (half compute speedup = 1×).  The projection should land in
        // a credible band around that.
        let s = projected_speedup(&VIT_DESKTOP, &MACHINE_DESKTOP, 128);
        assert!(s > 1.3 && s <= 2.0, "desktop speedup {s}");
    }

    #[test]
    fn cluster_speedup_in_paper_band() {
        // Paper: up to 1.57× on H100s (compute-rich ⇒ memory-bound
        // fraction smaller than the naive 2×).
        let s = projected_speedup(&VIT_BASE, &MACHINE_CLUSTER, 64);
        assert!(s > 1.2 && s <= 2.0, "cluster speedup {s}");
    }

    #[test]
    fn memory_bound_on_desktop() {
        // The paper attributes the desktop speedup to loads, which
        // requires the workload to be memory-bound there.
        let w = step_work(&VIT_DESKTOP, Precision::Fp32, 64);
        let t_mem = w.bytes / (MACHINE_DESKTOP.bandwidth_gbs * 1e9);
        let t_cmp = w.flops / (MACHINE_DESKTOP.tflops_f32 * 1e12);
        assert!(t_mem > t_cmp, "t_mem={t_mem} t_cmp={t_cmp}");
    }
}
