//! MPX — Mixed Precision Training for JAX: the Rust coordinator.
//!
//! This crate is Layer 3 of the three-layer reproduction of
//! *Gräfe & Trimpe, "MPX: Mixed Precision Training for JAX", 2025*
//! (see `DESIGN.md`): a self-contained training framework that loads
//! the AOT-compiled train steps (HLO text emitted once by
//! `python/compile/aot.py`) and runs them through the PJRT CPU client.
//! Python is never on the training path.
//!
//! Module map (one subsystem per module — see `DESIGN.md §4`):
//!
//! * [`util`] — offline substrates: JSON parser, PRNG, bench harness,
//!   mini property-testing (no external crates are available offline).
//! * [`numerics`] — software IEEE binary16 / bfloat16, the host-side
//!   mirror of every cast the compiled graphs perform.
//! * [`hostkernel`] — vectorized host-compute layer: branchless batch
//!   f32↔f16/bf16 casts, the fused unscale+stats gradient scan,
//!   chunk-parallel elementwise add/scale for the all-reduce, and the
//!   steady-state [`hostkernel::BufferPool`].  Bitwise-deterministic
//!   across runs and thread counts (see its module docs).
//! * [`scaling`] — the dynamic loss-scaling controller (paper §3.3)
//!   for the data-parallel mode; parity-tested against the Python
//!   implementation.
//! * [`pytree`] — leaf inventories: the manifest contract between
//!   `aot.py` and the runtime.
//! * [`runtime`] — the backend HAL: `Backend`/`Executable` traits,
//!   the artifact registry, backend-agnostic [`runtime::Value`]
//!   leaves, the always-available pure-Rust [`runtime::host`]
//!   interpreter, and the PJRT backend behind the `xla` feature.
//! * [`config`] — TOML-subset config system + machine/model presets.
//! * [`data`] — deterministic synthetic CIFAR-100/ImageNet-like
//!   datasets with a prefetching loader.
//! * [`optim`] — Rust AdamW/SGD over flat f32 tensors (master weights
//!   for the data-parallel mode).
//! * [`collective`] — deterministic tree all-reduce across shards.
//! * [`trainer`] — the fused single-device loop and the simulated
//!   multi-device data-parallel loop; checkpointing. Runs on either
//!   runtime backend.
//! * [`serve`] — continuous-batching multi-model serving engine: one
//!   bounded request queue per (model, precision) lane, a
//!   weighted-deficit scheduler that refills the shared worker pool
//!   as slots free, per-request streamed completions, autoscaling,
//!   a latency-aware bucket planner (`serve::planner`: which batch
//!   sizes to AOT-compile and which flush timeouts to run, per lane,
//!   from an offered-load profile and per-lane SLOs), a
//!   virtual-clock simulation harness, and an HTTP/1.1 network
//!   transport (`serve::transport`: streamed chunked responses,
//!   Prometheus `/metrics`, graceful drain) behind `mpx serve
//!   --listen`; all timing flows through the `serve::clock::Clock`
//!   trait so policy is deterministically testable.
//! * [`trace`] — always-on span tracing: bounded sharded ring
//!   buffers behind a [`trace::Tracer`] threaded through the serve
//!   scheduler and the trainers, Chrome trace-event JSON export
//!   (Perfetto-loadable, `GET /debug/trace`), and the
//!   [`trace::ServiceSample`] calibration records the bucket planner
//!   consumes.  Virtual-clock runs produce bit-deterministic traces.
//! * [`hlo`] — HLO-text parsers: the per-line census and the deep
//!   executable-graph frontend ([`hlo::graph`]) the host backend runs.
//! * [`memmodel`] — Fig. 2 memory model + Fig. 3 roofline projection.
//! * [`metrics`] — step timers, loss history, latency histograms
//!   (rank-interpolated quantiles, optional bounded reservoir),
//!   CSV/JSONL writers.
//! * [`cli`] — argument parsing for the `mpx` binary and examples.

pub mod cli;
pub mod collective;
pub mod config;
pub mod data;
pub mod hlo;
pub mod hostkernel;
pub mod memmodel;
pub mod metrics;
pub mod numerics;
pub mod optim;
pub mod pytree;
pub mod runtime;
pub mod scaling;
pub mod serve;
pub mod trace;
pub mod trainer;
pub mod util;

/// Crate-wide result type (anyhow, matching the `xla` crate's errors).
pub type Result<T> = anyhow::Result<T>;
