//! Config system: a from-scratch TOML-subset parser plus the typed
//! training configuration and machine/model presets the launcher and
//! the benches consume.
//!
//! Supported TOML subset (all the framework needs): `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer /
//! float / bool / homogeneous-array values, `#` comments.

pub mod toml;

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::runtime::BackendKind;
use crate::scaling::{PolicyKind, ScalingConfig, ScalingSpec};
use crate::serve::batcher::SchedPolicy;
use crate::trace::TraceConfig;
use toml::TomlDoc;

/// Numeric execution mode (paper §5 compares fp32 against mixed f16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    MixedF16,
    MixedBf16,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "fp32" | "f32" | "full" => Precision::Fp32,
            "mixed_f16" | "f16" | "mixed" => Precision::MixedF16,
            "mixed_bf16" | "bf16" => Precision::MixedBf16,
            _ => bail!("unknown precision {s:?}"),
        })
    }

    /// The artifact-name component (`aot.py` naming convention).
    pub fn tag(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::MixedF16 => "mixed_f16",
            Precision::MixedBf16 => "mixed_bf16",
        }
    }

    /// Does this mode cast gradients through binary16 (and therefore
    /// need loss scaling at all)?
    pub fn is_f16(self) -> bool {
        self == Precision::MixedF16
    }

    /// The deprecated implicit convention (pre-`[train.scaling]`):
    /// mixed f16 ⇒ dynamic defaults, everything else ⇒ pinned at 1.
    /// New code goes through [`TrainConfig::scaling_spec`], which
    /// prefers the explicit table and falls back to exactly this.
    pub fn scaling_config(self) -> ScalingConfig {
        match self {
            Precision::MixedF16 => ScalingConfig::default(),
            _ => ScalingConfig::pinned(),
        }
    }
}

/// Model presets mirrored from `python/compile/model.py::PRESETS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelPreset {
    pub name: &'static str,
    pub image_size: usize,
    pub patch_size: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub feature_dim: usize,
    pub mlp_dim: usize,
    pub num_heads: usize,
    pub depth: usize,
}

impl ModelPreset {
    pub fn seq_len(&self) -> usize {
        (self.image_size / self.patch_size).pow(2) + 1
    }
}

pub const VIT_TINY: ModelPreset = ModelPreset {
    name: "vit_tiny",
    image_size: 32,
    patch_size: 8,
    channels: 3,
    num_classes: 10,
    feature_dim: 64,
    mlp_dim: 128,
    num_heads: 4,
    depth: 2,
};

/// Paper §5 desktop model: "size 256, residual blocks containing one
/// hidden layer of 800 neurons", CIFAR-100.
pub const VIT_DESKTOP: ModelPreset = ModelPreset {
    name: "vit_desktop",
    image_size: 32,
    patch_size: 4,
    channels: 3,
    num_classes: 100,
    feature_dim: 256,
    mlp_dim: 800,
    num_heads: 8,
    depth: 6,
};

/// Paper §5 cluster model: ViT-Base dimensions, ImageNet-1k.
pub const VIT_BASE: ModelPreset = ModelPreset {
    name: "vit_base",
    image_size: 224,
    patch_size: 16,
    channels: 3,
    num_classes: 1000,
    feature_dim: 768,
    mlp_dim: 3072,
    num_heads: 12,
    depth: 12,
};

pub fn model_preset(name: &str) -> Result<ModelPreset> {
    Ok(match name {
        "vit_tiny" => VIT_TINY,
        "vit_desktop" => VIT_DESKTOP,
        "vit_base" => VIT_BASE,
        _ => bail!("unknown model preset {name:?}"),
    })
}

/// Machine profiles for the roofline projection (paper §5 hardware).
#[derive(Debug, Clone, Copy)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Peak fp32 TFLOP/s.
    pub tflops_f32: f64,
    /// fp16 compute speedup over fp32 (paper: 1× RTX4070, 2× H100).
    pub half_speedup: f64,
    /// Memory bandwidth GB/s.
    pub bandwidth_gbs: f64,
    /// Number of devices (cluster = 4×H100).
    pub devices: usize,
}

pub const MACHINE_DESKTOP: MachineProfile = MachineProfile {
    name: "desktop_rtx4070",
    tflops_f32: 29.1,
    half_speedup: 1.0, // paper: "no computing speedup for half precision"
    bandwidth_gbs: 504.0,
    devices: 1,
};

pub const MACHINE_CLUSTER: MachineProfile = MachineProfile {
    name: "cluster_h100",
    tflops_f32: 67.0,
    half_speedup: 2.0, // paper: "double the speed for half precision"
    bandwidth_gbs: 3350.0,
    devices: 4,
};

pub fn machine_profile(name: &str) -> Result<MachineProfile> {
    Ok(match name {
        "desktop" | "desktop_rtx4070" => MACHINE_DESKTOP,
        "cluster" | "cluster_h100" => MACHINE_CLUSTER,
        _ => bail!("unknown machine profile {name:?}"),
    })
}

/// Full training-run configuration (CLI flags and/or TOML file).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub precision: Precision,
    pub batch: usize,
    pub steps: u64,
    pub seed: u64,
    pub shards: usize,
    pub artifacts_dir: String,
    /// Runtime backend compiling the artifacts (`backend = "xla" |
    /// "host"`); defaults to xla when compiled in, host otherwise.
    pub backend: BackendKind,
    pub log_every: u64,
    pub checkpoint_every: u64,
    pub checkpoint_dir: Option<String>,
    pub dataset: String,
    /// Learning-rate metadata (must match the AOT'd optimizer).
    pub lr: f64,
    pub weight_decay: f64,
    /// Explicit `[train.scaling]` selection; `None` falls back to the
    /// deprecated precision-derived convention
    /// ([`Precision::scaling_config`]).
    pub scaling: Option<ScalingSpec>,
    /// Span tracing (`[trace]` table, shared with the serve path).
    pub trace: TraceConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "vit_tiny".into(),
            precision: Precision::MixedF16,
            batch: 8,
            steps: 100,
            seed: 0,
            shards: 1,
            artifacts_dir: "artifacts".into(),
            backend: BackendKind::default_kind(),
            log_every: 10,
            checkpoint_every: 0,
            checkpoint_dir: None,
            dataset: "synthetic".into(),
            lr: 3e-4,
            weight_decay: 1e-4,
            scaling: None,
            trace: TraceConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Artifact name of the fused step for this config.
    pub fn step_artifact(&self) -> String {
        format!(
            "step_fused_{}_{}_b{}",
            self.model,
            self.precision.tag(),
            self.batch
        )
    }

    pub fn grads_artifact(&self) -> String {
        format!(
            "grads_{}_{}_b{}",
            self.model,
            self.precision.tag(),
            self.batch
        )
    }

    pub fn init_artifact(&self) -> String {
        format!("init_{}_{}", self.model, self.precision.tag())
    }

    /// Load from a TOML file (section `[train]` + scalars).
    pub fn from_toml_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        let doc = TomlDoc::parse(&text).context("parse config")?;
        let mut cfg = TrainConfig::default();

        if let Some(s) = doc.get_str("train.model") {
            cfg.model = s.to_string();
        }
        if let Some(s) = doc.get_str("train.precision") {
            cfg.precision = Precision::parse(s)?;
        }
        if let Some(v) = doc.get_int("train.batch") {
            cfg.batch = v as usize;
        }
        if let Some(v) = doc.get_int("train.steps") {
            cfg.steps = v as u64;
        }
        if let Some(v) = doc.get_int("train.seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_int("train.shards") {
            cfg.shards = v as usize;
        }
        if let Some(s) = doc.get_str("train.artifacts_dir") {
            cfg.artifacts_dir = s.to_string();
        }
        if let Some(s) = doc.get_str("train.backend") {
            cfg.backend = BackendKind::parse(s)?;
        }
        if let Some(v) = doc.get_int("train.log_every") {
            cfg.log_every = v as u64;
        }
        if let Some(v) = doc.get_int("train.checkpoint_every") {
            cfg.checkpoint_every = v as u64;
        }
        if let Some(s) = doc.get_str("train.checkpoint_dir") {
            cfg.checkpoint_dir = Some(s.to_string());
        }
        if let Some(s) = doc.get_str("train.dataset") {
            cfg.dataset = s.to_string();
        }
        if let Some(v) = doc.get_float("train.lr") {
            cfg.lr = v;
        }
        if let Some(v) = doc.get_float("train.weight_decay") {
            cfg.weight_decay = v;
        }
        cfg.scaling = parse_scaling_toml(&doc)?;
        apply_trace_toml(&mut cfg.trace, &doc);
        cfg.trace.validate()?;
        model_preset(&cfg.model)?; // validate
        cfg.scaling_spec()?; // validate policy × precision
        Ok(cfg)
    }

    /// Resolve the effective scaling policy: the explicit
    /// `[train.scaling]` table if present (validated against the
    /// precision), else the deprecated precision-derived convention.
    pub fn scaling_spec(&self) -> Result<ScalingSpec> {
        let Some(spec) = &self.scaling else {
            return Ok(ScalingSpec::legacy(self.precision.is_f16()));
        };
        spec.validate()?;
        if spec.kind != PolicyKind::Pinned && !self.precision.is_f16() {
            bail!(
                "scaling: policy \"{}\" drives an f16 loss scale, but \
                 precision \"{}\" never casts gradients through f16 — use \
                 policy = \"pinned\" (or drop the [train.scaling] table for \
                 the deprecated precision-derived default, which pins \
                 fp32/bf16 at scale 1)",
                spec.kind.tag(),
                self.precision.tag(),
            );
        }
        Ok(spec.clone())
    }
}

/// Parse the explicit `[train.scaling]` table (`None` when absent).
///
/// `policy` is mandatory once the table exists; per-policy keys are
/// rejected on policies that cannot honor them, so a config that says
/// `pinned` with a `period` fails loudly instead of silently ignoring
/// the knob.
pub fn parse_scaling_toml(doc: &TomlDoc) -> Result<Option<ScalingSpec>> {
    const KEYS: [&str; 8] = [
        "policy",
        "init_scale",
        "period",
        "factor",
        "min_scale",
        "max_scale",
        "headroom",
        "underflow_target",
    ];
    let present: Vec<&str> = KEYS
        .iter()
        .copied()
        .filter(|k| doc.get(&format!("train.scaling.{k}")).is_some())
        .collect();
    if present.is_empty() {
        return Ok(None);
    }
    let Some(policy) = doc.get_str("train.scaling.policy") else {
        bail!(
            "[train.scaling] requires an explicit policy = \"dynamic\" | \
             \"pinned\" | \"adaptive\" (found keys {present:?}); configs \
             without the table keep the deprecated precision-derived \
             default"
        );
    };
    let kind = PolicyKind::parse(policy)?;
    let rejected: &[&str] = match kind {
        PolicyKind::Pinned => {
            &["period", "factor", "headroom", "underflow_target"]
        }
        PolicyKind::Dynamic => &["headroom", "underflow_target"],
        PolicyKind::Adaptive => &[],
    };
    for k in rejected {
        if present.contains(k) {
            bail!(
                "[train.scaling] key {k:?} makes no sense for policy = \
                 {policy:?}",
            );
        }
    }
    let mut spec = ScalingSpec::preset(kind);
    if let Some(v) = doc.get_float("train.scaling.init_scale") {
        spec.base.init_scale = v as f32;
    }
    if let Some(v) = doc.get_int("train.scaling.period") {
        if v < 0 {
            bail!("[train.scaling] period must be ≥ 0 (got {v})");
        }
        spec.base.period = v as u32;
    }
    if let Some(v) = doc.get_float("train.scaling.factor") {
        spec.base.factor = v as f32;
    }
    if let Some(v) = doc.get_float("train.scaling.min_scale") {
        spec.base.min_scale = v as f32;
    }
    if let Some(v) = doc.get_float("train.scaling.max_scale") {
        spec.base.max_scale = v as f32;
    }
    if let Some(v) = doc.get_float("train.scaling.headroom") {
        spec.tuning.headroom = v as f32;
    }
    if let Some(v) = doc.get_float("train.scaling.underflow_target") {
        spec.tuning.underflow_target = v;
    }
    spec.validate()?;
    Ok(Some(spec))
}

/// Apply the shared `[trace]` table (enabled / buffer_spans /
/// trace_out) onto `trace` — the same keys configure the serve and
/// train paths.
pub fn apply_trace_toml(trace: &mut TraceConfig, doc: &TomlDoc) {
    if let Some(b) = doc.get_bool("trace.enabled") {
        trace.enabled = b;
    }
    if let Some(v) = doc.get_int("trace.buffer_spans") {
        trace.buffer_spans = v.max(0) as usize;
    }
    if let Some(s) = doc.get_str("trace.trace_out") {
        trace.trace_out = Some(s.to_string());
    }
}

/// One explicitly configured (model, precision) serving lane with its
/// own offered load and SLO — a TOML `[serve.lanes.<name>]` table.
/// Replaces the legacy single-rate-split-evenly scheme: each lane
/// declares what traffic it expects and what latency it owes, which
/// is exactly the profile the bucket planner
/// ([`crate::serve::planner`]) consumes.
#[derive(Debug, Clone)]
pub struct LaneConfig {
    /// Table name; lanes are ordered by name (TOML tables have no
    /// reliable file order in this parser).
    pub name: String,
    pub precision: Precision,
    /// Offered Poisson arrival rate in req/s (≤ 0 ⇒ back-to-back
    /// saturation, the closed-loop calibration case).
    pub rate: f64,
    /// Per-request end-to-end SLO: the p99 deadline the planner must
    /// meet and the miss threshold the reports count against.
    pub deadline_ms: u64,
    /// Weighted-deficit service weight (≥ 1).
    pub weight: u64,
    /// Optional explicit dispatch-size distribution for the planner
    /// (`burst_sizes[i]` arrives with probability weight
    /// `burst_weights[i]`); empty ⇒ derived from `rate` (Poisson over
    /// the flush window).
    pub burst_sizes: Vec<usize>,
    pub burst_weights: Vec<f64>,
}

impl LaneConfig {
    /// A lane with the given name/precision and neutral defaults
    /// (back-to-back rate, 100 ms deadline, weight 1, derived size
    /// distribution).
    pub fn named(name: &str, precision: Precision) -> LaneConfig {
        LaneConfig {
            name: name.to_string(),
            precision,
            rate: 0.0,
            deadline_ms: 100,
            weight: 1,
            burst_sizes: Vec::new(),
            burst_weights: Vec::new(),
        }
    }

    pub fn deadline(&self) -> Duration {
        Duration::from_millis(self.deadline_ms)
    }

    /// The explicit `(size, weight)` distribution, empty when the
    /// planner should derive one from the arrival rate.
    pub fn size_dist(&self) -> Vec<(usize, f64)> {
        self.burst_sizes
            .iter()
            .copied()
            .zip(self.burst_weights.iter().copied())
            .collect()
    }
}

/// Where the planner's linear service model comes from
/// (`[serve.planner] source`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerSource {
    /// The `[serve.planner] overhead_us` / `per_row_us` constants.
    Config,
    /// The measured per-lane fit persisted as `calibration.json` next
    /// to the artifacts ([`crate::serve::calibrate`]); lanes without a
    /// calibrated entry fall back to the config constants.
    Calibrated,
}

impl PlannerSource {
    pub fn parse(s: &str) -> Result<PlannerSource> {
        Ok(match s {
            "config" => PlannerSource::Config,
            "calibrated" => PlannerSource::Calibrated,
            _ => bail!(
                "unknown planner source {s:?} (expected \"config\" or \
                 \"calibrated\")"
            ),
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            PlannerSource::Config => "config",
            PlannerSource::Calibrated => "calibrated",
        }
    }
}

/// Knobs for the latency-aware bucket planner (`[serve.planner]`).
/// The linear service model (`service(b) = overhead + per_row × b`)
/// mirrors the one `serve::simulate` executes batches with; set
/// `source = "calibrated"` to replace the two constants with the
/// per-lane fit `serve::calibrate` persists from measured executions.
#[derive(Debug, Clone)]
pub struct PlannerSettings {
    /// Force the planner on/off; lanes tables being present turns it
    /// on even when false (see [`ServeConfig::use_planner`]).
    pub enabled: bool,
    /// Per-batch fixed service overhead, microseconds.
    pub overhead_us: u64,
    /// Per-row service cost, microseconds; must be ≥ 1 when the
    /// planner is in use (a zero per-row cost claims capacity that
    /// grows unboundedly with bucket size).
    pub per_row_us: u64,
    /// Max bucket artifacts to AOT-compile per lane (0 = unlimited).
    pub max_compiled: usize,
    /// Fraction of each deadline the plan may spend (headroom for
    /// model error); must be in (0, 1].
    pub safety: f64,
    /// Service-model source: config constants or the measured
    /// `calibration.json` fit.
    pub source: PlannerSource,
}

impl Default for PlannerSettings {
    fn default() -> Self {
        PlannerSettings {
            enabled: false,
            overhead_us: 300,
            per_row_us: 130,
            max_compiled: 0,
            safety: 0.9,
            source: PlannerSource::Config,
        }
    }
}

/// Network-transport knobs (`[serve.transport]`) — consumed by
/// [`crate::serve::transport::Server`] when `mpx serve --listen`
/// turns the engine into an HTTP service.
#[derive(Debug, Clone)]
pub struct TransportConfig {
    /// Listen address (`--listen` overrides); `host:0` binds an
    /// ephemeral port (tests).
    pub addr: String,
    /// Concurrent connections served; connections beyond the cap are
    /// turned away with `503` before their request is read.
    pub max_connections: usize,
    /// Inter-byte gap budget while a request is being received: a
    /// connection that goes this long without delivering another
    /// byte *mid-request* is evicted with `408`.  Idle keep-alive
    /// connections between requests are governed by
    /// `idle_timeout_ms` instead.
    pub read_timeout_ms: u64,
    /// Whole-request deadline: from the first byte of a request to
    /// its complete parse.  A trickling (slowloris) client that
    /// keeps each inter-byte gap under `read_timeout_ms` is still
    /// evicted with `408` when this budget runs out.
    pub request_deadline_ms: u64,
    /// Idle budget for a keep-alive connection sitting between
    /// requests; on expiry the connection is closed silently (no
    /// request means no one to send a status to).
    pub idle_timeout_ms: u64,
    /// Max pipelined requests in flight per connection; beyond the
    /// cap the reactor stops reading the socket (backpressure) until
    /// responses drain.
    pub max_pipelined: usize,
    /// Graceful-drain budget: after shutdown is requested, pending
    /// streams get this long to flush before they are abandoned with
    /// an error chunk.
    pub drain_deadline_ms: u64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            addr: "127.0.0.1:7878".into(),
            max_connections: 256,
            read_timeout_ms: 5_000,
            request_deadline_ms: 30_000,
            idle_timeout_ms: 60_000,
            max_pipelined: 32,
            drain_deadline_ms: 10_000,
        }
    }
}

impl TransportConfig {
    pub fn read_timeout(&self) -> Duration {
        Duration::from_millis(self.read_timeout_ms)
    }

    pub fn request_deadline(&self) -> Duration {
        Duration::from_millis(self.request_deadline_ms)
    }

    pub fn idle_timeout(&self) -> Duration {
        Duration::from_millis(self.idle_timeout_ms)
    }

    pub fn drain_deadline(&self) -> Duration {
        Duration::from_millis(self.drain_deadline_ms)
    }

    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            bail!("serve.transport: empty listen addr");
        }
        if self.max_connections == 0 {
            bail!("serve.transport: max_connections must be ≥ 1");
        }
        if self.read_timeout_ms == 0 {
            bail!("serve.transport: read_timeout_ms must be ≥ 1");
        }
        if self.request_deadline_ms == 0 {
            bail!("serve.transport: request_deadline_ms must be ≥ 1");
        }
        if self.idle_timeout_ms == 0 {
            bail!("serve.transport: idle_timeout_ms must be ≥ 1");
        }
        if self.max_pipelined == 0 {
            bail!("serve.transport: max_pipelined must be ≥ 1");
        }
        if self.drain_deadline_ms == 0 {
            bail!("serve.transport: drain_deadline_ms must be ≥ 1");
        }
        Ok(())
    }
}

/// Serving-engine configuration (`[serve]` TOML section + CLI
/// overrides — see [`crate::serve`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    /// Primary lane precision (single-lane runs; the first lane when
    /// `lane_precisions` is set).
    pub precision: Precision,
    /// Largest batch the batcher may form (the artifact batch size).
    pub max_batch: usize,
    /// Initial executor threads; each replicates every lane's model
    /// state (ddp-style).
    pub workers: usize,
    /// Autoscale ceiling: `> workers` lets the scheduler spawn up to
    /// this many workers when backlog grows (and retire them as it
    /// falls); 0 or `== workers` keeps the pool fixed.
    pub max_workers: usize,
    /// Queued requests one worker absorbs before the pool grows
    /// (autoscale sensitivity); 0 ⇒ `max_batch`.
    pub autoscale_depth: usize,
    /// Batch refill policy: continuous batching (default) or the
    /// PR-1 form-whole-batch-then-execute loop (A/B benchmarking).
    pub policy: SchedPolicy,
    /// Multi-model routing: one lane per precision listed here
    /// (empty ⇒ a single `precision` lane).
    pub lane_precisions: Vec<Precision>,
    /// Weighted-deficit service weights, matching `lane_precisions`
    /// (empty ⇒ all 1).
    pub lane_weights: Vec<u64>,
    /// Per-lane load/SLO tables (`[serve.lanes.<name>]`), ordered by
    /// name.  Non-empty lanes supersede the flat
    /// `lane_precisions`/`lane_weights` style (setting both is a
    /// validation error) and turn the bucket planner on.
    pub lanes: Vec<LaneConfig>,
    /// Bucket-planner knobs (`[serve.planner]`).
    pub planner: PlannerSettings,
    /// Network-transport knobs (`[serve.transport]`, `--listen`).
    pub transport: TransportConfig,
    /// Per-lane admission bound: requests beyond this queue depth are
    /// rejected (open loop) or block the generator (closed loop).
    pub queue_capacity: usize,
    /// Max time the oldest queued request waits before a partial
    /// batch is flushed — bounds tail latency under light load.
    pub flush_timeout_ms: u64,
    /// Per-request end-to-end deadline (reported, not enforced).
    pub deadline_ms: u64,
    /// Total requests the load generator offers (split across lanes).
    pub requests: u64,
    /// Poisson arrival rate in requests/s; ≤ 0 means back-to-back.
    pub arrival_rate: f64,
    /// Open loop drops on a full queue; closed loop blocks instead.
    pub open_loop: bool,
    pub seed: u64,
    pub artifacts_dir: String,
    /// Runtime backend compiling the artifacts (`backend = "xla" |
    /// "host"`); defaults to xla when compiled in, host otherwise.
    pub backend: BackendKind,
    /// Span tracing (`[trace]` table, `--trace-out`); disabled by
    /// default.
    pub trace: TraceConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "vit_tiny".into(),
            precision: Precision::MixedF16,
            max_batch: 8,
            workers: 2,
            max_workers: 0,
            autoscale_depth: 0,
            policy: SchedPolicy::Continuous,
            lane_precisions: Vec::new(),
            lane_weights: Vec::new(),
            lanes: Vec::new(),
            planner: PlannerSettings::default(),
            transport: TransportConfig::default(),
            queue_capacity: 64,
            flush_timeout_ms: 5,
            deadline_ms: 100,
            requests: 200,
            arrival_rate: 0.0,
            open_loop: false,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            backend: BackendKind::default_kind(),
            trace: TraceConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn flush_timeout(&self) -> Duration {
        Duration::from_millis(self.flush_timeout_ms)
    }

    pub fn deadline(&self) -> Duration {
        Duration::from_millis(self.deadline_ms)
    }

    /// The (precision, weight) lane set this config describes: the
    /// explicit `lane_precisions`/`lane_weights` lists, or the single
    /// `precision` lane at weight 1.
    pub fn effective_lanes(&self) -> Vec<(Precision, u64)> {
        if self.lane_precisions.is_empty() {
            return vec![(self.precision, 1)];
        }
        self.lane_precisions
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                (p, self.lane_weights.get(i).copied().unwrap_or(1))
            })
            .collect()
    }

    /// The full per-lane load/SLO description the engine and planner
    /// consume: the explicit `[serve.lanes.*]` tables when present,
    /// otherwise lanes synthesized from the legacy flat keys — one
    /// lane per [`ServeConfig::effective_lanes`] entry, named by its
    /// precision tag, with the single `arrival_rate` split evenly and
    /// the single `deadline_ms` shared (exactly the PR-3 behaviour).
    pub fn lane_configs(&self) -> Vec<LaneConfig> {
        if !self.lanes.is_empty() {
            return self.lanes.clone();
        }
        let eff = self.effective_lanes();
        let n = eff.len() as f64;
        eff.iter()
            .map(|&(p, w)| LaneConfig {
                name: p.tag().to_string(),
                precision: p,
                rate: if self.arrival_rate > 0.0 {
                    self.arrival_rate / n
                } else {
                    0.0
                },
                deadline_ms: self.deadline_ms,
                weight: w,
                burst_sizes: Vec::new(),
                burst_weights: Vec::new(),
            })
            .collect()
    }

    /// Whether the serve path should run the bucket planner: forced
    /// on via `[serve.planner] enabled = true`, or implied by any
    /// `[serve.lanes.*]` table (per-lane SLOs only mean something
    /// when something plans against them).
    pub fn use_planner(&self) -> bool {
        self.planner.enabled || !self.lanes.is_empty()
    }

    /// Name of the forward artifact serving batches of size `batch`
    /// for the primary precision.
    pub fn fwd_artifact(&self, batch: usize) -> String {
        self.fwd_artifact_for(self.precision, batch)
    }

    /// Per-lane variant of [`ServeConfig::fwd_artifact`].
    pub fn fwd_artifact_for(
        &self,
        precision: Precision,
        batch: usize,
    ) -> String {
        format!("fwd_{}_{}_b{}", self.model, precision.tag(), batch)
    }

    pub fn init_artifact(&self) -> String {
        self.init_artifact_for(self.precision)
    }

    pub fn init_artifact_for(&self, precision: Precision) -> String {
        format!("init_{}_{}", self.model, precision.tag())
    }

    pub fn validate(&self) -> Result<()> {
        model_preset(&self.model)?;
        if self.workers == 0 {
            bail!("serve: workers must be ≥ 1");
        }
        if self.max_workers != 0 && self.max_workers < self.workers {
            bail!(
                "serve: max_workers {} below workers {}",
                self.max_workers,
                self.workers
            );
        }
        if self.max_batch == 0 {
            bail!("serve: batch must be ≥ 1");
        }
        if self.queue_capacity < self.max_batch {
            bail!(
                "serve: queue capacity {} smaller than batch {} — the \
                 batcher could never fill a full batch",
                self.queue_capacity,
                self.max_batch
            );
        }
        if !self.lane_weights.is_empty()
            && self.lane_weights.len() != self.lane_precisions.len()
        {
            bail!(
                "serve: lane_weights has {} entries but precisions has {} — \
                 each precision lane needs exactly one weight (omit \
                 lane_weights entirely for all-1 weights)",
                self.lane_weights.len(),
                self.lane_precisions.len()
            );
        }
        if self.lane_weights.iter().any(|&w| w == 0) {
            bail!("serve: lane weights must be ≥ 1");
        }
        if !self.lanes.is_empty() {
            if !self.lane_precisions.is_empty()
                || !self.lane_weights.is_empty()
            {
                bail!(
                    "serve: [serve.lanes.*] tables and the flat \
                     precisions/lane_weights keys are mutually exclusive — \
                     describe the lanes one way"
                );
            }
            let mut seen = std::collections::BTreeSet::new();
            for l in &self.lanes {
                if l.name.is_empty() {
                    bail!("serve: lane with an empty name");
                }
                if !seen.insert(l.name.as_str()) {
                    bail!("serve: duplicate lane {:?}", l.name);
                }
                if l.weight == 0 {
                    bail!("serve: lane {:?} weight must be ≥ 1", l.name);
                }
                if l.deadline_ms == 0 {
                    bail!("serve: lane {:?} needs a deadline_ms ≥ 1", l.name);
                }
                if !l.rate.is_finite() {
                    bail!("serve: lane {:?} rate must be finite", l.name);
                }
                if l.burst_sizes.len() != l.burst_weights.len() {
                    bail!(
                        "serve: lane {:?} has {} burst_sizes but {} \
                         burst_weights — the arrays pair up elementwise",
                        l.name,
                        l.burst_sizes.len(),
                        l.burst_weights.len()
                    );
                }
                if l.burst_sizes.iter().any(|&s| s == 0) {
                    bail!("serve: lane {:?} burst_sizes must be ≥ 1", l.name);
                }
                if l.burst_weights.iter().any(|&w| !(w > 0.0) || !w.is_finite())
                {
                    bail!(
                        "serve: lane {:?} burst_weights must be finite and \
                         > 0",
                        l.name
                    );
                }
            }
        }
        self.transport.validate()?;
        self.trace.validate()?;
        if !(self.planner.safety > 0.0 && self.planner.safety <= 1.0) {
            bail!(
                "serve: planner safety {} outside (0, 1]",
                self.planner.safety
            );
        }
        if self.use_planner() && self.planner.per_row_us == 0 {
            // A zero per-row cost makes capacity_rps grow without
            // bound in the bucket size, so every rate looks absorbable
            // — the planner would happily "prove" any SLO feasible.
            bail!(
                "serve: planner per_row_us must be ≥ 1 — a zero per-row \
                 service cost claims unbounded batch capacity (set \
                 [serve.planner] per_row_us, or source = \"calibrated\" \
                 once measurements exist)"
            );
        }
        if self.use_planner() && self.policy == SchedPolicy::FormFirst {
            bail!(
                "serve: the bucket planner plans for continuous batching — \
                 policy = \"form_first\" makes lone requests wait out the \
                 flush window, voiding the planned latency model; use \
                 policy = \"continuous\" or drop the lane tables / \
                 [serve.planner] enabled"
            );
        }
        Ok(())
    }

    /// Load from a TOML file's `[serve]` section (missing keys keep
    /// their defaults).
    pub fn from_toml_file(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        let doc = TomlDoc::parse(&text).context("parse config")?;
        let mut cfg = ServeConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(s) = doc.get_str("serve.model") {
            self.model = s.to_string();
        }
        if let Some(s) = doc.get_str("serve.precision") {
            self.precision = Precision::parse(s)?;
        }
        if let Some(v) = doc.get_int("serve.batch") {
            self.max_batch = v as usize;
        }
        if let Some(v) = doc.get_int("serve.workers") {
            self.workers = v as usize;
        }
        if let Some(v) = doc.get_int("serve.max_workers") {
            self.max_workers = v as usize;
        }
        if let Some(v) = doc.get_int("serve.autoscale_depth") {
            self.autoscale_depth = v as usize;
        }
        if let Some(s) = doc.get_str("serve.policy") {
            self.policy = SchedPolicy::parse(s)?;
        }
        if let Some(list) = doc.get_str_array("serve.precisions") {
            self.lane_precisions = list
                .into_iter()
                .map(Precision::parse)
                .collect::<Result<Vec<_>>>()?;
            if let Some(&first) = self.lane_precisions.first() {
                self.precision = first;
            }
        }
        if let Some(list) = doc.get_int_array("serve.lane_weights") {
            self.lane_weights =
                list.into_iter().map(|w| w.max(0) as u64).collect();
        }
        if let Some(b) = doc.get_bool("serve.planner.enabled") {
            self.planner.enabled = b;
        }
        if let Some(v) = doc.get_int("serve.planner.overhead_us") {
            // Rejected, not clamped: `v.max(0)` silently turned a
            // negative service model into a zero one.
            if v < 0 {
                bail!("serve: planner overhead_us {v} is negative");
            }
            self.planner.overhead_us = v as u64;
        }
        if let Some(v) = doc.get_int("serve.planner.per_row_us") {
            if v < 0 {
                bail!("serve: planner per_row_us {v} is negative");
            }
            self.planner.per_row_us = v as u64;
        }
        if let Some(v) = doc.get_int("serve.planner.max_compiled") {
            self.planner.max_compiled = v.max(0) as usize;
        }
        if let Some(v) = doc.get_float("serve.planner.safety") {
            self.planner.safety = v;
        }
        if let Some(s) = doc.get_str("serve.planner.source") {
            self.planner.source = PlannerSource::parse(s)?;
        }
        if let Some(s) = doc.get_str("serve.transport.addr") {
            self.transport.addr = s.to_string();
        }
        if let Some(v) = doc.get_int("serve.transport.max_connections") {
            self.transport.max_connections = v.max(0) as usize;
        }
        if let Some(v) = doc.get_int("serve.transport.read_timeout_ms") {
            self.transport.read_timeout_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("serve.transport.request_deadline_ms") {
            self.transport.request_deadline_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("serve.transport.idle_timeout_ms") {
            self.transport.idle_timeout_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("serve.transport.max_pipelined") {
            self.transport.max_pipelined = v.max(0) as usize;
        }
        if let Some(v) = doc.get_int("serve.transport.drain_deadline_ms") {
            self.transport.drain_deadline_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("serve.queue_capacity") {
            self.queue_capacity = v as usize;
        }
        if let Some(v) = doc.get_int("serve.flush_timeout_ms") {
            self.flush_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("serve.deadline_ms") {
            self.deadline_ms = v as u64;
        }
        if let Some(v) = doc.get_int("serve.requests") {
            self.requests = v as u64;
        }
        if let Some(v) = doc.get_float("serve.arrival_rate") {
            self.arrival_rate = v;
        }
        if let Some(b) = doc.get_bool("serve.open_loop") {
            self.open_loop = b;
        }
        if let Some(v) = doc.get_int("serve.seed") {
            self.seed = v as u64;
        }
        if let Some(s) = doc.get_str("serve.artifacts_dir") {
            self.artifacts_dir = s.to_string();
        }
        if let Some(s) = doc.get_str("serve.backend") {
            self.backend = BackendKind::parse(s)?;
        }
        apply_trace_toml(&mut self.trace, doc);
        // Lane tables parse last so unset lane keys inherit the
        // [serve] scalars (precision, deadline_ms) regardless of key
        // order in the file.
        let lane_names = doc.child_tables("serve.lanes");
        if !lane_names.is_empty() {
            self.lanes.clear();
            for name in lane_names {
                let base = format!("serve.lanes.{name}");
                let nested = doc.child_tables(&base);
                if !nested.is_empty() {
                    bail!(
                        "serve: [serve.lanes.{name}] has nested tables \
                         {nested:?} — lane tables are flat (keys: precision, \
                         rate, deadline_ms, weight, burst_sizes, \
                         burst_weights)"
                    );
                }
                let mut lane = LaneConfig::named(&name, self.precision);
                lane.deadline_ms = self.deadline_ms;
                if let Some(s) = doc.get_str(&format!("{base}.precision")) {
                    lane.precision = Precision::parse(s)?;
                }
                if let Some(v) = doc.get_float(&format!("{base}.rate")) {
                    lane.rate = v;
                }
                if let Some(v) = doc.get_int(&format!("{base}.deadline_ms")) {
                    lane.deadline_ms = v.max(0) as u64;
                }
                if let Some(v) = doc.get_int(&format!("{base}.weight")) {
                    lane.weight = v.max(0) as u64;
                }
                if let Some(list) =
                    doc.get_int_array(&format!("{base}.burst_sizes"))
                {
                    lane.burst_sizes =
                        list.into_iter().map(|v| v.max(0) as usize).collect();
                }
                if let Some(list) =
                    doc.get_float_array(&format!("{base}.burst_weights"))
                {
                    lane.burst_weights = list;
                }
                self.lanes.push(lane);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_parse_and_tags() {
        assert_eq!(Precision::parse("f16").unwrap(), Precision::MixedF16);
        assert_eq!(Precision::parse("fp32").unwrap().tag(), "fp32");
        assert!(Precision::parse("f64").is_err());
    }

    #[test]
    fn scaling_config_by_precision() {
        assert_eq!(Precision::MixedF16.scaling_config().init_scale, 32768.0);
        assert_eq!(Precision::Fp32.scaling_config().init_scale, 1.0);
        assert_eq!(Precision::MixedBf16.scaling_config().max_scale, 1.0);
    }

    fn cfg_from(text: &str, name: &str) -> Result<TrainConfig> {
        let path = std::env::temp_dir().join(name);
        std::fs::write(&path, text).unwrap();
        TrainConfig::from_toml_file(path.to_str().unwrap())
    }

    #[test]
    fn scaling_table_parses_adaptive() {
        let cfg = cfg_from(
            r#"
[train]
precision = "mixed_f16"

[train.scaling]
policy = "adaptive"
init_scale = 1024.0
period = 50
headroom = 0.25
underflow_target = 0.01
"#,
            "mpx_scaling_adaptive.toml",
        )
        .unwrap();
        let spec = cfg.scaling_spec().unwrap();
        assert_eq!(spec.kind, PolicyKind::Adaptive);
        assert_eq!(spec.base.init_scale, 1024.0);
        assert_eq!(spec.base.period, 50);
        assert_eq!(spec.tuning.headroom, 0.25);
        assert_eq!(spec.tuning.underflow_target, 0.01);
    }

    #[test]
    fn scaling_table_rejects_nonsense_combos() {
        // adaptive with period = 0
        let err = cfg_from(
            r#"
[train.scaling]
policy = "adaptive"
period = 0
"#,
            "mpx_scaling_p0.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("period = 0"), "{err}");

        // pinned with a growth period
        let err = cfg_from(
            r#"
[train.scaling]
policy = "pinned"
period = 100
"#,
            "mpx_scaling_pinned_period.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("makes no sense"), "{err}");

        // dynamic with adaptive-only tuning
        let err = cfg_from(
            r#"
[train.scaling]
policy = "dynamic"
headroom = 0.5
"#,
            "mpx_scaling_dyn_headroom.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("headroom"), "{err}");

        // table without an explicit policy
        let err = cfg_from(
            r#"
[train.scaling]
period = 100
"#,
            "mpx_scaling_no_policy.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("requires an explicit policy"), "{err}");

        // adaptive on a precision that never touches f16
        let err = cfg_from(
            r#"
[train]
precision = "fp32"

[train.scaling]
policy = "adaptive"
"#,
            "mpx_scaling_fp32_adaptive.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("never casts gradients through f16"), "{err}");
    }

    #[test]
    fn old_configs_keep_parsing_via_the_legacy_default() {
        // No [train.scaling] table at all: the deprecated convention
        // applies — f16 ⇒ dynamic defaults, fp32 ⇒ pinned at 1.
        let cfg = cfg_from(
            "[train]\nprecision = \"mixed_f16\"\n",
            "mpx_scaling_legacy_f16.toml",
        )
        .unwrap();
        assert!(cfg.scaling.is_none());
        let spec = cfg.scaling_spec().unwrap();
        assert_eq!(spec.kind, PolicyKind::Dynamic);
        assert_eq!(spec.base, ScalingConfig::default());
        assert!(spec.matches_compiled(true));

        let cfg = cfg_from(
            "[train]\nprecision = \"fp32\"\n",
            "mpx_scaling_legacy_fp32.toml",
        )
        .unwrap();
        let spec = cfg.scaling_spec().unwrap();
        assert_eq!(spec.kind, PolicyKind::Pinned);
        assert_eq!(spec.base.init_scale, 1.0);
        assert!(spec.matches_compiled(false));
    }

    #[test]
    fn presets_match_paper() {
        // §5: desktop ViT feature 256, hidden 800; cluster ViT-Base.
        assert_eq!(VIT_DESKTOP.feature_dim, 256);
        assert_eq!(VIT_DESKTOP.mlp_dim, 800);
        assert_eq!(VIT_DESKTOP.num_classes, 100); // CIFAR-100
        assert_eq!(VIT_BASE.feature_dim, 768);
        assert_eq!(VIT_BASE.mlp_dim, 3072);
        assert_eq!(VIT_BASE.num_classes, 1000); // ImageNet-1k
        assert_eq!(VIT_BASE.seq_len(), 197);
        assert_eq!(VIT_DESKTOP.seq_len(), 65);
    }

    #[test]
    fn machines_match_paper() {
        // §5: RTX4070 same speed half/full; H100 double for half.
        assert_eq!(MACHINE_DESKTOP.half_speedup, 1.0);
        assert_eq!(MACHINE_CLUSTER.half_speedup, 2.0);
        assert_eq!(MACHINE_CLUSTER.devices, 4);
    }

    #[test]
    fn artifact_names() {
        let cfg = TrainConfig {
            model: "vit_desktop".into(),
            precision: Precision::MixedF16,
            batch: 64,
            ..Default::default()
        };
        assert_eq!(cfg.step_artifact(), "step_fused_vit_desktop_mixed_f16_b64");
        assert_eq!(cfg.init_artifact(), "init_vit_desktop_mixed_f16");
    }

    #[test]
    fn toml_roundtrip() {
        let text = r#"
# training run
[train]
model = "vit_desktop"
precision = "mixed_f16"
batch = 64
steps = 500
lr = 0.0003
"#;
        let path = std::env::temp_dir().join("mpx_cfg_test.toml");
        std::fs::write(&path, text).unwrap();
        let cfg =
            TrainConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.model, "vit_desktop");
        assert_eq!(cfg.batch, 64);
        assert_eq!(cfg.steps, 500);
        assert!((cfg.lr - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn serve_section_roundtrip() {
        let text = r#"
[serve]
model = "vit_tiny"
precision = "mixed_bf16"
batch = 16
workers = 4
queue_capacity = 128
flush_timeout_ms = 3
arrival_rate = 120.5
open_loop = true
"#;
        let path = std::env::temp_dir().join("mpx_serve_cfg_test.toml");
        std::fs::write(&path, text).unwrap();
        let cfg =
            ServeConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.model, "vit_tiny");
        assert_eq!(cfg.precision, Precision::MixedBf16);
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_capacity, 128);
        assert_eq!(cfg.flush_timeout_ms, 3);
        assert!((cfg.arrival_rate - 120.5).abs() < 1e-9);
        assert!(cfg.open_loop);
        // untouched keys keep defaults
        assert_eq!(cfg.requests, ServeConfig::default().requests);
        cfg.validate().unwrap();
    }

    #[test]
    fn trace_table_applies_to_serve_and_train() {
        let text = r#"
[serve]
workers = 2

[trace]
enabled = true
buffer_spans = 4096
trace_out = "out/trace.json"

[train]
steps = 5
"#;
        let path = std::env::temp_dir().join("mpx_trace_cfg_test.toml");
        std::fs::write(&path, text).unwrap();
        let scfg =
            ServeConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert!(scfg.trace.enabled);
        assert_eq!(scfg.trace.buffer_spans, 4096);
        assert_eq!(scfg.trace.trace_out.as_deref(), Some("out/trace.json"));
        scfg.validate().unwrap();
        let tcfg =
            TrainConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert!(tcfg.trace.enabled);
        assert_eq!(tcfg.trace.buffer_spans, 4096);
        // Defaults: off, with a sane buffer.
        let d = ServeConfig::default();
        assert!(!d.trace.enabled);
        assert!(d.trace.buffer_spans > 0);
        // enabled with a zero ring is a config error.
        let mut bad = ServeConfig::default();
        bad.trace.enabled = true;
        bad.trace.buffer_spans = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serve_config_validation() {
        let mut cfg = ServeConfig::default();
        cfg.validate().unwrap();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        cfg.workers = 2;
        cfg.queue_capacity = cfg.max_batch - 1;
        assert!(cfg.validate().is_err());
        cfg.queue_capacity = 64;
        cfg.max_workers = 1; // below workers
        assert!(cfg.validate().is_err());
        cfg.max_workers = 8;
        cfg.validate().unwrap();
        cfg.lane_precisions = vec![Precision::Fp32, Precision::MixedF16];
        cfg.lane_weights = vec![2];
        assert!(cfg.validate().is_err(), "weight/precision length mismatch");
        cfg.lane_weights = vec![2, 0];
        assert!(cfg.validate().is_err(), "zero weight");
        cfg.lane_weights = vec![2, 1];
        cfg.validate().unwrap();
    }

    #[test]
    fn serve_lane_section_roundtrip() {
        let text = r#"
[serve]
precisions = ["fp32", "mixed_f16"]
lane_weights = [1, 2]
max_workers = 6
autoscale_depth = 16
policy = "form_first"
"#;
        let path = std::env::temp_dir().join("mpx_serve_lane_cfg_test.toml");
        std::fs::write(&path, text).unwrap();
        let cfg =
            ServeConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert_eq!(
            cfg.lane_precisions,
            vec![Precision::Fp32, Precision::MixedF16]
        );
        assert_eq!(cfg.lane_weights, vec![1, 2]);
        // primary precision follows the first lane
        assert_eq!(cfg.precision, Precision::Fp32);
        assert_eq!(cfg.max_workers, 6);
        assert_eq!(cfg.autoscale_depth, 16);
        assert_eq!(cfg.policy, SchedPolicy::FormFirst);
        cfg.validate().unwrap();
        assert_eq!(
            cfg.effective_lanes(),
            vec![(Precision::Fp32, 1), (Precision::MixedF16, 2)]
        );
    }

    #[test]
    fn serve_lane_tables_roundtrip() {
        let text = r#"
[serve]
batch = 8
workers = 2
precision = "fp32"
deadline_ms = 150

[serve.lanes.chat]
precision = "mixed_f16"
rate = 80.0
deadline_ms = 20
weight = 2
burst_sizes = [1, 2]
burst_weights = [0.8, 0.2]

[serve.lanes.bulk]
rate = 0.0

[serve.planner]
enabled = true
overhead_us = 250
per_row_us = 120
max_compiled = 3
safety = 0.8
"#;
        let path = std::env::temp_dir().join("mpx_serve_lanes_cfg_test.toml");
        std::fs::write(&path, text).unwrap();
        let cfg =
            ServeConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        cfg.validate().unwrap();
        assert!(cfg.use_planner());
        assert_eq!(cfg.planner.overhead_us, 250);
        assert_eq!(cfg.planner.per_row_us, 120);
        assert_eq!(cfg.planner.max_compiled, 3);
        assert!((cfg.planner.safety - 0.8).abs() < 1e-12);
        // Lanes come back ordered by name (bulk, chat).
        assert_eq!(cfg.lanes.len(), 2);
        let bulk = &cfg.lanes[0];
        assert_eq!(bulk.name, "bulk");
        // Unset lane keys inherit the section defaults.
        assert_eq!(bulk.precision, Precision::Fp32);
        assert_eq!(bulk.deadline_ms, 150);
        assert_eq!(bulk.weight, 1);
        assert_eq!(bulk.rate, 0.0);
        let chat = &cfg.lanes[1];
        assert_eq!(chat.name, "chat");
        assert_eq!(chat.precision, Precision::MixedF16);
        assert!((chat.rate - 80.0).abs() < 1e-9);
        assert_eq!(chat.deadline_ms, 20);
        assert_eq!(chat.weight, 2);
        assert_eq!(chat.size_dist(), vec![(1, 0.8), (2, 0.2)]);
        // lane_configs passes explicit tables through verbatim.
        assert_eq!(cfg.lane_configs().len(), 2);
        assert_eq!(cfg.lane_configs()[1].name, "chat");
    }

    #[test]
    fn serve_transport_section_roundtrip() {
        let text = r#"
[serve]
workers = 2

[serve.transport]
addr = "0.0.0.0:9000"
max_connections = 64
read_timeout_ms = 2500
request_deadline_ms = 12000
idle_timeout_ms = 45000
max_pipelined = 8
drain_deadline_ms = 1500
"#;
        let path = std::env::temp_dir().join("mpx_serve_transport_cfg.toml");
        std::fs::write(&path, text).unwrap();
        let cfg =
            ServeConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.transport.addr, "0.0.0.0:9000");
        assert_eq!(cfg.transport.max_connections, 64);
        assert_eq!(cfg.transport.read_timeout_ms, 2500);
        assert_eq!(cfg.transport.request_deadline_ms, 12000);
        assert_eq!(cfg.transport.idle_timeout_ms, 45000);
        assert_eq!(cfg.transport.max_pipelined, 8);
        assert_eq!(cfg.transport.drain_deadline_ms, 1500);
        assert_eq!(
            cfg.transport.read_timeout(),
            Duration::from_millis(2500)
        );
        assert_eq!(
            cfg.transport.request_deadline(),
            Duration::from_millis(12000)
        );
        assert_eq!(
            cfg.transport.idle_timeout(),
            Duration::from_millis(45000)
        );
        // Untouched configs keep the defaults and validate.
        let d = TransportConfig::default();
        assert_eq!(d.addr, "127.0.0.1:7878");
        assert_eq!(d.request_deadline_ms, 30_000);
        assert_eq!(d.idle_timeout_ms, 60_000);
        assert_eq!(d.max_pipelined, 32);
        d.validate().unwrap();
    }

    #[test]
    fn transport_validation_rejects_zeroes() {
        let bad = [
            TransportConfig { max_connections: 0, ..Default::default() },
            TransportConfig { read_timeout_ms: 0, ..Default::default() },
            TransportConfig { request_deadline_ms: 0, ..Default::default() },
            TransportConfig { idle_timeout_ms: 0, ..Default::default() },
            TransportConfig { max_pipelined: 0, ..Default::default() },
            TransportConfig { drain_deadline_ms: 0, ..Default::default() },
            TransportConfig { addr: String::new(), ..Default::default() },
        ];
        for t in bad {
            assert!(t.validate().is_err(), "{t:?} should not validate");
        }
        // ServeConfig::validate folds the transport check in.
        let mut cfg = ServeConfig::default();
        cfg.transport.max_connections = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn nested_lane_tables_are_rejected_not_dropped() {
        // `[serve.lanes.us.east]` would otherwise parse as an
        // all-defaults lane "us" with every east.* key ignored.
        let text = r#"
[serve.lanes.us.east]
rate = 500.0
deadline_ms = 20
"#;
        let path = std::env::temp_dir().join("mpx_serve_nested_lane.toml");
        std::fs::write(&path, text).unwrap();
        let err = ServeConfig::from_toml_file(path.to_str().unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("nested"), "got: {err}");
    }

    #[test]
    fn lane_tables_validation() {
        let mut cfg = ServeConfig::default();
        cfg.lanes = vec![
            LaneConfig::named("a", Precision::Fp32),
            LaneConfig::named("b", Precision::MixedF16),
        ];
        cfg.validate().unwrap();
        assert!(cfg.use_planner(), "lane tables imply the planner");

        // Mixing lane tables with the flat keys is ambiguous.
        let mut bad = cfg.clone();
        bad.lane_precisions = vec![Precision::Fp32];
        assert!(bad.validate().is_err());

        let mut bad = cfg.clone();
        bad.lanes[1].name = "a".into();
        assert!(bad.validate().is_err(), "duplicate lane name");

        let mut bad = cfg.clone();
        bad.lanes[0].weight = 0;
        assert!(bad.validate().is_err(), "zero weight");

        let mut bad = cfg.clone();
        bad.lanes[0].deadline_ms = 0;
        assert!(bad.validate().is_err(), "zero deadline");

        let mut bad = cfg.clone();
        bad.lanes[0].burst_sizes = vec![1, 2];
        bad.lanes[0].burst_weights = vec![1.0];
        assert!(bad.validate().is_err(), "burst array length mismatch");

        let mut bad = cfg.clone();
        bad.lanes[0].burst_sizes = vec![0];
        bad.lanes[0].burst_weights = vec![1.0];
        assert!(bad.validate().is_err(), "zero burst size");

        let mut bad = cfg.clone();
        bad.planner.safety = 0.0;
        assert!(bad.validate().is_err(), "safety outside (0, 1]");

        let mut bad = cfg.clone();
        bad.policy = SchedPolicy::FormFirst;
        assert!(
            bad.validate().is_err(),
            "form_first voids the planner's latency model"
        );

        let mut bad = cfg;
        bad.planner.overhead_us = 0;
        bad.planner.per_row_us = 0;
        assert!(bad.validate().is_err(), "all-zero service model");
    }

    #[test]
    fn planner_model_keys_reject_negatives_and_zero_per_row() {
        let parse = |body: &str, name: &str| {
            let path = std::env::temp_dir().join(name);
            std::fs::write(&path, body).unwrap();
            ServeConfig::from_toml_file(path.to_str().unwrap())
        };
        // Negative values used to be clamped to 0 by `v.max(0)` —
        // they must fail loudly like the transport keys do.
        let err = parse(
            "[serve.planner]\noverhead_us = -5\n",
            "mpx_planner_neg_overhead.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("negative"), "got: {err}");
        let err = parse(
            "[serve.planner]\nper_row_us = -1\n",
            "mpx_planner_neg_per_row.toml",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("negative"), "got: {err}");

        // per_row_us = 0 with the planner on claims capacity that
        // grows unboundedly with bucket size — rejected on its own,
        // not only when overhead_us is also zero.
        let mut cfg = ServeConfig::default();
        cfg.planner.enabled = true;
        cfg.planner.per_row_us = 0;
        assert!(cfg.validate().is_err(), "zero per_row_us must fail");
        // ...while zero overhead alone is a legal pure per-row model,
        cfg.planner.overhead_us = 0;
        cfg.planner.per_row_us = 130;
        cfg.validate().unwrap();
        // ...and with the planner off the model keys are inert.
        let mut cfg = ServeConfig::default();
        cfg.planner.per_row_us = 0;
        cfg.validate().unwrap();

        // The service-model source key parses both values, defaults
        // to config, and rejects anything else.
        assert_eq!(ServeConfig::default().planner.source, PlannerSource::Config);
        let cfg = parse(
            "[serve.planner]\nsource = \"calibrated\"\n",
            "mpx_planner_source_cal.toml",
        )
        .unwrap();
        assert_eq!(cfg.planner.source, PlannerSource::Calibrated);
        let cfg = parse(
            "[serve.planner]\nsource = \"config\"\n",
            "mpx_planner_source_cfg.toml",
        )
        .unwrap();
        assert_eq!(cfg.planner.source, PlannerSource::Config);
        assert!(parse(
            "[serve.planner]\nsource = \"psychic\"\n",
            "mpx_planner_source_bad.toml",
        )
        .is_err());
        assert_eq!(PlannerSource::Calibrated.tag(), "calibrated");
        assert_eq!(PlannerSource::Config.tag(), "config");
    }

    #[test]
    fn legacy_lane_configs_split_the_rate_evenly() {
        let mut cfg = ServeConfig {
            lane_precisions: vec![Precision::Fp32, Precision::MixedF16],
            lane_weights: vec![1, 2],
            arrival_rate: 100.0,
            deadline_ms: 40,
            ..ServeConfig::default()
        };
        let lanes = cfg.lane_configs();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].name, "fp32");
        assert_eq!(lanes[1].name, "mixed_f16");
        assert!((lanes[0].rate - 50.0).abs() < 1e-9);
        assert!((lanes[1].rate - 50.0).abs() < 1e-9);
        assert_eq!(lanes[0].deadline_ms, 40);
        assert_eq!(lanes[1].weight, 2);
        assert!(!cfg.use_planner(), "legacy flat keys stay planner-off");
        // Back-to-back stays back-to-back per lane.
        cfg.arrival_rate = 0.0;
        assert_eq!(cfg.lane_configs()[0].rate, 0.0);
    }

    #[test]
    fn effective_lanes_default_to_single_precision() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.effective_lanes(), vec![(Precision::MixedF16, 1)]);
        assert_eq!(cfg.policy, SchedPolicy::Continuous);
    }

    #[test]
    fn serve_artifact_names() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.fwd_artifact(8), "fwd_vit_tiny_mixed_f16_b8");
        assert_eq!(cfg.init_artifact(), "init_vit_tiny_mixed_f16");
        assert_eq!(
            cfg.fwd_artifact_for(Precision::Fp32, 4),
            "fwd_vit_tiny_fp32_b4"
        );
        assert_eq!(
            cfg.init_artifact_for(Precision::MixedBf16),
            "init_vit_tiny_mixed_bf16"
        );
    }
}
