//! TOML-subset parser (no external crates offline).
//!
//! Grammar: `[section]` / `[a.b]` headers, `key = value` lines where
//! value ∈ {"string", integer, float, bool, [array of scalars]},
//! `#` comments anywhere, blank lines.  Keys are addressed by dotted
//! path (`train.batch`).  This covers the repo's config files; the
//! parser rejects what it does not understand rather than guessing.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

#[derive(Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
    /// Every `[section]` header seen, including ones with no keys —
    /// so an all-defaults table like `[serve.lanes.bulk]` is
    /// enumerable by [`TomlDoc::child_tables`] rather than silently
    /// dropped.
    sections: std::collections::BTreeSet<String>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                let name = name.trim();
                if name.is_empty()
                    || !name.chars().all(|c| {
                        c.is_ascii_alphanumeric() || c == '_' || c == '.'
                    })
                {
                    bail!("line {}: bad section name {name:?}", lineno + 1);
                }
                section = name.to_string();
                doc.sections.insert(section.clone());
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`", lineno + 1);
            };
            let key = line[..eq].trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_')
            {
                bail!("line {}: bad key {key:?}", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(path, value);
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        match self.get(path) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, path: &str) -> Option<i64> {
        match self.get(path) {
            Some(TomlValue::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_float(&self, path: &str) -> Option<f64> {
        match self.get(path) {
            Some(TomlValue::Float(v)) => Some(*v),
            Some(TomlValue::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        match self.get(path) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Homogeneous string array (`ks = ["a", "b"]`); `None` when the
    /// key is absent, not an array, or mixes types.
    pub fn get_str_array(&self, path: &str) -> Option<Vec<&str>> {
        match self.get(path) {
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Some(s.as_str()),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// Homogeneous integer array (`ns = [2, 1]`).
    pub fn get_int_array(&self, path: &str) -> Option<Vec<i64>> {
        match self.get(path) {
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Int(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// Numeric array (`ws = [0.8, 0.2]`); integers promote to floats,
    /// matching [`TomlDoc::get_float`].
    pub fn get_float_array(&self, path: &str) -> Option<Vec<f64>> {
        match self.get(path) {
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Float(f) => Some(*f),
                    TomlValue::Int(i) => Some(*i as f64),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// Names of the direct child tables under `prefix`: with keys
    /// `serve.lanes.chat.rate` and `serve.lanes.bulk.rate`,
    /// `child_tables("serve.lanes")` is `["bulk", "chat"]`.  Sorted
    /// and deduplicated, so table enumeration is deterministic
    /// regardless of file order.  A bare `[prefix.name]` header with
    /// no keys still counts — an all-defaults table is a table.
    pub fn child_tables(&self, prefix: &str) -> Vec<String> {
        let pre = format!("{prefix}.");
        let mut out = std::collections::BTreeSet::new();
        for key in self.values.keys() {
            if let Some(rest) = key.strip_prefix(&pre) {
                if let Some((child, _)) = rest.split_once('.') {
                    out.insert(child.to_string());
                }
            }
        }
        for section in &self.sections {
            if let Some(rest) = section.strip_prefix(&pre) {
                let child = rest.split('.').next().unwrap_or(rest);
                if !child.is_empty() {
                    out.insert(child.to_string());
                }
            }
        }
        out.into_iter().collect()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string");
        };
        if inner.contains('"') {
            bail!("embedded quote in string (escapes unsupported)");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    // number: int first, then float
    if let Ok(v) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = TomlDoc::parse(
            r#"
# top comment
title = "mpx"          # inline comment

[train]
model = "vit_desktop"
batch = 64
lr = 3e-4
resume = false
batches = [8, 16, 32]

[machine.desktop]
bandwidth = 504.0
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("title"), Some("mpx"));
        assert_eq!(doc.get_int("train.batch"), Some(64));
        assert_eq!(doc.get_float("train.lr"), Some(3e-4));
        assert_eq!(doc.get_bool("train.resume"), Some(false));
        assert_eq!(doc.get_float("machine.desktop.bandwidth"), Some(504.0));
        match doc.get("train.batches") {
            Some(TomlValue::Array(a)) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn typed_array_getters() {
        let doc = TomlDoc::parse(
            r#"
ss = ["fp32", "mixed_f16"]
ns = [2, 1]
mixed = [1, "two"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str_array("ss"), Some(vec!["fp32", "mixed_f16"]));
        assert_eq!(doc.get_int_array("ns"), Some(vec![2, 1]));
        // mixed or mistyped arrays are refused, not coerced
        assert_eq!(doc.get_str_array("ns"), None);
        assert_eq!(doc.get_int_array("ss"), None);
        assert_eq!(doc.get_str_array("mixed"), None);
        assert_eq!(doc.get_int_array("mixed"), None);
        assert_eq!(doc.get_str_array("absent"), None);
    }

    #[test]
    fn int_promotes_to_float_getter() {
        let doc = TomlDoc::parse("x = 5").unwrap();
        assert_eq!(doc.get_float("x"), Some(5.0));
        assert_eq!(doc.get_int("x"), Some(5));
    }

    #[test]
    fn float_array_promotes_ints() {
        let doc = TomlDoc::parse(
            r#"
ws = [0.8, 0.2]
mixed_num = [1, 0.5]
ss = ["a"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_float_array("ws"), Some(vec![0.8, 0.2]));
        assert_eq!(doc.get_float_array("mixed_num"), Some(vec![1.0, 0.5]));
        assert_eq!(doc.get_float_array("ss"), None);
        assert_eq!(doc.get_float_array("absent"), None);
    }

    #[test]
    fn child_tables_enumerates_sorted_unique_names() {
        let doc = TomlDoc::parse(
            r#"
[serve]
batch = 8

[serve.lanes.chat]
rate = 80.0
weight = 2

[serve.lanes.bulk]
rate = 0.0

[serve.planner]
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(doc.child_tables("serve.lanes"), vec!["bulk", "chat"]);
        // Direct keys under the prefix (no deeper segment) are not
        // tables; unrelated prefixes see nothing.
        assert_eq!(doc.child_tables("serve.lanes.chat"), Vec::<String>::new());
        assert_eq!(doc.child_tables("train"), Vec::<String>::new());
        // `serve` has child tables `lanes.*` and `planner`.
        assert_eq!(doc.child_tables("serve"), vec!["lanes", "planner"]);
    }

    #[test]
    fn bare_table_headers_still_enumerate() {
        // An all-defaults table has a header but no keys — it must
        // not vanish from enumeration.
        let doc = TomlDoc::parse(
            r#"
[serve.lanes.chat]
rate = 80.0

[serve.lanes.idle]
"#,
        )
        .unwrap();
        assert_eq!(doc.child_tables("serve.lanes"), vec!["chat", "idle"]);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.get_str("k"), Some("a#b"));
    }

    #[test]
    fn underscore_numbers() {
        let doc = TomlDoc::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.get_int("n"), Some(1_000_000));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
        assert!(TomlDoc::parse("bad key = 1").is_err());
    }
}
