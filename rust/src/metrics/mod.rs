//! Run metrics: step timing, loss history, scaling trace, latency
//! histograms, writers.

use std::io::Write;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::benchkit::quantile_ns;

/// Latency distribution with rank-interpolated quantiles.
///
/// Exact-sample implementation by default (no bucketing error): every
/// recorded duration is kept as integer nanoseconds, quantiles sort
/// on demand.  Quantile estimation is the shared rank-interpolated
/// [`quantile_ns`] (Hyndman–Fan type 7) — truncating the rank
/// instead (the bug this type replaced) under-reports upper tails on
/// small samples: p99 of 10 samples would return the 9th of 10.
///
/// [`with_sample_cap`] bounds memory for long-running servers: once
/// the retained samples reach the cap, every second one is discarded
/// and the record stride doubles — a deterministic capped reservoir
/// (no RNG; the same observation sequence always retains the same
/// samples).  Memory is then `O(cap)` however many observations
/// arrive, quantiles become a uniform-in-time subsample, and the
/// scalar statistics — [`count`], [`total`], [`mean`], [`max`] — stay
/// **exact** in both modes (tracked as running counters, not derived
/// from the retained samples).
///
/// Per-worker histograms are recorded independently and [`merge`]d
/// for the serving report; merging is exact sample concatenation in
/// exact mode, and re-enforces the receiver's cap otherwise.
///
/// [`merge`]: LatencyHistogram::merge
/// [`with_sample_cap`]: LatencyHistogram::with_sample_cap
/// [`count`]: LatencyHistogram::count
/// [`total`]: LatencyHistogram::total
/// [`mean`]: LatencyHistogram::mean
/// [`max`]: LatencyHistogram::max
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    samples_ns: Vec<u64>,
    /// Retained-sample bound; 0 = exact mode (keep everything).
    sample_cap: usize,
    /// Record every `stride`-th observation (1 until the cap bites).
    stride: u64,
    /// Observations seen, for the stride phase.
    tick: u64,
    /// Exact observation count (what [`count`] reports).
    observed: u64,
    /// Exact sum of all observations, in nanoseconds.
    sum_ns: u128,
    /// Exact maximum observation, in nanoseconds.
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Precomputed summary of a [`LatencyHistogram`] (one sort).
#[derive(Debug, Clone, Copy)]
pub struct LatencySummary {
    pub count: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            samples_ns: Vec::new(),
            sample_cap: 0,
            stride: 1,
            tick: 0,
            observed: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    /// Bounded-memory mode: retain at most ~`cap` samples (compacting
    /// by stride-doubling past it), while `count`/`total`/`mean`/`max`
    /// stay exact.  `cap` is clamped to ≥ 2.
    pub fn with_sample_cap(cap: usize) -> LatencyHistogram {
        LatencyHistogram { sample_cap: cap.max(2), ..Self::new() }
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.observed += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
        if self.tick % self.stride == 0 {
            self.samples_ns.push(ns);
            self.enforce_cap();
        }
        self.tick += 1;
    }

    /// Drop every second retained sample and double the stride until
    /// the retained set fits the cap again.  Deterministic: which
    /// observations survive depends only on their arrival order.
    fn enforce_cap(&mut self) {
        if self.sample_cap == 0 {
            return;
        }
        while self.samples_ns.len() >= self.sample_cap {
            let mut i = 0usize;
            self.samples_ns.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride = self.stride.saturating_mul(2);
        }
    }

    /// Exact observation count — a running counter, not the retained
    /// sample count, so it is unaffected by [`with_sample_cap`]
    /// compaction.
    ///
    /// [`with_sample_cap`]: LatencyHistogram::with_sample_cap
    pub fn count(&self) -> usize {
        self.observed as usize
    }

    pub fn is_empty(&self) -> bool {
        self.observed == 0
    }

    /// Retained samples backing the quantiles (== [`count`] in exact
    /// mode, ≤ the cap in bounded mode).
    ///
    /// [`count`]: LatencyHistogram::count
    pub fn retained(&self) -> usize {
        self.samples_ns.len()
    }

    /// Fold another histogram in (per-worker → run aggregate).  The
    /// scalar statistics merge exactly; the receiver's sample cap (if
    /// any) is re-enforced on the concatenated samples.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.samples_ns.extend_from_slice(&other.samples_ns);
        self.observed += other.observed;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.enforce_cap();
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.observed == 0 {
            return None;
        }
        Some(Duration::from_nanos(
            (self.sum_ns / self.observed as u128).min(u64::MAX as u128)
                as u64,
        ))
    }

    pub fn max(&self) -> Option<Duration> {
        (self.observed > 0).then(|| Duration::from_nanos(self.max_ns))
    }

    /// Sum of all recorded samples (the Prometheus summary `_sum`).
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.sum_ns.min(u64::MAX as u128) as u64)
    }

    /// Rank-interpolated quantile, `q` ∈ [0, 1].
    pub fn quantile(&self, q: f64) -> Option<Duration> {
        self.quantiles(&[q]).map(|v| v[0])
    }

    /// Several quantiles with a single sort.
    pub fn quantiles(&self, qs: &[f64]) -> Option<Vec<Duration>> {
        if self.samples_ns.is_empty() {
            return None;
        }
        let mut xs = self.samples_ns.clone();
        xs.sort_unstable();
        Some(qs.iter().map(|&q| quantile_ns(&xs, q)).collect())
    }

    pub fn summary(&self) -> Option<LatencySummary> {
        if self.samples_ns.is_empty() {
            return None;
        }
        let mut xs = self.samples_ns.clone();
        xs.sort_unstable();
        Some(LatencySummary {
            count: self.count(),
            mean: self.mean().unwrap(),
            p50: quantile_ns(&xs, 0.5),
            p95: quantile_ns(&xs, 0.95),
            p99: quantile_ns(&xs, 0.99),
            max: Duration::from_nanos(self.max_ns),
        })
    }
}

/// A set of [`LatencyHistogram`]s keyed by name — the per-lane
/// latency aggregation the multi-model serve report uses (one entry
/// per (model, precision) lane).
///
/// Entries keep insertion order (lane order), and [`merge`] is exact
/// sample concatenation per key, so merging per-worker sets equals
/// recording into one shared set.
///
/// [`merge`]: NamedHistograms::merge
#[derive(Debug, Clone, Default)]
pub struct NamedHistograms {
    entries: Vec<(String, LatencyHistogram)>,
}

impl NamedHistograms {
    pub fn new() -> NamedHistograms {
        NamedHistograms { entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The histogram for `name`, created empty on first use.
    pub fn entry(&mut self, name: &str) -> &mut LatencyHistogram {
        if let Some(i) =
            self.entries.iter().position(|(n, _)| n == name)
        {
            return &mut self.entries[i].1;
        }
        self.entries.push((name.to_string(), LatencyHistogram::new()));
        &mut self.entries.last_mut().unwrap().1
    }

    pub fn get(&self, name: &str) -> Option<&LatencyHistogram> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Fold another set in, key by key.
    pub fn merge(&mut self, other: &NamedHistograms) {
        for (name, h) in &other.entries {
            self.entry(name).merge(h);
        }
    }

    pub fn iter(
        &self,
    ) -> impl Iterator<Item = (&str, &LatencyHistogram)> {
        self.entries.iter().map(|(n, h)| (n.as_str(), h))
    }

    /// All samples pooled across names.
    pub fn merged(&self) -> LatencyHistogram {
        let mut all = LatencyHistogram::new();
        for (_, h) in &self.entries {
            all.merge(h);
        }
        all
    }

    /// Append this set as a Prometheus `summary` family named
    /// `metric`, one `{lane="..."}` series per entry: p50/p95/p99
    /// quantile samples (seconds) plus `_sum` and `_count`.  This is
    /// what the serve transport's `GET /metrics` endpoint exports.
    /// Lane names pass through [`prom_escape`]; `_count` is the
    /// exact observation count, so it is monotone under
    /// [`LatencyHistogram::with_sample_cap`] compaction.
    pub fn to_prometheus(&self, metric: &str, out: &mut String) {
        use std::fmt::Write;
        let _ = writeln!(out, "# HELP {metric} per-lane latency summary");
        let _ = writeln!(out, "# TYPE {metric} summary");
        for (lane, h) in self.iter() {
            let lane = prom_escape(lane);
            if let Some(qs) = h.quantiles(&[0.5, 0.95, 0.99]) {
                for (q, v) in ["0.5", "0.95", "0.99"].iter().zip(qs) {
                    let _ = writeln!(
                        out,
                        "{metric}{{lane=\"{lane}\",quantile=\"{q}\"}} {}",
                        v.as_secs_f64()
                    );
                }
            }
            let _ = writeln!(
                out,
                "{metric}_sum{{lane=\"{lane}\"}} {}",
                h.total().as_secs_f64()
            );
            let _ = writeln!(
                out,
                "{metric}_count{{lane=\"{lane}\"}} {}",
                h.count()
            );
        }
    }
}

/// Escape a Prometheus label *value*: the text exposition format
/// requires `\`, `"` and newline escaped inside `label="..."`.
/// Everything the transport's `/metrics` endpoint interpolates into a
/// label goes through here.
pub fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(ch),
        }
    }
    out
}

/// Render a finished training run in the Prometheus text exposition
/// format: run-wide counters plus the per-layer-group scaling series.
/// `scaling` rows are `(group, scale, skipped)` — one per policy
/// group, so the global policies export a single `group="global"`
/// series while the adaptive policy gets one per derived layer group
/// (`mpx_train_loss_scale{group="blocks[0]"} …`).  Group names pass
/// through [`prom_escape`].  This backs `mpx train --metrics-prom`,
/// which writes the result as a node-exporter-style textfile.
pub fn train_prometheus(
    metrics: &RunMetrics,
    scaling: &[(String, f32, u64)],
) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# HELP mpx_train_steps_total training steps taken"
    );
    let _ = writeln!(out, "# TYPE mpx_train_steps_total counter");
    let _ =
        writeln!(out, "mpx_train_steps_total {}", metrics.records.len());
    let _ = writeln!(
        out,
        "# HELP mpx_train_steps_skipped_total steps skipped run-wide \
         (gradient overflow)"
    );
    let _ = writeln!(out, "# TYPE mpx_train_steps_skipped_total counter");
    let _ = writeln!(
        out,
        "mpx_train_steps_skipped_total {}",
        metrics.skipped_steps()
    );
    if let Some(loss) = metrics.recent_loss(10) {
        let _ = writeln!(
            out,
            "# HELP mpx_train_loss mean loss over the last 10 steps"
        );
        let _ = writeln!(out, "# TYPE mpx_train_loss gauge");
        let _ = writeln!(out, "mpx_train_loss {loss}");
    }
    if !scaling.is_empty() {
        let _ = writeln!(
            out,
            "# HELP mpx_train_loss_scale current loss scale per layer \
             group"
        );
        let _ = writeln!(out, "# TYPE mpx_train_loss_scale gauge");
        for (group, scale, _) in scaling {
            let _ = writeln!(
                out,
                "mpx_train_loss_scale{{group=\"{}\"}} {scale}",
                prom_escape(group)
            );
        }
        let _ = writeln!(
            out,
            "# HELP mpx_train_skipped_steps_total optimizer steps \
             skipped per layer group (overflow backoff)"
        );
        let _ =
            writeln!(out, "# TYPE mpx_train_skipped_steps_total counter");
        for (group, _, skipped) in scaling {
            let _ = writeln!(
                out,
                "mpx_train_skipped_steps_total{{group=\"{}\"}} {skipped}",
                prom_escape(group)
            );
        }
    }
    out
}

/// Exponential moving average (smoothing for console logs).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// One training step's record.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub grads_finite: bool,
    pub loss_scale: f32,
    pub step_time: Duration,
}

/// In-memory run history + optional CSV sink.
pub struct RunMetrics {
    pub records: Vec<StepRecord>,
    started: Instant,
    csv: Option<std::fs::File>,
}

impl RunMetrics {
    pub fn new() -> RunMetrics {
        RunMetrics { records: Vec::new(), started: Instant::now(), csv: None }
    }

    /// Also stream records to a CSV file.
    pub fn with_csv(path: &str) -> Result<RunMetrics> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create metrics csv {path}"))?;
        writeln!(f, "step,loss,grads_finite,loss_scale,step_ms")?;
        Ok(RunMetrics {
            records: Vec::new(),
            started: Instant::now(),
            csv: Some(f),
        })
    }

    pub fn record(&mut self, r: StepRecord) -> Result<()> {
        if let Some(f) = &mut self.csv {
            writeln!(
                f,
                "{},{},{},{},{:.3}",
                r.step,
                r.loss,
                r.grads_finite as u8,
                r.loss_scale,
                r.step_time.as_secs_f64() * 1e3
            )?;
        }
        self.records.push(r);
        Ok(())
    }

    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Mean step time over the records after `skip` warmup steps.
    pub fn mean_step_time(&self, skip: usize) -> Option<Duration> {
        let xs: Vec<Duration> = self
            .records
            .iter()
            .skip(skip)
            .map(|r| r.step_time)
            .collect();
        if xs.is_empty() {
            return None;
        }
        Some(xs.iter().sum::<Duration>() / xs.len() as u32)
    }

    /// Mean loss over the last `n` records.
    pub fn recent_loss(&self, n: usize) -> Option<f32> {
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        if tail.is_empty() {
            return None;
        }
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    pub fn skipped_steps(&self) -> usize {
        self.records.iter().filter(|r| !r.grads_finite).count()
    }
}

impl Default for RunMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, ms: u64) -> StepRecord {
        StepRecord {
            step,
            loss,
            grads_finite: true,
            loss_scale: 1.0,
            step_time: Duration::from_millis(ms),
        }
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(4.0), 4.0);
        assert_eq!(e.push(0.0), 2.0);
        assert_eq!(e.push(0.0), 1.0);
    }

    #[test]
    fn mean_step_time_skips_warmup() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 1.0, 1000)).unwrap(); // compile-warmed first step
        m.record(rec(1, 1.0, 10)).unwrap();
        m.record(rec(2, 1.0, 20)).unwrap();
        assert_eq!(m.mean_step_time(1), Some(Duration::from_millis(15)));
    }

    #[test]
    fn recent_loss_window() {
        let mut m = RunMetrics::new();
        for i in 0..10 {
            m.record(rec(i, i as f32, 1)).unwrap();
        }
        assert_eq!(m.recent_loss(2), Some(8.5));
        assert_eq!(m.recent_loss(100), Some(4.5));
    }

    #[test]
    fn csv_written() {
        let path = std::env::temp_dir().join("mpx_metrics_test.csv");
        let path = path.to_str().unwrap();
        {
            let mut m = RunMetrics::with_csv(path).unwrap();
            m.record(rec(0, 0.5, 3)).unwrap();
        }
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("step,loss"));
        assert!(text.contains("0,0.5,1,1,3.000"));
    }

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn histogram_exact_quantiles_on_known_distribution() {
        // 0..=100 ms: every quantile lands exactly on a sample.
        let mut h = LatencyHistogram::new();
        for v in 0..=100u64 {
            h.record(ms(v));
        }
        assert_eq!(h.quantile(0.0), Some(ms(0)));
        assert_eq!(h.quantile(0.5), Some(ms(50)));
        assert_eq!(h.quantile(0.95), Some(ms(95)));
        assert_eq!(h.quantile(0.99), Some(ms(99)));
        assert_eq!(h.quantile(1.0), Some(ms(100)));
        assert_eq!(h.mean(), Some(ms(50)));
        assert_eq!(h.max(), Some(ms(100)));
    }

    #[test]
    fn histogram_interpolates_between_ranks() {
        let mut h = LatencyHistogram::new();
        for v in [10u64, 20, 30, 40] {
            h.record(ms(v));
        }
        // h = 0.5·3 = 1.5 → 20 + 0.5·(30-20) = 25 ms.
        assert_eq!(h.quantile(0.5), Some(ms(25)));
        // h = 0.99·3 = 2.97 → 30 + 0.97·10 = 39.7 ms.
        assert_eq!(h.quantile(0.99), Some(Duration::from_micros(39_700)));
    }

    #[test]
    fn histogram_p99_not_truncated_on_small_samples() {
        // Regression for the old `((n-1) as f64 * q) as usize` rank:
        // on 1..=10 ms it truncates 8.91 → index 8 and reports 9 ms.
        let mut h = LatencyHistogram::new();
        for v in 1..=10u64 {
            h.record(ms(v));
        }
        let p99 = h.quantile(0.99).unwrap();
        assert!(p99 > ms(9), "p99 {p99:?} truncated toward zero");
        assert!(p99 <= ms(10));
    }

    #[test]
    fn histogram_merge_matches_pooled_samples() {
        // Per-worker histograms merged == one histogram of all samples.
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut pooled = LatencyHistogram::new();
        for v in 0..50u64 {
            a.record(ms(v));
            pooled.record(ms(v));
        }
        for v in 50..=100u64 {
            b.record(ms(v));
            pooled.record(ms(v));
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), pooled.count());
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), pooled.quantile(q), "q={q}");
        }
        let s = merged.summary().unwrap();
        assert_eq!(s.count, 101);
        assert_eq!(s.p50, ms(50));
    }

    #[test]
    fn named_histograms_merge_by_key() {
        let mut a = NamedHistograms::new();
        a.entry("fp32").record(ms(10));
        a.entry("f16").record(ms(2));
        let mut b = NamedHistograms::new();
        b.entry("f16").record(ms(4));
        b.entry("bf16").record(ms(3));
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.get("fp32").unwrap().count(), 1);
        assert_eq!(a.get("f16").unwrap().count(), 2);
        assert_eq!(a.get("f16").unwrap().max(), Some(ms(4)));
        assert_eq!(a.get("bf16").unwrap().count(), 1);
        assert!(a.get("f64").is_none());
        assert_eq!(a.merged().count(), 4);
        // insertion order preserved
        let names: Vec<&str> = a.iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["fp32", "f16", "bf16"]);
    }

    #[test]
    fn capped_histogram_bounds_memory_and_keeps_exact_scalars() {
        let mut exact = LatencyHistogram::new();
        let mut capped = LatencyHistogram::with_sample_cap(64);
        for v in 1..=1000u64 {
            exact.record(ms(v));
            capped.record(ms(v));
        }
        // Memory bounded, counters exact.
        assert!(capped.retained() <= 64, "retained {}", capped.retained());
        assert_eq!(capped.count(), 1000);
        assert_eq!(capped.total(), exact.total());
        assert_eq!(capped.mean(), exact.mean());
        assert_eq!(capped.max(), Some(ms(1000)));
        let s = capped.summary().unwrap();
        assert_eq!(s.count, 1000);
        assert_eq!(s.max, ms(1000));
        // The stride subsample of a uniform ramp stays uniform: the
        // median lands near the true one.
        let p50 = capped.quantile(0.5).unwrap();
        assert!(
            p50 >= ms(400) && p50 <= ms(600),
            "capped p50 {p50:?} drifted"
        );
        // Deterministic: same observations, same retained samples.
        let mut again = LatencyHistogram::with_sample_cap(64);
        for v in 1..=1000u64 {
            again.record(ms(v));
        }
        assert_eq!(again.quantiles(&[0.25, 0.5, 0.99]), capped.quantiles(&[0.25, 0.5, 0.99]));
    }

    #[test]
    fn capped_histogram_count_is_monotone_across_compaction() {
        // `_count` is the completed-requests signal: it must never
        // move backwards when the reservoir compacts ("drains" half
        // its samples).
        let mut h = LatencyHistogram::with_sample_cap(8);
        let mut last = 0usize;
        for v in 1..=100u64 {
            h.record(ms(v));
            assert!(h.count() > last, "count regressed at {v}");
            last = h.count();
        }
        assert_eq!(last, 100);
        assert!(h.retained() <= 8);
        // And the exported `_count` line says the same.
        let mut set = NamedHistograms::new();
        set.entry("bulk").merge(&h);
        let mut text = String::new();
        set.to_prometheus("mpx_lat", &mut text);
        assert!(text.contains("mpx_lat_count{lane=\"bulk\"} 100"), "{text}");
    }

    #[test]
    fn capped_histogram_merge_stays_exact_and_bounded() {
        let mut a = LatencyHistogram::with_sample_cap(32);
        let mut b = LatencyHistogram::new();
        for v in 0..200u64 {
            a.record(ms(v));
            b.record(ms(v + 200));
        }
        a.merge(&b);
        assert_eq!(a.count(), 400);
        assert_eq!(a.max(), Some(ms(399)));
        assert!(a.retained() <= 32);
    }

    #[test]
    fn to_prometheus_escapes_label_values() {
        let mut set = NamedHistograms::new();
        set.entry("weird\"lane\\name\nx").record(ms(5));
        let mut text = String::new();
        set.to_prometheus("mpx_lat", &mut text);
        assert!(
            text.contains("lane=\"weird\\\"lane\\\\name\\nx\""),
            "unescaped label in: {text}"
        );
        // No raw newline inside any sample line.
        for line in text.lines() {
            assert!(!line.contains("weird\"lane"), "raw quote: {line}");
        }
        assert_eq!(prom_escape("plain"), "plain");
        assert_eq!(prom_escape("a\\b"), "a\\\\b");
        assert_eq!(prom_escape("a\"b"), "a\\\"b");
        assert_eq!(prom_escape("a\nb"), "a\\nb");
    }

    #[test]
    fn histogram_empty_is_none() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), None);
        assert!(h.summary().is_none());
        assert!(h.mean().is_none());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn train_prometheus_exports_per_group_series() {
        let mut m = RunMetrics::new();
        m.record(rec(1, 2.0, 1)).unwrap();
        m.record(StepRecord { grads_finite: false, ..rec(2, 2.0, 1) })
            .unwrap();
        let rows = vec![
            ("blocks[0]".to_string(), 32768.0f32, 3u64),
            ("pos_embed".to_string(), 65536.0, 0),
        ];
        let text = train_prometheus(&m, &rows);
        assert!(text.contains("mpx_train_steps_total 2"), "{text}");
        assert!(text.contains("mpx_train_steps_skipped_total 1"), "{text}");
        assert!(
            text.contains(
                "mpx_train_loss_scale{group=\"blocks[0]\"} 32768"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "mpx_train_loss_scale{group=\"pos_embed\"} 65536"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "mpx_train_skipped_steps_total{group=\"blocks[0]\"} 3"
            ),
            "{text}"
        );
        // One HELP/TYPE header per family, not per series.
        assert_eq!(
            text.matches("# TYPE mpx_train_loss_scale gauge").count(),
            1,
            "{text}"
        );
    }

    #[test]
    fn train_prometheus_escapes_group_labels() {
        let m = RunMetrics::new();
        let rows = vec![("odd\"group\\x".to_string(), 1.0f32, 0u64)];
        let text = train_prometheus(&m, &rows);
        assert!(
            text.contains("group=\"odd\\\"group\\\\x\""),
            "unescaped label in: {text}"
        );
    }

    #[test]
    fn skipped_counter() {
        let mut m = RunMetrics::new();
        m.record(StepRecord {
            grads_finite: false,
            ..rec(0, 1.0, 1)
        })
        .unwrap();
        m.record(rec(1, 1.0, 1)).unwrap();
        assert_eq!(m.skipped_steps(), 1);
    }
}
