//! Run metrics: step timing, loss history, scaling trace, writers.

use std::io::Write;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

/// Exponential moving average (smoothing for console logs).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// One training step's record.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub grads_finite: bool,
    pub loss_scale: f32,
    pub step_time: Duration,
}

/// In-memory run history + optional CSV sink.
pub struct RunMetrics {
    pub records: Vec<StepRecord>,
    started: Instant,
    csv: Option<std::fs::File>,
}

impl RunMetrics {
    pub fn new() -> RunMetrics {
        RunMetrics { records: Vec::new(), started: Instant::now(), csv: None }
    }

    /// Also stream records to a CSV file.
    pub fn with_csv(path: &str) -> Result<RunMetrics> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create metrics csv {path}"))?;
        writeln!(f, "step,loss,grads_finite,loss_scale,step_ms")?;
        Ok(RunMetrics {
            records: Vec::new(),
            started: Instant::now(),
            csv: Some(f),
        })
    }

    pub fn record(&mut self, r: StepRecord) -> Result<()> {
        if let Some(f) = &mut self.csv {
            writeln!(
                f,
                "{},{},{},{},{:.3}",
                r.step,
                r.loss,
                r.grads_finite as u8,
                r.loss_scale,
                r.step_time.as_secs_f64() * 1e3
            )?;
        }
        self.records.push(r);
        Ok(())
    }

    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Mean step time over the records after `skip` warmup steps.
    pub fn mean_step_time(&self, skip: usize) -> Option<Duration> {
        let xs: Vec<Duration> = self
            .records
            .iter()
            .skip(skip)
            .map(|r| r.step_time)
            .collect();
        if xs.is_empty() {
            return None;
        }
        Some(xs.iter().sum::<Duration>() / xs.len() as u32)
    }

    /// Mean loss over the last `n` records.
    pub fn recent_loss(&self, n: usize) -> Option<f32> {
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        if tail.is_empty() {
            return None;
        }
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    pub fn skipped_steps(&self) -> usize {
        self.records.iter().filter(|r| !r.grads_finite).count()
    }
}

impl Default for RunMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, ms: u64) -> StepRecord {
        StepRecord {
            step,
            loss,
            grads_finite: true,
            loss_scale: 1.0,
            step_time: Duration::from_millis(ms),
        }
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(4.0), 4.0);
        assert_eq!(e.push(0.0), 2.0);
        assert_eq!(e.push(0.0), 1.0);
    }

    #[test]
    fn mean_step_time_skips_warmup() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 1.0, 1000)).unwrap(); // compile-warmed first step
        m.record(rec(1, 1.0, 10)).unwrap();
        m.record(rec(2, 1.0, 20)).unwrap();
        assert_eq!(m.mean_step_time(1), Some(Duration::from_millis(15)));
    }

    #[test]
    fn recent_loss_window() {
        let mut m = RunMetrics::new();
        for i in 0..10 {
            m.record(rec(i, i as f32, 1)).unwrap();
        }
        assert_eq!(m.recent_loss(2), Some(8.5));
        assert_eq!(m.recent_loss(100), Some(4.5));
    }

    #[test]
    fn csv_written() {
        let path = std::env::temp_dir().join("mpx_metrics_test.csv");
        let path = path.to_str().unwrap();
        {
            let mut m = RunMetrics::with_csv(path).unwrap();
            m.record(rec(0, 0.5, 3)).unwrap();
        }
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("step,loss"));
        assert!(text.contains("0,0.5,1,1,3.000"));
    }

    #[test]
    fn skipped_counter() {
        let mut m = RunMetrics::new();
        m.record(StepRecord {
            grads_finite: false,
            ..rec(0, 1.0, 1)
        })
        .unwrap();
        m.record(rec(1, 1.0, 1)).unwrap();
        assert_eq!(m.skipped_steps(), 1);
    }
}
