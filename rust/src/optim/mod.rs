//! Rust-side optimizers over flat f32 master weights.
//!
//! The data-parallel trainer owns the optimizer (the `grads_*`
//! artifacts return gradients only), mirroring a multi-GPU MPX
//! deployment where the update is replicated host logic.  Math is
//! identical to `python/mpx/optim.py` — AdamW with bias correction and
//! decoupled weight decay — and is cross-checked against the fused
//! (in-graph) optimizer by the data-parallel equivalence test.

/// Hyper-parameters matching `python/compile/trainstep.py`.
#[derive(Debug, Clone, Copy)]
pub struct AdamWConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 1e-4,
        }
    }
}

/// AdamW over a list of flat parameter tensors.
pub struct AdamW {
    cfg: AdamWConfig,
    step: u64,
    mu: Vec<Vec<f32>>,
    nu: Vec<Vec<f32>>,
}

impl AdamW {
    /// `sizes[i]` is the element count of parameter tensor `i`.
    pub fn new(cfg: AdamWConfig, sizes: &[usize]) -> AdamW {
        AdamW {
            cfg,
            step: 0,
            mu: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            nu: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// The persistent state — `(step, first moments, second moments)`
    /// — for checkpointing.  Together with the master weights this is
    /// everything a resumed run needs to continue bit-identically.
    pub fn state(&self) -> (u64, &[Vec<f32>], &[Vec<f32>]) {
        (self.step, &self.mu, &self.nu)
    }

    /// Restore a checkpointed state; shapes must match the sizes the
    /// optimizer was constructed with.
    pub fn set_state(
        &mut self,
        step: u64,
        mu: Vec<Vec<f32>>,
        nu: Vec<Vec<f32>>,
    ) {
        assert_eq!(mu.len(), self.mu.len(), "moment arity");
        assert_eq!(nu.len(), self.nu.len(), "moment arity");
        for (new, old) in mu.iter().zip(&self.mu) {
            assert_eq!(new.len(), old.len(), "moment shape");
        }
        for (new, old) in nu.iter().zip(&self.nu) {
            assert_eq!(new.len(), old.len(), "moment shape");
        }
        self.step = step;
        self.mu = mu;
        self.nu = nu;
    }

    /// One update: `params[i] -= lr · (m̂/(√v̂+ε) + wd·p)`.
    ///
    /// Skipping a step (non-finite grads) simply means *not calling*
    /// `update` — matching `mpx.optimizer_update`'s semantics where
    /// neither parameters nor moments advance.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), self.mu.len(), "param arity");
        assert_eq!(grads.len(), self.mu.len(), "grad arity");
        self.step += 1;
        let c = &self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.step as i32);
        let bc2 = 1.0 - c.beta2.powi(self.step as i32);

        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.mu.iter_mut().zip(self.nu.iter_mut()))
        {
            debug_assert_eq!(p.len(), g.len());
            for i in 0..p.len() {
                let gi = g[i];
                m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * gi;
                v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * gi * gi;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                let step = mhat / (vhat.sqrt() + c.eps)
                    + c.weight_decay * p[i];
                p[i] -= c.lr * step;
            }
        }
    }
}

/// Plain SGD (with optional momentum) — the lighter baseline.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, sizes: &[usize]) -> Sgd {
        Sgd {
            lr,
            momentum,
            velocity: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        for ((p, g), v) in
            params.iter_mut().zip(grads).zip(self.velocity.iter_mut())
        {
            for i in 0..p.len() {
                v[i] = self.momentum * v[i] + g[i];
                p[i] -= self.lr * v[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_first_step_is_signlike() {
        // With bias correction, step 1 ≈ -lr·sign(g) for wd=0.
        let cfg = AdamWConfig { weight_decay: 0.0, ..Default::default() };
        let mut opt = AdamW::new(cfg, &[1]);
        let mut p = vec![vec![0.0f32]];
        opt.update(&mut p, &[vec![1e-4]]);
        assert!((p[0][0] + cfg.lr).abs() < 1e-6, "{}", p[0][0]);
    }

    #[test]
    fn adamw_converges_quadratic() {
        let cfg = AdamWConfig {
            lr: 0.05,
            weight_decay: 0.0,
            ..Default::default()
        };
        let mut opt = AdamW::new(cfg, &[1]);
        let mut p = vec![vec![5.0f32]];
        for _ in 0..500 {
            let g = vec![vec![2.0 * p[0][0]]];
            opt.update(&mut p, &g);
        }
        assert!(p[0][0].abs() < 0.05, "{}", p[0][0]);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let cfg = AdamWConfig {
            lr: 0.1,
            weight_decay: 0.5,
            ..Default::default()
        };
        let mut opt = AdamW::new(cfg, &[1]);
        let mut p = vec![vec![10.0f32]];
        opt.update(&mut p, &[vec![0.0]]);
        assert!(p[0][0] < 10.0);
    }

    #[test]
    fn skipping_preserves_moments() {
        // not calling update ⇒ step counter & moments unchanged
        let mut opt = AdamW::new(AdamWConfig::default(), &[2]);
        let mut p = vec![vec![1.0f32, 2.0]];
        opt.update(&mut p, &[vec![0.1, 0.1]]);
        let step_before = opt.step_count();
        // "skip" — nothing to call; verify counter semantics
        assert_eq!(step_before, 1);
    }

    #[test]
    fn matches_python_adamw_trace() {
        // Fixed trace cross-checked against python/mpx/optim.py:
        // p0=1.0, g=0.5 for 3 steps, lr=0.1, wd=0 →
        // python: 0.9000000 0.8000249 0.7001293 (approx)
        let cfg = AdamWConfig {
            lr: 0.1,
            weight_decay: 0.0,
            ..Default::default()
        };
        let mut opt = AdamW::new(cfg, &[1]);
        let mut p = vec![vec![1.0f32]];
        let mut seen = Vec::new();
        for _ in 0..3 {
            opt.update(&mut p, &[vec![0.5]]);
            seen.push(p[0][0]);
        }
        assert!((seen[0] - 0.9).abs() < 1e-4, "{seen:?}");
        assert!((seen[1] - 0.8).abs() < 1e-3, "{seen:?}");
        assert!((seen[2] - 0.7).abs() < 2e-3, "{seen:?}");
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut opt = Sgd::new(1.0, 0.5, &[1]);
        let mut p = vec![vec![0.0f32]];
        opt.update(&mut p, &[vec![1.0]]); // v=1, p=-1
        opt.update(&mut p, &[vec![1.0]]); // v=1.5, p=-2.5
        assert!((p[0][0] + 2.5).abs() < 1e-6);
    }
}
