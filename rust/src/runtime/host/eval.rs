//! Graph evaluator for the host backend: typed host tensors plus the
//! per-op kernels. Numerics contract is documented on [`super`] —
//! f16/bf16 elementwise math rounds through the RTNE cast lanes,
//! integer ops are bit-exact, `dot`/`reduce` accumulate in f32 in a
//! fixed (row-major) order.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::hlo::graph::GShape;
use crate::hostkernel::cast;
use crate::hostkernel::BufferPool;
use crate::pytree::DType;
use crate::runtime::value::{as_bytes, Value};

use super::{
    BOp, CmpDir, Comp, ConvCfg, GatherCfg, HostExecutable, Node, Op,
    ScatterCfg, UOp,
};

/// Typed element storage. f16/bf16 keep their native 16-bit words;
/// math on them goes through f32 with one final RTNE rounding.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Data {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Bf16(Vec<u16>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    Pred(Vec<u8>),
}

impl Data {
    pub(crate) fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::F16(v) | Data::Bf16(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::I8(v) => v.len(),
            Data::U8(v) | Data::Pred(v) => v.len(),
        }
    }
}

/// One evaluated tensor: dtype + dims + typed storage.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Tensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub(crate) fn elems(&self) -> usize {
        nelems(&self.dims)
    }

    /// Decode a [`Value`]'s native bytes into typed storage.
    pub(crate) fn from_value(v: &Value) -> Result<Tensor> {
        let b = v.bytes();
        let data = match v.dtype() {
            DType::F32 => Data::F32(
                b.chunks_exact(4)
                    .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::F16 => Data::F16(words16(b)),
            DType::Bf16 => Data::Bf16(words16(b)),
            DType::S32 => Data::I32(
                b.chunks_exact(4)
                    .map(|c| i32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::U32 => Data::U32(
                b.chunks_exact(4)
                    .map(|c| u32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::S8 => Data::I8(b.iter().map(|&x| x as i8).collect()),
            DType::U8 => Data::U8(b.to_vec()),
            DType::Pred => Data::Pred(b.to_vec()),
        };
        Ok(Tensor { dtype: v.dtype(), dims: v.shape().to_vec(), data })
    }

    /// Encode back to a [`Value`] (validates element count).
    pub(crate) fn to_value(&self) -> Result<Value> {
        let bytes = match &self.data {
            Data::F32(v) => as_bytes(v).to_vec(),
            Data::F16(v) | Data::Bf16(v) => as_bytes(v).to_vec(),
            Data::I32(v) => as_bytes(v).to_vec(),
            Data::U32(v) => as_bytes(v).to_vec(),
            Data::I8(v) => as_bytes(v).to_vec(),
            Data::U8(v) | Data::Pred(v) => v.clone(),
        };
        Value::new(self.dtype, self.dims.clone(), bytes)
    }

    fn scalar_i64(&self) -> Result<i64> {
        match &self.data {
            Data::I32(v) => Ok(v[0] as i64),
            Data::U32(v) => Ok(v[0] as i64),
            Data::I8(v) => Ok(v[0] as i64),
            Data::U8(v) | Data::Pred(v) => Ok(v[0] as i64),
            _ => bail!("expected integer scalar, got {}", self.dtype.name()),
        }
    }

    fn scalar_pred(&self) -> Result<bool> {
        match &self.data {
            Data::Pred(v) => Ok(v[0] != 0),
            _ => bail!("expected pred scalar, got {}", self.dtype.name()),
        }
    }
}

fn words16(b: &[u8]) -> Vec<u16> {
    b.chunks_exact(2).map(|c| u16::from_ne_bytes([c[0], c[1]])).collect()
}

/// Evaluation value: a tensor or a tuple (while-loop state, roots).
#[derive(Debug, Clone)]
pub(crate) enum Val {
    T(Rc<Tensor>),
    Tup(Vec<Val>),
}

fn tt(v: &Val) -> Result<&Tensor> {
    match v {
        Val::T(t) => Ok(t),
        Val::Tup(_) => bail!("expected array value, got tuple"),
    }
}

fn nelems(dims: &[usize]) -> usize {
    dims.iter().product::<usize>().max(1)
}

fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for d in (0..dims.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * dims[d + 1];
    }
    s
}

/// Advance a row-major multi-index (last dim fastest).
fn advance(idx: &mut [usize], dims: &[usize]) {
    for d in (0..dims.len()).rev() {
        idx[d] += 1;
        if idx[d] < dims[d] {
            return;
        }
        idx[d] = 0;
    }
}

/// All Σ idxᵢ·strideᵢ offsets for the given dim subset, enumerated
/// row-major over the subset order. Precomputing these turns
/// dot-general's index arithmetic into three table lookups.
fn subset_offsets(
    dims: &[usize],
    strides: &[usize],
    subset: &[usize],
) -> Vec<usize> {
    let mut out = vec![0usize];
    for &d in subset {
        let mut next = Vec::with_capacity(out.len() * dims[d]);
        for &base in &out {
            for i in 0..dims[d] {
                next.push(base + i * strides[d]);
            }
        }
        out = next;
    }
    out
}

/// Parse a `constant(...)` payload for the given shape.
pub(crate) fn parse_constant(
    shape: &GShape,
    payload: Option<&str>,
) -> Result<Tensor> {
    let dtype = shape.dtype()?;
    let dims = shape.dims()?.to_vec();
    let n = nelems(&dims);
    let raw = payload.context("constant without payload")?;
    let cleaned: String = raw
        .chars()
        .map(|c| if c == '{' || c == '}' { ' ' } else { c })
        .collect();
    let toks: Vec<&str> = cleaned
        .split(',')
        .flat_map(|s| s.split_whitespace())
        .collect();
    if toks.len() != n {
        bail!(
            "constant {}: {} elems declared, {} literals in payload",
            shape.print(),
            n,
            toks.len()
        );
    }
    let fparse = |t: &str| -> Result<f32> {
        t.parse::<f32>().with_context(|| format!("float literal {t:?}"))
    };
    let data = match dtype {
        DType::F32 => Data::F32(
            toks.iter().map(|t| fparse(t)).collect::<Result<_>>()?,
        ),
        DType::F16 => Data::F16(
            toks.iter()
                .map(|t| fparse(t).map(|x| cast::f16_lane(x.to_bits())))
                .collect::<Result<_>>()?,
        ),
        DType::Bf16 => Data::Bf16(
            toks.iter()
                .map(|t| fparse(t).map(|x| cast::bf16_lane(x.to_bits())))
                .collect::<Result<_>>()?,
        ),
        DType::S32 => Data::I32(
            toks.iter()
                .map(|t| t.parse::<i32>().context("s32 literal"))
                .collect::<Result<_>>()?,
        ),
        DType::U32 => Data::U32(
            toks.iter()
                .map(|t| t.parse::<u32>().context("u32 literal"))
                .collect::<Result<_>>()?,
        ),
        DType::S8 => Data::I8(
            toks.iter()
                .map(|t| t.parse::<i8>().context("s8 literal"))
                .collect::<Result<_>>()?,
        ),
        DType::U8 => Data::U8(
            toks.iter()
                .map(|t| t.parse::<u8>().context("u8 literal"))
                .collect::<Result<_>>()?,
        ),
        DType::Pred => Data::Pred(
            toks.iter()
                .map(|t| match *t {
                    "true" => Ok(1u8),
                    "false" => Ok(0u8),
                    other => other.parse::<u8>().context("pred literal"),
                })
                .collect::<Result<_>>()?,
        ),
    };
    Ok(Tensor { dtype, dims, data })
}

/// View a float tensor as f32 (f16/bf16 widen exactly).
fn to_f32_vec(t: &Tensor) -> Result<Vec<f32>> {
    match &t.data {
        Data::F32(v) => Ok(v.clone()),
        Data::F16(v) => {
            let mut out = vec![0f32; v.len()];
            cast::f16_to_f32_slice(v, &mut out);
            Ok(out)
        }
        Data::Bf16(v) => {
            let mut out = vec![0f32; v.len()];
            cast::bf16_to_f32_slice(v, &mut out);
            Ok(out)
        }
        _ => bail!("expected float tensor, got {}", t.dtype.name()),
    }
}

/// Round an f32 buffer back to the given float dtype (RTNE for
/// f16/bf16 — the single rounding step of the numerics contract).
fn from_f32(dtype: DType, dims: Vec<usize>, v: Vec<f32>) -> Result<Tensor> {
    let data = match dtype {
        DType::F32 => Data::F32(v),
        DType::F16 => {
            let mut out = vec![0u16; v.len()];
            cast::f32_to_f16_slice(&v, &mut out);
            Data::F16(out)
        }
        DType::Bf16 => {
            let mut out = vec![0u16; v.len()];
            cast::f32_to_bf16_slice(&v, &mut out);
            Data::Bf16(out)
        }
        _ => bail!("float op cannot produce {}", dtype.name()),
    };
    Ok(Tensor { dtype, dims, data })
}

fn to_i64_vec(t: &Tensor) -> Result<Vec<i64>> {
    match &t.data {
        Data::I32(v) => Ok(v.iter().map(|&x| x as i64).collect()),
        Data::U32(v) => Ok(v.iter().map(|&x| x as i64).collect()),
        Data::I8(v) => Ok(v.iter().map(|&x| x as i64).collect()),
        Data::U8(v) | Data::Pred(v) => {
            Ok(v.iter().map(|&x| x as i64).collect())
        }
        _ => bail!("expected integer tensor, got {}", t.dtype.name()),
    }
}

/// Extract element `lin` as a scalar tensor (generic-reduce path).
fn scalar_at(t: &Tensor, lin: usize) -> Tensor {
    let data = match &t.data {
        Data::F32(v) => Data::F32(vec![v[lin]]),
        Data::F16(v) => Data::F16(vec![v[lin]]),
        Data::Bf16(v) => Data::Bf16(vec![v[lin]]),
        Data::I32(v) => Data::I32(vec![v[lin]]),
        Data::U32(v) => Data::U32(vec![v[lin]]),
        Data::I8(v) => Data::I8(vec![v[lin]]),
        Data::U8(v) => Data::U8(vec![v[lin]]),
        Data::Pred(v) => Data::Pred(vec![v[lin]]),
    };
    Tensor { dtype: t.dtype, dims: Vec::new(), data }
}

/// Gather `src` elements into a new tensor of shape `odims`: `map`
/// turns each output multi-index into a source linear index (`None` →
/// the `pad` scalar). One routine implements broadcast / transpose /
/// slice / pad / dynamic-slice / gather.
fn remap(
    src: &Tensor,
    odims: &[usize],
    pad: Option<&Tensor>,
    mut map: impl FnMut(&[usize]) -> Option<usize>,
) -> Result<Tensor> {
    let out_elems = nelems(odims);
    macro_rules! go {
        ($var:ident, $s:ident) => {{
            let padv = match pad {
                None => None,
                Some(p) => match &p.data {
                    Data::$var(pv) => Some(pv[0]),
                    _ => bail!(
                        "pad value dtype {} != operand {}",
                        p.dtype.name(),
                        src.dtype.name()
                    ),
                },
            };
            let mut out = Vec::with_capacity(out_elems);
            let mut idx = vec![0usize; odims.len()];
            for _ in 0..out_elems {
                match map(&idx) {
                    Some(i) => out.push($s[i]),
                    None => out.push(
                        padv.context("index out of range without pad value")?,
                    ),
                }
                advance(&mut idx, odims);
            }
            Data::$var(out)
        }};
    }
    let data = match &src.data {
        Data::F32(s) => go!(F32, s),
        Data::F16(s) => go!(F16, s),
        Data::Bf16(s) => go!(Bf16, s),
        Data::I32(s) => go!(I32, s),
        Data::U32(s) => go!(U32, s),
        Data::I8(s) => go!(I8, s),
        Data::U8(s) => go!(U8, s),
        Data::Pred(s) => go!(Pred, s),
    };
    Ok(Tensor { dtype: src.dtype, dims: odims.to_vec(), data })
}

// ---- elementwise kernels -------------------------------------------------

fn compare_t(l: &Tensor, r: &Tensor, dir: CmpDir) -> Result<Tensor> {
    if l.data.len() != r.data.len() {
        bail!("compare: operand sizes differ");
    }
    macro_rules! cmp {
        ($a:expr, $b:expr) => {
            match dir {
                CmpDir::Eq => $a == $b,
                CmpDir::Ne => $a != $b,
                CmpDir::Ge => $a >= $b,
                CmpDir::Gt => $a > $b,
                CmpDir::Le => $a <= $b,
                CmpDir::Lt => $a < $b,
            }
        };
    }
    let out: Vec<u8> = if l.dtype.is_float() {
        let (a, b) = (to_f32_vec(l)?, to_f32_vec(r)?);
        a.iter().zip(&b).map(|(x, y)| cmp!(x, y) as u8).collect()
    } else {
        macro_rules! icmp {
            ($a:ident, $b:ident) => {
                $a.iter().zip($b).map(|(x, y)| cmp!(x, y) as u8).collect()
            };
        }
        match (&l.data, &r.data) {
            (Data::I32(a), Data::I32(b)) => icmp!(a, b),
            (Data::U32(a), Data::U32(b)) => icmp!(a, b),
            (Data::I8(a), Data::I8(b)) => icmp!(a, b),
            (Data::U8(a), Data::U8(b)) => icmp!(a, b),
            (Data::Pred(a), Data::Pred(b)) => icmp!(a, b),
            _ => bail!(
                "compare: dtype mismatch {} vs {}",
                l.dtype.name(),
                r.dtype.name()
            ),
        }
    };
    Ok(Tensor {
        dtype: DType::Pred,
        dims: l.dims.clone(),
        data: Data::Pred(out),
    })
}

fn select_t(p: &Tensor, t: &Tensor, f: &Tensor) -> Result<Tensor> {
    let preds = match &p.data {
        Data::Pred(v) => v,
        _ => bail!("select: predicate is {}", p.dtype.name()),
    };
    if t.data.len() != f.data.len() {
        bail!("select: branch sizes differ");
    }
    // scalar predicate picks a whole branch
    if preds.len() == 1 && t.data.len() != 1 {
        return Ok(if preds[0] != 0 { t.clone() } else { f.clone() });
    }
    if preds.len() != t.data.len() {
        bail!("select: predicate size differs from branches");
    }
    macro_rules! sel {
        ($var:ident, $a:ident, $b:ident) => {
            Data::$var(
                preds
                    .iter()
                    .zip($a.iter().zip($b))
                    .map(|(&p, (x, y))| if p != 0 { *x } else { *y })
                    .collect(),
            )
        };
    }
    let data = match (&t.data, &f.data) {
        (Data::F32(a), Data::F32(b)) => sel!(F32, a, b),
        (Data::F16(a), Data::F16(b)) => sel!(F16, a, b),
        (Data::Bf16(a), Data::Bf16(b)) => sel!(Bf16, a, b),
        (Data::I32(a), Data::I32(b)) => sel!(I32, a, b),
        (Data::U32(a), Data::U32(b)) => sel!(U32, a, b),
        (Data::I8(a), Data::I8(b)) => sel!(I8, a, b),
        (Data::U8(a), Data::U8(b)) => sel!(U8, a, b),
        (Data::Pred(a), Data::Pred(b)) => sel!(Pred, a, b),
        _ => bail!(
            "select: branch dtypes differ ({} vs {})",
            t.dtype.name(),
            f.dtype.name()
        ),
    };
    Ok(Tensor { dtype: t.dtype, dims: t.dims.clone(), data })
}

fn unary_t(u: UOp, x: &Tensor) -> Result<Tensor> {
    if x.dtype.is_float() {
        let f: fn(f32) -> f32 = match u {
            UOp::Neg => |a| -a,
            UOp::Abs => f32::abs,
            UOp::Exp => f32::exp,
            UOp::Log => f32::ln,
            UOp::Log1p => f32::ln_1p,
            UOp::Tanh => f32::tanh,
            UOp::Sqrt => f32::sqrt,
            UOp::Rsqrt => |a| 1.0 / a.sqrt(),
        };
        let v: Vec<f32> = to_f32_vec(x)?.into_iter().map(f).collect();
        return from_f32(x.dtype, x.dims.clone(), v);
    }
    let data = match (u, &x.data) {
        (UOp::Neg, Data::I32(v)) => {
            Data::I32(v.iter().map(|a| a.wrapping_neg()).collect())
        }
        (UOp::Abs, Data::I32(v)) => {
            Data::I32(v.iter().map(|a| a.wrapping_abs()).collect())
        }
        (UOp::Neg, Data::U32(v)) => {
            Data::U32(v.iter().map(|a| a.wrapping_neg()).collect())
        }
        (UOp::Abs, Data::U32(v)) => Data::U32(v.clone()),
        _ => bail!("unary {u:?} unsupported for {}", x.dtype.name()),
    };
    Ok(Tensor { dtype: x.dtype, dims: x.dims.clone(), data })
}

fn binary_t(op: BOp, l: &Tensor, r: &Tensor) -> Result<Tensor> {
    if l.data.len() != r.data.len() {
        bail!("binary {op:?}: operand sizes differ");
    }
    if l.dtype.is_float() {
        let f: fn(f32, f32) -> f32 = match op {
            BOp::Add => |a, b| a + b,
            BOp::Sub => |a, b| a - b,
            BOp::Mul => |a, b| a * b,
            BOp::Div => |a, b| a / b,
            BOp::Max => |a, b| {
                if a.is_nan() {
                    a
                } else if b.is_nan() {
                    b
                } else if a >= b {
                    a
                } else {
                    b
                }
            },
            BOp::Min => |a, b| {
                if a.is_nan() {
                    a
                } else if b.is_nan() {
                    b
                } else if a <= b {
                    a
                } else {
                    b
                }
            },
            BOp::Pow => f32::powf,
            _ => bail!("float {op:?} unsupported"),
        };
        let (a, b) = (to_f32_vec(l)?, to_f32_vec(r)?);
        let v: Vec<f32> =
            a.iter().zip(&b).map(|(x, y)| f(*x, *y)).collect();
        return from_f32(l.dtype, l.dims.clone(), v);
    }
    macro_rules! ibin {
        ($var:ident, $a:ident, $b:ident, $shr:expr) => {{
            let mut out = Vec::with_capacity($a.len());
            for (&x, &y) in $a.iter().zip($b) {
                out.push(match op {
                    BOp::Add => x.wrapping_add(y),
                    BOp::Sub => x.wrapping_sub(y),
                    BOp::Mul => x.wrapping_mul(y),
                    BOp::Div => x.checked_div(y).unwrap_or(0),
                    BOp::Max => x.max(y),
                    BOp::Min => x.min(y),
                    BOp::And => x & y,
                    BOp::Or => x | y,
                    BOp::Xor => x ^ y,
                    BOp::Shl => x.checked_shl(y as u32).unwrap_or(0),
                    BOp::Shr => $shr(x, y as u32),
                    BOp::Pow => bail!("integer power unsupported"),
                });
            }
            Data::$var(out)
        }};
    }
    let data = match (&l.data, &r.data) {
        (Data::I32(a), Data::I32(b)) => {
            ibin!(I32, a, b, |x: i32, s: u32| (x as u32)
                .checked_shr(s)
                .unwrap_or(0)
                as i32)
        }
        (Data::U32(a), Data::U32(b)) => {
            ibin!(U32, a, b, |x: u32, s: u32| x.checked_shr(s).unwrap_or(0))
        }
        (Data::I8(a), Data::I8(b)) => {
            ibin!(I8, a, b, |x: i8, s: u32| (x as u8)
                .checked_shr(s)
                .unwrap_or(0)
                as i8)
        }
        (Data::U8(a), Data::U8(b)) => {
            ibin!(U8, a, b, |x: u8, s: u32| x.checked_shr(s).unwrap_or(0))
        }
        (Data::Pred(a), Data::Pred(b)) => match op {
            BOp::And => {
                Data::Pred(a.iter().zip(b).map(|(x, y)| x & y).collect())
            }
            BOp::Or => {
                Data::Pred(a.iter().zip(b).map(|(x, y)| x | y).collect())
            }
            BOp::Xor => {
                Data::Pred(a.iter().zip(b).map(|(x, y)| x ^ y).collect())
            }
            _ => bail!("pred {op:?} unsupported"),
        },
        _ => bail!(
            "binary {op:?}: dtype mismatch {} vs {}",
            l.dtype.name(),
            r.dtype.name()
        ),
    };
    Ok(Tensor { dtype: l.dtype, dims: l.dims.clone(), data })
}

fn convert_t(x: &Tensor, dst: DType, dims: &[usize]) -> Result<Tensor> {
    if x.dtype.is_float() {
        let v = to_f32_vec(x)?;
        if dst.is_float() {
            return from_f32(dst, dims.to_vec(), v);
        }
        let data = match dst {
            DType::S32 => Data::I32(v.iter().map(|&a| a as i32).collect()),
            DType::U32 => Data::U32(v.iter().map(|&a| a as u32).collect()),
            DType::S8 => Data::I8(v.iter().map(|&a| a as i8).collect()),
            DType::U8 => Data::U8(v.iter().map(|&a| a as u8).collect()),
            DType::Pred => {
                Data::Pred(v.iter().map(|&a| (a != 0.0) as u8).collect())
            }
            _ => unreachable!("float dsts handled above"),
        };
        return Ok(Tensor { dtype: dst, dims: dims.to_vec(), data });
    }
    let v = to_i64_vec(x)?;
    let data = match dst {
        DType::F32 | DType::F16 | DType::Bf16 => {
            let f: Vec<f32> = v.iter().map(|&a| a as f32).collect();
            return from_f32(dst, dims.to_vec(), f);
        }
        DType::S32 => Data::I32(v.iter().map(|&a| a as i32).collect()),
        DType::U32 => Data::U32(v.iter().map(|&a| a as u32).collect()),
        DType::S8 => Data::I8(v.iter().map(|&a| a as i8).collect()),
        DType::U8 => Data::U8(v.iter().map(|&a| a as u8).collect()),
        DType::Pred => Data::Pred(v.iter().map(|&a| (a != 0) as u8).collect()),
    };
    Ok(Tensor { dtype: dst, dims: dims.to_vec(), data })
}

fn iota_t(dtype: DType, dims: &[usize], dim: usize) -> Result<Tensor> {
    let n = nelems(dims);
    let mut vals = Vec::with_capacity(n);
    let mut idx = vec![0usize; dims.len()];
    for _ in 0..n {
        vals.push(idx.get(dim).copied().unwrap_or(0));
        advance(&mut idx, dims);
    }
    let data = match dtype {
        DType::F32 | DType::F16 | DType::Bf16 => {
            let f: Vec<f32> = vals.iter().map(|&k| k as f32).collect();
            return from_f32(dtype, dims.to_vec(), f);
        }
        DType::S32 => Data::I32(vals.iter().map(|&k| k as i32).collect()),
        DType::U32 => Data::U32(vals.iter().map(|&k| k as u32).collect()),
        DType::S8 => Data::I8(vals.iter().map(|&k| k as i8).collect()),
        DType::U8 => Data::U8(vals.iter().map(|&k| k as u8).collect()),
        DType::Pred => bail!("iota over pred unsupported"),
    };
    Ok(Tensor { dtype, dims: dims.to_vec(), data })
}

// ---- data movement -------------------------------------------------------

/// Concatenate along `dim`. Byte-level slab copies — uniform over all
/// dtypes since storage is dense row-major.
fn concat_t(parts: &[&Tensor], dim: usize, odims: &[usize]) -> Result<Tensor> {
    let first = parts.first().context("concatenate with no operands")?;
    let dtype = first.dtype;
    let eb = dtype.bytes();
    let inner: usize = odims[dim + 1..].iter().product::<usize>().max(1);
    let outer: usize = odims[..dim].iter().product::<usize>().max(1);
    let mut part_bytes = Vec::with_capacity(parts.len());
    for t in parts {
        if t.dtype != dtype {
            bail!("concatenate: mixed dtypes");
        }
        part_bytes.push(t.to_value()?.into_bytes());
    }
    let mut out = Vec::with_capacity(nelems(odims) * eb);
    for o in 0..outer {
        for (t, b) in parts.iter().zip(&part_bytes) {
            let slab = t.dims[dim] * inner * eb;
            out.extend_from_slice(&b[o * slab..(o + 1) * slab]);
        }
    }
    Tensor::from_value(&Value::new(dtype, odims.to_vec(), out)?)
}

/// dynamic-update-slice: write `upd` into a copy of `base` at starts
/// clamped per XLA semantics (`0 ≤ s ≤ dim − upd_dim`).
fn dus_t(base: &Tensor, upd: &Tensor, starts: &[i64]) -> Result<Tensor> {
    let rank = base.dims.len();
    if starts.len() != rank || upd.dims.len() != rank {
        bail!("dynamic-update-slice: rank mismatch");
    }
    let start: Vec<usize> = (0..rank)
        .map(|d| {
            starts[d].clamp(0, (base.dims[d] - upd.dims[d]) as i64) as usize
        })
        .collect();
    let eb = base.dtype.bytes();
    let mut out = base.to_value()?.into_bytes();
    let ub = upd.to_value()?.into_bytes();
    let bstr = strides_of(&base.dims);
    let n = upd.elems();
    let mut idx = vec![0usize; rank];
    for e in 0..n {
        let lin: usize =
            (0..rank).map(|d| (start[d] + idx[d]) * bstr[d]).sum();
        out[lin * eb..(lin + 1) * eb]
            .copy_from_slice(&ub[e * eb..(e + 1) * eb]);
        advance(&mut idx, &upd.dims);
    }
    Tensor::from_value(&Value::new(base.dtype, base.dims.clone(), out)?)
}

// ---- contraction kernels -------------------------------------------------

/// dot-general: f32 accumulation, fixed k order. Output rows are split
/// across threads for large problems — parallelism never reorders any
/// element's reduction.
fn dot_t(
    l: &Tensor,
    r: &Tensor,
    lb: &[usize],
    lc: &[usize],
    rb: &[usize],
    rc: &[usize],
    out_dtype: DType,
    odims: &[usize],
) -> Result<Tensor> {
    let lv = to_f32_vec(l)?;
    let rv = to_f32_vec(r)?;
    let (ld, rd) = (&l.dims, &r.dims);
    let (ls, rs) = (strides_of(ld), strides_of(rd));
    for (i, (&a, &b)) in lb.iter().zip(rb).enumerate() {
        if ld[a] != rd[b] {
            bail!("dot: batch dim {i} sizes differ ({} vs {})", ld[a], rd[b]);
        }
    }
    let kl: usize = lc.iter().map(|&d| ld[d]).product::<usize>().max(1);
    let kr: usize = rc.iter().map(|&d| rd[d]).product::<usize>().max(1);
    if kl != kr {
        bail!("dot: contracting sizes differ ({kl} vs {kr})");
    }
    let lfree: Vec<usize> = (0..ld.len())
        .filter(|d| !lb.contains(d) && !lc.contains(d))
        .collect();
    let rfree: Vec<usize> = (0..rd.len())
        .filter(|d| !rb.contains(d) && !rc.contains(d))
        .collect();
    let lbo = subset_offsets(ld, &ls, lb);
    let lfo = subset_offsets(ld, &ls, &lfree);
    let lco = subset_offsets(ld, &ls, lc);
    let rbo = subset_offsets(rd, &rs, rb);
    let rfo = subset_offsets(rd, &rs, &rfree);
    let rco = subset_offsets(rd, &rs, rc);
    let (bsz, msz, nsz, ksz) = (lbo.len(), lfo.len(), rfo.len(), lco.len());
    if nelems(odims) != bsz * msz * nsz {
        bail!(
            "dot: output {:?} has {} elems, contraction wants {}",
            odims,
            nelems(odims),
            bsz * msz * nsz
        );
    }
    let mut out = vec![0f32; bsz * msz * nsz];
    let dot_row = |b: usize, m: usize, orow: &mut [f32]| {
        let (lbase, rbase) = (lbo[b] + lfo[m], rbo[b]);
        for (n, slot) in orow.iter_mut().enumerate() {
            let rb0 = rbase + rfo[n];
            let mut acc = 0f32;
            for k in 0..ksz {
                acc += lv[lbase + lco[k]] * rv[rb0 + rco[k]];
            }
            *slot = acc;
        }
    };
    let rows = bsz * msz;
    let work = rows * nsz * ksz;
    let threads = if work >= (1 << 22) {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(16)
            .min(rows)
    } else {
        1
    };
    if threads > 1 {
        let rows_per = rows.div_ceil(threads);
        std::thread::scope(|s| {
            for (ti, chunk) in out.chunks_mut(rows_per * nsz).enumerate() {
                let dot_row = &dot_row;
                s.spawn(move || {
                    for (ri, orow) in chunk.chunks_mut(nsz).enumerate() {
                        let row = ti * rows_per + ri;
                        dot_row(row / msz, row % msz, orow);
                    }
                });
            }
        });
    } else {
        for (row, orow) in out.chunks_mut(nsz).enumerate() {
            dot_row(row / msz, row % msz, orow);
        }
    }
    from_f32(out_dtype, odims.to_vec(), out)
}

/// Convolution via im2col + dot: patches matrix `[N·out_spatial,
/// window·Cin]` (staged in the global [`BufferPool`]) times the
/// flattened kernel `[window·Cin, Cout]`.
fn conv_t(
    cfg: &ConvCfg,
    l: &Tensor,
    r: &Tensor,
    out_dtype: DType,
    odims: &[usize],
) -> Result<Tensor> {
    let lv = to_f32_vec(l)?;
    let rv = to_f32_vec(r)?;
    let (ld, rd) = (&l.dims, &r.dims);
    let (ls, rs) = (strides_of(ld), strides_of(rd));
    let rank = cfg.window.len();
    let batch = ld[cfg.lhs.batch];
    let cin = ld[cfg.lhs.feature];
    let cout = rd[cfg.rhs.batch];
    if rd[cfg.rhs.feature] != cin {
        bail!(
            "convolution: kernel input features {} != lhs features {cin}",
            rd[cfg.rhs.feature]
        );
    }
    for (i, &w) in cfg.window.iter().enumerate() {
        if rd[cfg.rhs.spatial[i]] != w {
            bail!("convolution: window {i} size mismatch");
        }
    }
    let in_sp: Vec<usize> = cfg.lhs.spatial.iter().map(|&d| ld[d]).collect();
    let out_sp: Vec<usize> =
        cfg.out.spatial.iter().map(|&d| odims[d]).collect();
    if odims[cfg.out.batch] != batch || odims[cfg.out.feature] != cout {
        bail!("convolution: output batch/feature mismatch");
    }
    let wsize: usize = cfg.window.iter().product::<usize>().max(1);
    let osize: usize = out_sp.iter().product::<usize>().max(1);
    let rows = batch * osize;
    let cols = wsize * cin;

    let pool = BufferPool::global();
    let mut patches = pool.take_f32(rows * cols);
    patches.resize(rows * cols, 0.0);
    let mut oidx = vec![0usize; rank];
    let mut widx = vec![0usize; rank];
    for n in 0..batch {
        let nbase = n * ls[cfg.lhs.batch];
        for o in 0..osize {
            let row = (n * osize + o) * cols;
            for w in 0..wsize {
                // input coordinate per spatial dim; OOB cells stay 0
                let mut sbase = Some(nbase);
                for d in 0..rank {
                    let i = (oidx[d] * cfg.strides[d] + widx[d]) as i64
                        - cfg.pads[d].0;
                    if i < 0 || i >= in_sp[d] as i64 {
                        sbase = None;
                        break;
                    }
                    sbase =
                        sbase.map(|s| s + i as usize * ls[cfg.lhs.spatial[d]]);
                }
                if let Some(sbase) = sbase {
                    let fs = ls[cfg.lhs.feature];
                    for c in 0..cin {
                        patches[row + w * cin + c] = lv[sbase + c * fs];
                    }
                }
                advance(&mut widx, &cfg.window);
            }
            advance(&mut oidx, &out_sp);
        }
    }

    // kernel → [window·Cin, Cout]
    let mut kmat = pool.take_f32(cols * cout);
    kmat.resize(cols * cout, 0.0);
    let mut widx = vec![0usize; rank];
    for w in 0..wsize {
        let wbase: usize =
            (0..rank).map(|d| widx[d] * rs[cfg.rhs.spatial[d]]).sum();
        for c in 0..cin {
            let base = wbase + c * rs[cfg.rhs.feature];
            for co in 0..cout {
                kmat[(w * cin + c) * cout + co] =
                    rv[base + co * rs[cfg.rhs.batch]];
            }
        }
        advance(&mut widx, &cfg.window);
    }

    let mut omat = pool.take_f32(rows * cout);
    omat.resize(rows * cout, 0.0);
    for row in 0..rows {
        let p = &patches[row * cols..(row + 1) * cols];
        let orow = &mut omat[row * cout..(row + 1) * cout];
        for (k, &pv) in p.iter().enumerate() {
            if pv != 0.0 {
                let krow = &kmat[k * cout..(k + 1) * cout];
                for (slot, &kv) in orow.iter_mut().zip(krow) {
                    *slot += pv * kv;
                }
            }
        }
    }

    // scatter rows into the output layout
    let ostr = strides_of(odims);
    let mut out = vec![0f32; nelems(odims)];
    let mut oidx = vec![0usize; rank];
    for n in 0..batch {
        for o in 0..osize {
            let base: usize = n * ostr[cfg.out.batch]
                + (0..rank)
                    .map(|d| oidx[d] * ostr[cfg.out.spatial[d]])
                    .sum::<usize>();
            let row = (n * osize + o) * cout;
            for co in 0..cout {
                out[base + co * ostr[cfg.out.feature]] = omat[row + co];
            }
            advance(&mut oidx, &out_sp);
        }
    }
    pool.put_f32(patches);
    pool.put_f32(kmat);
    pool.put_f32(omat);
    from_f32(out_dtype, odims.to_vec(), out)
}

/// XLA gather (with operand/start-indices batching dims).
fn gather_t(
    cfg: &GatherCfg,
    operand: &Tensor,
    indices: &Tensor,
    odims: &[usize],
) -> Result<Tensor> {
    let ind = to_i64_vec(indices)?;
    let idims = &indices.dims;
    let istr = strides_of(idims);
    let opdims = &operand.dims;
    let opstr = strides_of(opdims);
    let irank = idims.len();
    let ivd = cfg.index_vector_dim;
    if cfg.slice_sizes.len() != opdims.len() {
        bail!("gather: slice_sizes rank mismatch");
    }
    // output batch dims ↔ indices dims (excluding ivd), in order
    let batch_out: Vec<usize> = (0..odims.len())
        .filter(|d| !cfg.offset_dims.contains(d))
        .collect();
    // offset output dims ↔ operand dims not collapsed/batching
    let offset_operand: Vec<usize> = (0..opdims.len())
        .filter(|d| {
            !cfg.collapsed_slice_dims.contains(d)
                && !cfg.operand_batching_dims.contains(d)
        })
        .collect();
    if offset_operand.len() != cfg.offset_dims.len() {
        bail!("gather: offset_dims rank mismatch");
    }
    let mut bc = vec![0usize; batch_out.len()];
    let mut iidx = vec![0usize; irank];
    let mut start = vec![0i64; opdims.len()];
    let map = move |oidx: &[usize]| -> Option<usize> {
        for (j, &d) in batch_out.iter().enumerate() {
            bc[j] = oidx[d];
        }
        start.iter_mut().for_each(|s| *s = 0);
        for (k, &d) in cfg.start_index_map.iter().enumerate() {
            // index into I: batch coords with ivd position = k
            let mut bpos = 0;
            for j in 0..irank {
                if j == ivd {
                    iidx[j] = k;
                } else {
                    iidx[j] = bc[bpos];
                    bpos += 1;
                }
            }
            let lin: usize =
                (0..irank).map(|j| iidx[j] * istr[j]).sum();
            let hi = (opdims[d] - cfg.slice_sizes[d]) as i64;
            start[d] = ind[lin].clamp(0, hi);
        }
        for (p, &d) in cfg.operand_batching_dims.iter().enumerate() {
            let j = cfg.start_indices_batching_dims[p];
            let pos = j - usize::from(ivd < irank && j > ivd);
            start[d] = bc[pos] as i64;
        }
        let mut lin = 0usize;
        for (q, &d) in offset_operand.iter().enumerate() {
            lin += (start[d] as usize + oidx[cfg.offset_dims[q]]) * opstr[d];
        }
        for &d in cfg
            .collapsed_slice_dims
            .iter()
            .chain(&cfg.operand_batching_dims)
        {
            lin += start[d] as usize * opstr[d];
        }
        Some(lin)
    };
    remap(operand, odims, None, map)
}

// ---- graph walk ----------------------------------------------------------

impl HostExecutable {
    pub(crate) fn eval_entry(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let args: Vec<Val> = inputs
            .iter()
            .map(|v| Tensor::from_value(v).map(|t| Val::T(Rc::new(t))))
            .collect::<Result<_>>()?;
        let root = self.eval_comp(self.entry, &args)?;
        match root {
            Val::Tup(parts) => {
                parts.into_iter().map(|p| tt(&p)?.to_value()).collect()
            }
            Val::T(t) => Ok(vec![t.to_value()?]),
        }
    }

    pub(crate) fn eval_comp(&self, ci: usize, args: &[Val]) -> Result<Val> {
        let comp = &self.comps[ci];
        if args.len() != comp.params.len() {
            bail!(
                "{}: called with {} args, wants {}",
                comp.name,
                args.len(),
                comp.params.len()
            );
        }
        let mut slots: Vec<Option<Val>> = vec![None; comp.nodes.len()];
        for i in 0..comp.nodes.len() {
            let v = self
                .eval_node(comp, i, &slots, args)
                .with_context(|| {
                    format!("{}: {}", comp.name, comp.nodes[i].name)
                })?;
            slots[i] = Some(v);
        }
        slots[comp.root].take().context("root not evaluated")
    }

    fn eval_node(
        &self,
        comp: &Comp,
        ni: usize,
        slots: &[Option<Val>],
        args: &[Val],
    ) -> Result<Val> {
        let node: &Node = &comp.nodes[ni];
        let arg = |k: usize| -> Result<&Val> {
            let &slot = node
                .args
                .get(k)
                .with_context(|| format!("missing operand {k}"))?;
            slots[slot].as_ref().context("operand evaluated out of order")
        };
        let ts = |k: usize| -> Result<&Tensor> { tt(arg(k)?) };
        let odims = || -> Result<&[usize]> { node.shape.dims() };
        let odt = || -> Result<DType> { node.shape.dtype() };
        let wrap = |t: Tensor| Ok(Val::T(Rc::new(t)));

        match &node.op {
            Op::Parameter(k) => Ok(args[*k].clone()),
            Op::Constant(t) => wrap(t.clone()),
            Op::Iota { dim } => wrap(iota_t(odt()?, odims()?, *dim)?),
            Op::Broadcast { dims } => {
                let src = ts(0)?;
                let ss = strides_of(&src.dims);
                let out = odims()?;
                wrap(remap(src, out, None, |idx| {
                    Some(
                        dims.iter()
                            .zip(&ss)
                            .map(|(&d, s)| idx[d] * s)
                            .sum(),
                    )
                })?)
            }
            Op::Reshape | Op::Copy => {
                let src = ts(0)?;
                let out = odims()?;
                if nelems(out) != src.elems() {
                    bail!("reshape: element count changes");
                }
                wrap(Tensor {
                    dtype: src.dtype,
                    dims: out.to_vec(),
                    data: src.data.clone(),
                })
            }
            Op::Transpose { perm } => {
                let src = ts(0)?;
                let ss = strides_of(&src.dims);
                wrap(remap(src, odims()?, None, |idx| {
                    Some(
                        idx.iter()
                            .zip(perm)
                            .map(|(&i, &p)| i * ss[p])
                            .sum(),
                    )
                })?)
            }
            Op::Slice { spec } => {
                let src = ts(0)?;
                let ss = strides_of(&src.dims);
                wrap(remap(src, odims()?, None, |idx| {
                    Some(
                        idx.iter()
                            .zip(spec)
                            .zip(&ss)
                            .map(|((&i, &(start, _, step)), s)| {
                                (start + i * step) * s
                            })
                            .sum(),
                    )
                })?)
            }
            Op::Concat { dim } => {
                let parts: Vec<&Tensor> = (0..node.args.len())
                    .map(ts)
                    .collect::<Result<_>>()?;
                wrap(concat_t(&parts, *dim, odims()?)?)
            }
            Op::Pad { cfg } => {
                let src = ts(0)?;
                let pad = ts(1)?;
                let ss = strides_of(&src.dims);
                let sdims = src.dims.clone();
                wrap(remap(src, odims()?, Some(pad), |idx| {
                    let mut lin = 0usize;
                    for (d, (&i, &(lo, _, interior))) in
                        idx.iter().zip(cfg).enumerate()
                    {
                        let mut pos = i as i64 - lo;
                        if pos < 0 {
                            return None;
                        }
                        if interior > 0 {
                            let step = interior as i64 + 1;
                            if pos % step != 0 {
                                return None;
                            }
                            pos /= step;
                        }
                        if pos >= sdims[d] as i64 {
                            return None;
                        }
                        lin += pos as usize * ss[d];
                    }
                    Some(lin)
                })?)
            }
            Op::Reduce { dims, comp } => {
                wrap(self.reduce_t(ts(0)?, ts(1)?, dims, *comp, odims()?)?)
            }
            Op::Dot { lb, lc, rb, rc } => wrap(dot_t(
                ts(0)?,
                ts(1)?,
                lb,
                lc,
                rb,
                rc,
                odt()?,
                odims()?,
            )?),
            Op::Conv(cfg) => {
                wrap(conv_t(cfg, ts(0)?, ts(1)?, odt()?, odims()?)?)
            }
            Op::Convert => wrap(convert_t(ts(0)?, odt()?, odims()?)?),
            Op::BitcastConvert => {
                let src = ts(0)?;
                let v = src.to_value()?;
                let nv = Value::new(odt()?, odims()?.to_vec(), v.into_bytes())
                    .context("bitcast-convert: byte width changes")?;
                wrap(Tensor::from_value(&nv)?)
            }
            Op::Compare(dir) => wrap(compare_t(ts(0)?, ts(1)?, *dir)?),
            Op::Select => wrap(select_t(ts(0)?, ts(1)?, ts(2)?)?),
            Op::IsFinite => {
                let v = to_f32_vec(ts(0)?)?;
                wrap(Tensor {
                    dtype: DType::Pred,
                    dims: odims()?.to_vec(),
                    data: Data::Pred(
                        v.iter().map(|a| a.is_finite() as u8).collect(),
                    ),
                })
            }
            Op::Unary(u) => wrap(unary_t(*u, ts(0)?)?),
            Op::Binary(b) => wrap(binary_t(*b, ts(0)?, ts(1)?)?),
            Op::Tuple => {
                let parts = (0..node.args.len())
                    .map(|k| arg(k).cloned())
                    .collect::<Result<Vec<_>>>()?;
                Ok(Val::Tup(parts))
            }
            Op::Gte(i) => match arg(0)? {
                Val::Tup(parts) => parts
                    .get(*i)
                    .cloned()
                    .with_context(|| format!("tuple has no element {i}")),
                Val::T(_) => bail!("get-tuple-element of array"),
            },
            Op::Call(ci) => {
                let cargs = (0..node.args.len())
                    .map(|k| arg(k).cloned())
                    .collect::<Result<Vec<_>>>()?;
                self.eval_comp(*ci, &cargs)
            }
            Op::While { cond, body } => {
                let mut state = arg(0)?.clone();
                loop {
                    let c =
                        self.eval_comp(*cond, std::slice::from_ref(&state))?;
                    if !tt(&c)?.scalar_pred()? {
                        return Ok(state);
                    }
                    state =
                        self.eval_comp(*body, std::slice::from_ref(&state))?;
                }
            }
            Op::Conditional { branches } => {
                let sel = ts(0)?;
                if sel.dtype == DType::Pred {
                    bail!("pred-form conditional unsupported (use s32 index)");
                }
                if node.args.len() != branches.len() + 1 {
                    bail!(
                        "conditional: {} operands for {} branches",
                        node.args.len(),
                        branches.len()
                    );
                }
                let i = sel
                    .scalar_i64()?
                    .clamp(0, branches.len() as i64 - 1)
                    as usize;
                let barg = arg(i + 1)?.clone();
                self.eval_comp(branches[i], std::slice::from_ref(&barg))
            }
            Op::DynamicSlice { sizes } => {
                let src = ts(0)?;
                let rank = src.dims.len();
                if node.args.len() != rank + 1 || sizes.len() != rank {
                    bail!("dynamic-slice: start operand count mismatch");
                }
                let start: Vec<usize> = (0..rank)
                    .map(|d| {
                        let s = ts(d + 1)?.scalar_i64()?;
                        Ok(s.clamp(0, (src.dims[d] - sizes[d]) as i64)
                            as usize)
                    })
                    .collect::<Result<_>>()?;
                let ss = strides_of(&src.dims);
                wrap(remap(src, odims()?, None, |idx| {
                    Some(
                        idx.iter()
                            .zip(&start)
                            .zip(&ss)
                            .map(|((&i, &s0), s)| (s0 + i) * s)
                            .sum(),
                    )
                })?)
            }
            Op::DynamicUpdateSlice => {
                let base = ts(0)?;
                let upd = ts(1)?;
                let rank = base.dims.len();
                if node.args.len() != rank + 2 {
                    bail!("dynamic-update-slice: start operand count");
                }
                let starts: Vec<i64> = (0..rank)
                    .map(|d| ts(d + 2)?.scalar_i64())
                    .collect::<Result<_>>()?;
                wrap(dus_t(base, upd, &starts)?)
            }
            Op::Gather(cfg) => {
                wrap(gather_t(cfg, ts(0)?, ts(1)?, odims()?)?)
            }
            Op::Scatter(cfg) => {
                wrap(self.scatter_t(cfg, ts(0)?, ts(1)?, ts(2)?)?)
            }
        }
    }

    /// If computation `ci` is `ROOT binary(p0, p1)`, return the fold op.
    fn match_fold(&self, ci: usize) -> Option<BOp> {
        let c = &self.comps[ci];
        if c.params.len() != 2 {
            return None;
        }
        let root = &c.nodes[c.root];
        if let Op::Binary(b) = root.op {
            let (p0, p1) = (c.params[0], c.params[1]);
            if root.args == [p0, p1] || root.args == [p1, p0] {
                return Some(b);
            }
        }
        None
    }

    /// Combiner that just returns the update (`ROOT = parameter(1)`).
    fn match_replace(&self, ci: usize) -> bool {
        let c = &self.comps[ci];
        matches!(c.nodes[c.root].op, Op::Parameter(1))
    }

    fn reduce_t(
        &self,
        src: &Tensor,
        init: &Tensor,
        rdims: &[usize],
        comp: usize,
        odims: &[usize],
    ) -> Result<Tensor> {
        let sstr_dims = &src.dims;
        // stride of each src dim in the *output* (0 when reduced)
        let ostr = strides_of(odims);
        let mut out_stride = vec![0usize; sstr_dims.len()];
        let mut oi = 0;
        for (d, slot) in out_stride.iter_mut().enumerate() {
            if !rdims.contains(&d) {
                *slot = ostr[oi];
                oi += 1;
            }
        }
        let out_elems = nelems(odims);
        let fold = self.match_fold(comp);

        if src.dtype.is_float() {
            if let Some(op) = fold {
                let f: fn(f32, f32) -> f32 = match op {
                    BOp::Add => |a, b| a + b,
                    BOp::Mul => |a, b| a * b,
                    BOp::Max => |a, b| {
                        if a.is_nan() || b.is_nan() {
                            f32::NAN
                        } else if a >= b {
                            a
                        } else {
                            b
                        }
                    },
                    BOp::Min => |a, b| {
                        if a.is_nan() || b.is_nan() {
                            f32::NAN
                        } else if a <= b {
                            a
                        } else {
                            b
                        }
                    },
                    _ => bail!("float reduce over {op:?} unsupported"),
                };
                let sv = to_f32_vec(src)?;
                let iv = to_f32_vec(init)?[0];
                let mut acc = vec![iv; out_elems];
                let mut idx = vec![0usize; sstr_dims.len()];
                for &x in &sv {
                    let o: usize = idx
                        .iter()
                        .zip(&out_stride)
                        .map(|(&i, &s)| i * s)
                        .sum();
                    acc[o] = f(acc[o], x);
                    advance(&mut idx, sstr_dims);
                }
                return from_f32(src.dtype, odims.to_vec(), acc);
            }
        } else if let (Data::Pred(sv), Data::Pred(iv), Some(op)) =
            (&src.data, &init.data, fold)
        {
            let f: fn(u8, u8) -> u8 = match op {
                BOp::And => |a, b| a & b,
                BOp::Or => |a, b| a | b,
                BOp::Xor => |a, b| a ^ b,
                _ => bail!("pred reduce over {op:?} unsupported"),
            };
            let mut acc = vec![iv[0]; out_elems];
            let mut idx = vec![0usize; sstr_dims.len()];
            for &x in sv {
                let o: usize = idx
                    .iter()
                    .zip(&out_stride)
                    .map(|(&i, &s)| i * s)
                    .sum();
                acc[o] = f(acc[o], x);
                advance(&mut idx, sstr_dims);
            }
            return Ok(Tensor {
                dtype: src.dtype,
                dims: odims.to_vec(),
                data: Data::Pred(acc),
            });
        }

        // generic fallback: run the region per element pair
        let init_s = scalar_at(init, 0);
        let mut acc: Vec<Tensor> = vec![init_s; out_elems];
        let mut idx = vec![0usize; sstr_dims.len()];
        for lin in 0..src.elems() {
            let o: usize = idx
                .iter()
                .zip(&out_stride)
                .map(|(&i, &s)| i * s)
                .sum();
            let l = Val::T(Rc::new(acc[o].clone()));
            let r = Val::T(Rc::new(scalar_at(src, lin)));
            let res = self.eval_comp(comp, &[l, r])?;
            acc[o] = tt(&res)?.clone();
            advance(&mut idx, sstr_dims);
        }
        let eb = src.dtype.bytes();
        let mut bytes = Vec::with_capacity(out_elems * eb);
        for t in &acc {
            bytes.extend_from_slice(t.to_value()?.bytes());
        }
        Tensor::from_value(&Value::new(src.dtype, odims.to_vec(), bytes)?)
    }

    /// XLA scatter (float operands; out-of-bounds updates dropped,
    /// updates applied in row-major order — deterministic).
    fn scatter_t(
        &self,
        cfg: &ScatterCfg,
        operand: &Tensor,
        indices: &Tensor,
        updates: &Tensor,
    ) -> Result<Tensor> {
        let mut acc = to_f32_vec(operand)?;
        let upd = to_f32_vec(updates)?;
        let ind = to_i64_vec(indices)?;
        let opdims = &operand.dims;
        let opstr = strides_of(opdims);
        let idims = &indices.dims;
        let istr = strides_of(idims);
        let irank = idims.len();
        let ivd = cfg.index_vector_dim;
        let udims = &updates.dims;

        // update dims not in update_window_dims = scatter (batch) dims
        let scatter_upd_dims: Vec<usize> = (0..udims.len())
            .filter(|d| !cfg.update_window_dims.contains(d))
            .collect();
        // window update dims ↔ operand dims not inserted/batching
        let window_operand: Vec<usize> = (0..opdims.len())
            .filter(|d| {
                !cfg.inserted_window_dims.contains(d)
                    && !cfg.input_batching_dims.contains(d)
            })
            .collect();
        if window_operand.len() != cfg.update_window_dims.len() {
            bail!("scatter: update_window_dims rank mismatch");
        }
        // window extent per operand dim (1 for inserted/batching)
        let mut ext = vec![1usize; opdims.len()];
        for (q, &d) in window_operand.iter().enumerate() {
            ext[d] = udims[cfg.update_window_dims[q]];
        }

        enum Comb {
            Fold(fn(f32, f32) -> f32),
            Replace,
            Region(usize),
        }
        let comb = if self.match_replace(cfg.comp) {
            Comb::Replace
        } else if let Some(op) = self.match_fold(cfg.comp) {
            Comb::Fold(match op {
                BOp::Add => |a, b| a + b,
                BOp::Mul => |a, b| a * b,
                BOp::Max => f32::max,
                BOp::Min => f32::min,
                _ => bail!("scatter combiner {op:?} unsupported"),
            })
        } else {
            Comb::Region(cfg.comp)
        };

        let mut uidx = vec![0usize; udims.len()];
        let mut iidx = vec![0usize; irank];
        let mut start = vec![0i64; opdims.len()];
        'updates: for (e, &uval) in upd.iter().enumerate() {
            let _ = e;
            // scatter coords → index into I (excluding ivd, in order)
            start.iter_mut().for_each(|s| *s = 0);
            for (k, &d) in
                cfg.scatter_dims_to_operand_dims.iter().enumerate()
            {
                let mut bpos = 0;
                for j in 0..irank {
                    if j == ivd {
                        iidx[j] = k;
                    } else {
                        iidx[j] = uidx[scatter_upd_dims[bpos]];
                        bpos += 1;
                    }
                }
                let lin: usize =
                    (0..irank).map(|j| iidx[j] * istr[j]).sum();
                let ik = ind[lin];
                if ik < 0 || ik + ext[d] as i64 > opdims[d] as i64 {
                    advance(&mut uidx, udims);
                    continue 'updates;
                }
                start[d] = ik;
            }
            for (p, &d) in cfg.input_batching_dims.iter().enumerate() {
                let j = cfg.scatter_indices_batching_dims[p];
                let pos = j - usize::from(ivd < irank && j > ivd);
                start[d] = uidx[scatter_upd_dims[pos]] as i64;
            }
            let mut lin = 0usize;
            for (q, &d) in window_operand.iter().enumerate() {
                lin += (start[d] as usize
                    + uidx[cfg.update_window_dims[q]])
                    * opstr[d];
            }
            for &d in cfg
                .inserted_window_dims
                .iter()
                .chain(&cfg.input_batching_dims)
            {
                lin += start[d] as usize * opstr[d];
            }
            match &comb {
                Comb::Replace => acc[lin] = uval,
                Comb::Fold(f) => acc[lin] = f(acc[lin], uval),
                Comb::Region(ci) => {
                    let l = Val::T(Rc::new(Tensor {
                        dtype: DType::F32,
                        dims: Vec::new(),
                        data: Data::F32(vec![acc[lin]]),
                    }));
                    let r = Val::T(Rc::new(Tensor {
                        dtype: DType::F32,
                        dims: Vec::new(),
                        data: Data::F32(vec![uval]),
                    }));
                    let res = self.eval_comp(*ci, &[l, r])?;
                    acc[lin] = match &tt(&res)?.data {
                        Data::F32(v) => v[0],
                        _ => bail!("scatter region must return f32"),
                    };
                }
            }
            advance(&mut uidx, udims);
        }
        from_f32(operand.dtype, opdims.clone(), acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_payloads() {
        let s = GShape::Array { dtype: DType::F32, dims: vec![] };
        let t = parse_constant(&s, Some("1e-05")).unwrap();
        assert_eq!(t.data, Data::F32(vec![1e-05]));
        let t = parse_constant(&s, Some("-inf")).unwrap();
        assert_eq!(t.data, Data::F32(vec![f32::NEG_INFINITY]));
        let s = GShape::Array { dtype: DType::S32, dims: vec![4] };
        let t = parse_constant(&s, Some("{13, 15, 26, 6}")).unwrap();
        assert_eq!(t.data, Data::I32(vec![13, 15, 26, 6]));
        let s = GShape::Array { dtype: DType::Pred, dims: vec![] };
        let t = parse_constant(&s, Some("true")).unwrap();
        assert_eq!(t.data, Data::Pred(vec![1]));
    }

    #[test]
    fn constant_arity_checked() {
        let s = GShape::Array { dtype: DType::F32, dims: vec![3] };
        assert!(parse_constant(&s, Some("{1, 2}")).is_err());
        assert!(parse_constant(&s, None).is_err());
    }

    #[test]
    fn remap_transpose() {
        let t = Tensor {
            dtype: DType::F32,
            dims: vec![2, 3],
            data: Data::F32(vec![0., 1., 2., 3., 4., 5.]),
        };
        let ss = strides_of(&t.dims);
        let out = remap(&t, &[3, 2], None, |idx| {
            Some(idx[0] * ss[1] + idx[1] * ss[0])
        })
        .unwrap();
        assert_eq!(out.data, Data::F32(vec![0., 3., 1., 4., 2., 5.]));
    }

    #[test]
    fn subset_offsets_enumerate_row_major() {
        let dims = [2, 3, 4];
        let s = strides_of(&dims);
        assert_eq!(s, vec![12, 4, 1]);
        let offs = subset_offsets(&dims, &s, &[0, 2]);
        assert_eq!(offs, vec![0, 1, 2, 3, 12, 13, 14, 15]);
    }

    #[test]
    fn binary_int_semantics() {
        let a = Tensor {
            dtype: DType::U32,
            dims: vec![2],
            data: Data::U32(vec![u32::MAX, 8]),
        };
        let b = Tensor {
            dtype: DType::U32,
            dims: vec![2],
            data: Data::U32(vec![1, 40]),
        };
        let add = binary_t(BOp::Add, &a, &b).unwrap();
        assert_eq!(add.data, Data::U32(vec![0, 48]));
        let shr = binary_t(BOp::Shr, &a, &b).unwrap();
        assert_eq!(shr.data, Data::U32(vec![u32::MAX >> 1, 0]));
    }
}


