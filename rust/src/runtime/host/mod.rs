//! Pure-Rust host interpreter backend.
//!
//! Compiles an artifact's HLO text (via the deep parser
//! [`crate::hlo::graph`]) into a lowered instruction graph and
//! evaluates it on plain host buffers — no native library, so every
//! artifact-gated suite runs under `--no-default-features`. The op
//! set covers everything the vit artifacts use (see the lowering
//! `match` below); an unknown opcode is rejected *at compile time*
//! with the opcode named.
//!
//! Numerics contract (what `backend_cross_check.rs` pins):
//!
//! * f16/bf16 elementwise math converts to f32, computes, and rounds
//!   back through the RTNE cast lanes in [`crate::hostkernel::cast`]
//!   — bit-identical to the scalar `numerics::F16`/`Bf16` reference,
//!   and exact vs XLA for single rounding steps (`convert` in
//!   particular is bit-exact).
//! * Integer / pred ops (the threefry path in init artifacts) are
//!   bit-exact: wrapping adds, shifts, xor.
//! * `dot` and `reduce` accumulate in f32 sequentially; XLA may use a
//!   different summation order, so float outputs agree only within a
//!   per-dtype tolerance (the cross-check's documented bound).
//!
//! Evaluation is deterministic: the only threaded kernel (`dot`)
//! splits *output rows* across threads, which never changes any
//! element's reduction order.

mod eval;
#[cfg(test)]
mod golden;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::hlo::graph::{GComputation, GShape, HloProgram};
use crate::pytree::DType;
use crate::runtime::value::Value;
use crate::runtime::{Backend, Executable};

pub(crate) use eval::{Data, Tensor};

/// The host interpreter backend (stateless — compilation produces a
/// self-contained [`HostExecutable`]).
pub struct HostBackend;

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn compile_hlo_file(&self, path: &Path) -> Result<Box<dyn Executable>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read HLO text {}", path.display()))?;
        let exe = HostExecutable::compile(&text)
            .with_context(|| format!("host-compile {}", path.display()))?;
        Ok(Box::new(exe))
    }
}

/// Comparison directions HLO prints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CmpDir {
    Eq,
    Ne,
    Ge,
    Gt,
    Le,
    Lt,
}

/// Unary elementwise ops.
#[derive(Debug, Clone, Copy)]
pub(crate) enum UOp {
    Neg,
    Abs,
    Exp,
    Log,
    Log1p,
    Tanh,
    Sqrt,
    Rsqrt,
}

/// Binary elementwise ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

#[derive(Debug, Clone)]
pub(crate) struct GatherCfg {
    pub offset_dims: Vec<usize>,
    pub collapsed_slice_dims: Vec<usize>,
    pub operand_batching_dims: Vec<usize>,
    pub start_indices_batching_dims: Vec<usize>,
    pub start_index_map: Vec<usize>,
    pub index_vector_dim: usize,
    pub slice_sizes: Vec<usize>,
}

#[derive(Debug, Clone)]
pub(crate) struct ScatterCfg {
    pub update_window_dims: Vec<usize>,
    pub inserted_window_dims: Vec<usize>,
    pub scatter_dims_to_operand_dims: Vec<usize>,
    pub input_batching_dims: Vec<usize>,
    pub scatter_indices_batching_dims: Vec<usize>,
    pub index_vector_dim: usize,
    pub comp: usize,
}

/// Positions of the batch/feature/spatial dims in one conv operand.
#[derive(Debug, Clone)]
pub(crate) struct ConvDimSpec {
    pub batch: usize,
    pub feature: usize,
    pub spatial: Vec<usize>,
}

#[derive(Debug, Clone)]
pub(crate) struct ConvCfg {
    pub window: Vec<usize>,
    pub strides: Vec<usize>,
    pub pads: Vec<(i64, i64)>,
    pub lhs: ConvDimSpec,
    pub rhs: ConvDimSpec, // batch = output-feature, feature = input-feature
    pub out: ConvDimSpec,
}

/// One lowered instruction.
#[derive(Debug, Clone)]
pub(crate) enum Op {
    Parameter(usize),
    Constant(Tensor),
    Iota { dim: usize },
    Broadcast { dims: Vec<usize> },
    Reshape,
    Copy,
    Transpose { perm: Vec<usize> },
    Slice { spec: Vec<(usize, usize, usize)> },
    Concat { dim: usize },
    Pad { cfg: Vec<(i64, i64, usize)> },
    Reduce { dims: Vec<usize>, comp: usize },
    Dot { lb: Vec<usize>, lc: Vec<usize>, rb: Vec<usize>, rc: Vec<usize> },
    Conv(Box<ConvCfg>),
    Convert,
    BitcastConvert,
    Compare(CmpDir),
    Select,
    IsFinite,
    Unary(UOp),
    Binary(BOp),
    Tuple,
    Gte(usize),
    Call(usize),
    While { cond: usize, body: usize },
    Conditional { branches: Vec<usize> },
    DynamicSlice { sizes: Vec<usize> },
    DynamicUpdateSlice,
    Gather(Box<GatherCfg>),
    Scatter(Box<ScatterCfg>),
}

#[derive(Debug, Clone)]
pub(crate) struct Node {
    pub name: String,
    pub shape: GShape,
    pub op: Op,
    pub args: Vec<usize>,
}

#[derive(Debug, Clone)]
pub(crate) struct Comp {
    pub name: String,
    pub params: Vec<usize>,
    pub nodes: Vec<Node>,
    pub root: usize,
}

/// A host-compiled artifact: the lowered graph plus the entry I/O
/// signature (leaf order = parameter order = manifest order; outputs
/// = root tuple elements in order).
pub struct HostExecutable {
    comps: Vec<Comp>,
    entry: usize,
    in_specs: Vec<(DType, Vec<usize>)>,
    out_specs: Vec<(DType, Vec<usize>)>,
}

impl HostExecutable {
    /// Lower parsed HLO text into an executable graph. Rejects any
    /// opcode outside the supported set, naming it.
    pub fn compile(text: &str) -> Result<HostExecutable> {
        let program = HloProgram::parse(text)?;
        Self::from_program(&program)
    }

    pub fn from_program(program: &HloProgram) -> Result<HostExecutable> {
        let mut comps = Vec::with_capacity(program.computations.len());
        for gc in &program.computations {
            comps.push(lower_computation(program, gc).with_context(|| {
                format!("lower computation {}", gc.name)
            })?);
        }
        let entry = program
            .computations
            .iter()
            .position(|c| c.is_entry)
            .context("module has no ENTRY computation")?;

        let ec = &comps[entry];
        let mut in_specs = Vec::with_capacity(ec.params.len());
        for &pi in &ec.params {
            let shape = &ec.nodes[pi].shape;
            in_specs.push((shape.dtype()?, shape.dims()?.to_vec()));
        }
        let out_specs = match &ec.nodes[ec.root].shape {
            GShape::Tuple(parts) => parts
                .iter()
                .map(|p| Ok((p.dtype()?, p.dims()?.to_vec())))
                .collect::<Result<Vec<_>>>()?,
            s @ GShape::Array { .. } => vec![(s.dtype()?, s.dims()?.to_vec())],
        };
        Ok(HostExecutable { comps, entry, in_specs, out_specs })
    }

    pub(crate) fn comp(&self, i: usize) -> &Comp {
        &self.comps[i]
    }

    pub fn num_inputs(&self) -> usize {
        self.in_specs.len()
    }

    pub fn num_outputs(&self) -> usize {
        self.out_specs.len()
    }
}

impl Executable for HostExecutable {
    fn execute(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.in_specs.len() {
            bail!(
                "host execute: got {} inputs, entry wants {}",
                inputs.len(),
                self.in_specs.len()
            );
        }
        for (i, (v, (dt, dims))) in
            inputs.iter().zip(&self.in_specs).enumerate()
        {
            if v.dtype() != *dt || v.shape() != dims.as_slice() {
                bail!(
                    "host execute: input {i} is {}{:?}, entry wants {}{:?}",
                    v.dtype().name(),
                    v.shape(),
                    dt.name(),
                    dims
                );
            }
        }
        let out = self.eval_entry(inputs)?;
        if out.len() != self.out_specs.len() {
            bail!(
                "host execute: produced {} outputs, entry declares {}",
                out.len(),
                self.out_specs.len()
            );
        }
        Ok(out)
    }
}

fn lower_computation(
    program: &HloProgram,
    gc: &GComputation,
) -> Result<Comp> {
    let comp_index = |name: &str| -> Result<usize> {
        program
            .computation_index(name)
            .with_context(|| format!("unknown computation {name}"))
    };
    let mut nodes = Vec::with_capacity(gc.instrs.len());
    for gi in &gc.instrs {
        let args = gi
            .operands
            .iter()
            .map(|o| {
                gc.find(o).with_context(|| {
                    format!("{}: operand {o} not defined before use", gi.name)
                })
            })
            .collect::<Result<Vec<usize>>>()?;

        let op = match gi.opcode.as_str() {
            "parameter" => Op::Parameter(gi.param_index()?),
            "constant" => Op::Constant(
                eval::parse_constant(&gi.shape, gi.payload.as_deref())
                    .with_context(|| format!("constant {}", gi.name))?,
            ),
            "iota" => Op::Iota { dim: gi.attr_usize("iota_dimension")? },
            "broadcast" => {
                Op::Broadcast { dims: gi.attr_usize_list("dimensions")? }
            }
            "reshape" => Op::Reshape,
            "copy" => Op::Copy,
            "transpose" => {
                Op::Transpose { perm: gi.attr_usize_list("dimensions")? }
            }
            "slice" => Op::Slice { spec: parse_slice(gi.attr_required("slice")?)? },
            "concatenate" => Op::Concat { dim: gi.attr_usize("dimensions")? },
            "pad" => Op::Pad { cfg: parse_padding(gi.attr_required("padding")?)? },
            "reduce" => Op::Reduce {
                dims: gi.attr_usize_list("dimensions")?,
                comp: comp_index(gi.attr_required("to_apply")?)?,
            },
            "dot" => Op::Dot {
                lb: opt_list(gi, "lhs_batch_dims")?,
                lc: opt_list(gi, "lhs_contracting_dims")?,
                rb: opt_list(gi, "rhs_batch_dims")?,
                rc: opt_list(gi, "rhs_contracting_dims")?,
            },
            "convolution" => Op::Conv(Box::new(parse_conv(gi)?)),
            "convert" => Op::Convert,
            "bitcast-convert" => Op::BitcastConvert,
            "compare" => Op::Compare(parse_direction(
                gi.attr_required("direction")?,
            )?),
            "select" => Op::Select,
            "is-finite" => Op::IsFinite,
            "negate" => Op::Unary(UOp::Neg),
            "abs" => Op::Unary(UOp::Abs),
            "exponential" => Op::Unary(UOp::Exp),
            "log" => Op::Unary(UOp::Log),
            "log-plus-one" => Op::Unary(UOp::Log1p),
            "tanh" => Op::Unary(UOp::Tanh),
            "sqrt" => Op::Unary(UOp::Sqrt),
            "rsqrt" => Op::Unary(UOp::Rsqrt),
            "add" => Op::Binary(BOp::Add),
            "subtract" => Op::Binary(BOp::Sub),
            "multiply" => Op::Binary(BOp::Mul),
            "divide" => Op::Binary(BOp::Div),
            "maximum" => Op::Binary(BOp::Max),
            "minimum" => Op::Binary(BOp::Min),
            "power" => Op::Binary(BOp::Pow),
            "and" => Op::Binary(BOp::And),
            "or" => Op::Binary(BOp::Or),
            "xor" => Op::Binary(BOp::Xor),
            "shift-left" => Op::Binary(BOp::Shl),
            "shift-right-logical" => Op::Binary(BOp::Shr),
            "tuple" => Op::Tuple,
            "get-tuple-element" => Op::Gte(gi.attr_usize("index")?),
            "call" => Op::Call(comp_index(gi.attr_required("to_apply")?)?),
            "while" => Op::While {
                cond: comp_index(gi.attr_required("condition")?)?,
                body: comp_index(gi.attr_required("body")?)?,
            },
            "conditional" => {
                let names = gi.attr_required("branch_computations")?;
                let inner = names
                    .trim()
                    .trim_start_matches('{')
                    .trim_end_matches('}');
                let branches = inner
                    .split(',')
                    .map(|n| comp_index(n.trim()))
                    .collect::<Result<Vec<_>>>()?;
                Op::Conditional { branches }
            }
            "dynamic-slice" => Op::DynamicSlice {
                sizes: gi.attr_usize_list("dynamic_slice_sizes")?,
            },
            "dynamic-update-slice" => Op::DynamicUpdateSlice,
            "gather" => Op::Gather(Box::new(GatherCfg {
                offset_dims: opt_list(gi, "offset_dims")?,
                collapsed_slice_dims: opt_list(gi, "collapsed_slice_dims")?,
                operand_batching_dims: opt_list(gi, "operand_batching_dims")?,
                start_indices_batching_dims: opt_list(
                    gi,
                    "start_indices_batching_dims",
                )?,
                start_index_map: gi.attr_usize_list("start_index_map")?,
                index_vector_dim: gi.attr_usize("index_vector_dim")?,
                slice_sizes: gi.attr_usize_list("slice_sizes")?,
            })),
            "scatter" => Op::Scatter(Box::new(ScatterCfg {
                update_window_dims: opt_list(gi, "update_window_dims")?,
                inserted_window_dims: opt_list(gi, "inserted_window_dims")?,
                scatter_dims_to_operand_dims: gi
                    .attr_usize_list("scatter_dims_to_operand_dims")?,
                input_batching_dims: opt_list(gi, "input_batching_dims")?,
                scatter_indices_batching_dims: opt_list(
                    gi,
                    "scatter_indices_batching_dims",
                )?,
                index_vector_dim: gi.attr_usize("index_vector_dim")?,
                comp: comp_index(gi.attr_required("to_apply")?)?,
            })),
            other => bail!(
                "host backend: unsupported opcode \"{other}\" \
                 (instruction {} in {})",
                gi.name,
                gc.name
            ),
        };
        nodes.push(Node {
            name: gi.name.clone(),
            shape: gi.shape.clone(),
            op,
            args,
        });
    }

    // params ordered by parameter number
    let mut params: Vec<(usize, usize)> = nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| match n.op {
            Op::Parameter(k) => Some((k, i)),
            _ => None,
        })
        .collect();
    params.sort();
    let params = params.into_iter().map(|(_, i)| i).collect();

    let root = gc.root_index()?;
    Ok(Comp { name: gc.name.clone(), params, nodes, root })
}

fn opt_list(
    gi: &crate::hlo::graph::GInstr,
    key: &str,
) -> Result<Vec<usize>> {
    match gi.attr(key) {
        Some(_) => gi.attr_usize_list(key),
        None => Ok(Vec::new()),
    }
}

fn parse_direction(s: &str) -> Result<CmpDir> {
    Ok(match s.trim() {
        "EQ" => CmpDir::Eq,
        "NE" => CmpDir::Ne,
        "GE" => CmpDir::Ge,
        "GT" => CmpDir::Gt,
        "LE" => CmpDir::Le,
        "LT" => CmpDir::Lt,
        other => bail!("unknown compare direction {other}"),
    })
}

/// `{[0:8], [1:17], [0:64:2]}` → per-dim (start, end, stride).
fn parse_slice(v: &str) -> Result<Vec<(usize, usize, usize)>> {
    let inner = v
        .trim()
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .with_context(|| format!("slice spec {v:?} not braced"))?;
    let mut out = Vec::new();
    for piece in inner.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        let body = piece
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .with_context(|| format!("slice bound {piece:?} not bracketed"))?;
        let parts: Vec<&str> = body.split(':').collect();
        if parts.len() < 2 || parts.len() > 3 {
            bail!("slice bound {piece:?} wants start:end[:stride]");
        }
        let start = parts[0].trim().parse::<usize>()?;
        let end = parts[1].trim().parse::<usize>()?;
        let stride = if parts.len() == 3 {
            parts[2].trim().parse::<usize>()?
        } else {
            1
        };
        if stride == 0 {
            bail!("slice bound {piece:?}: zero stride");
        }
        out.push((start, end, stride));
    }
    Ok(out)
}

/// `0_0x0_16x1_2_3` → per-dim (low, high, interior). Lows/highs may
/// be negative (XLA allows trimming pads).
fn parse_padding(v: &str) -> Result<Vec<(i64, i64, usize)>> {
    let mut out = Vec::new();
    for dim in v.trim().split('x') {
        let parts: Vec<&str> = dim.split('_').collect();
        if parts.len() < 2 || parts.len() > 3 {
            bail!("padding {dim:?} wants low_high[_interior]");
        }
        let low = parts[0].parse::<i64>()?;
        let high = parts[1].parse::<i64>()?;
        let interior =
            if parts.len() == 3 { parts[2].parse::<usize>()? } else { 0 };
        out.push((low, high, interior));
    }
    Ok(out)
}

/// `b01f_01io->b01f` → per-operand dim positions.
fn parse_dim_labels(v: &str) -> Result<(ConvDimSpec, ConvDimSpec, ConvDimSpec)> {
    let (input, rest) = v
        .trim()
        .split_once('_')
        .with_context(|| format!("dim_labels {v:?} missing '_'"))?;
    let (kernel, output) = rest
        .split_once("->")
        .with_context(|| format!("dim_labels {v:?} missing '->'"))?;
    let spec = |labels: &str, b: char, f: char| -> Result<ConvDimSpec> {
        let mut batch = None;
        let mut feature = None;
        let mut spatial = vec![None; labels.len().saturating_sub(2)];
        for (pos, c) in labels.chars().enumerate() {
            if c == b {
                batch = Some(pos);
            } else if c == f {
                feature = Some(pos);
            } else {
                let k = c
                    .to_digit(10)
                    .with_context(|| format!("bad dim label {c:?} in {labels}"))?
                    as usize;
                if k >= spatial.len() {
                    bail!("spatial label {k} out of range in {labels}");
                }
                spatial[k] = Some(pos);
            }
        }
        Ok(ConvDimSpec {
            batch: batch.with_context(|| format!("{labels}: no {b} dim"))?,
            feature: feature
                .with_context(|| format!("{labels}: no {f} dim"))?,
            spatial: spatial
                .into_iter()
                .map(|s| s.context("missing spatial label"))
                .collect::<Result<Vec<_>>>()?,
        })
    };
    Ok((spec(input, 'b', 'f')?, spec(kernel, 'o', 'i')?, spec(output, 'b', 'f')?))
}

/// `window={size=2x2 stride=1x1 pad=0_0x0_0}` + `dim_labels`.
fn parse_conv(gi: &crate::hlo::graph::GInstr) -> Result<ConvCfg> {
    if let Some(fgc) = gi.attr("feature_group_count") {
        if fgc.trim() != "1" {
            bail!("convolution {}: grouped conv unsupported", gi.name);
        }
    }
    let (lhs, rhs, out) = parse_dim_labels(gi.attr_required("dim_labels")?)?;
    let window_attr = gi.attr_required("window")?;
    let inner = window_attr
        .trim()
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .with_context(|| format!("window {window_attr:?} not braced"))?;
    let mut window = Vec::new();
    let mut strides = Vec::new();
    let mut pads = Vec::new();
    for field in inner.split_whitespace() {
        let (key, val) = field
            .split_once('=')
            .with_context(|| format!("window field {field:?}"))?;
        match key {
            "size" => {
                window = val
                    .split('x')
                    .map(|d| d.parse::<usize>().context("window size"))
                    .collect::<Result<Vec<_>>>()?;
            }
            "stride" => {
                strides = val
                    .split('x')
                    .map(|d| d.parse::<usize>().context("window stride"))
                    .collect::<Result<Vec<_>>>()?;
            }
            "pad" => {
                pads = val
                    .split('x')
                    .map(|d| {
                        let (l, h) = d
                            .split_once('_')
                            .context("window pad wants low_high")?;
                        Ok((l.parse::<i64>()?, h.parse::<i64>()?))
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            "lhs_dilate" | "rhs_dilate" => {
                if val.split('x').any(|d| d.trim() != "1") {
                    bail!("convolution {}: dilation unsupported", gi.name);
                }
            }
            _ => {} // reversal etc. — reject only when non-default
        }
    }
    if window.is_empty() {
        bail!("convolution {}: window has no size", gi.name);
    }
    let rank = window.len();
    if strides.is_empty() {
        strides = vec![1; rank];
    }
    if pads.is_empty() {
        pads = vec![(0, 0); rank];
    }
    if strides.len() != rank || pads.len() != rank || lhs.spatial.len() != rank
    {
        bail!("convolution {}: inconsistent window rank", gi.name);
    }
    Ok(ConvCfg { window, strides, pads, lhs, rhs, out })
}
