//! Golden-vector tests for the interpreter ops: each kernel pinned
//! against tiny hand-computed cases, plus f16/bf16 convert
//! bit-exactness against the scalar `numerics::F16`/`Bf16` reference
//! — the same discipline `hostkernel/cast.rs` applies to its slices.

use crate::numerics::{Bf16, F16};
use crate::runtime::value::{lit_f32, lit_i32, read_f32, Value};
use crate::runtime::Executable;

use super::HostExecutable;

fn run(text: &str, inputs: &[Value]) -> Vec<Value> {
    let exe = HostExecutable::compile(text).expect("compile");
    let refs: Vec<&Value> = inputs.iter().collect();
    exe.execute(&refs).expect("execute")
}

fn run1(text: &str, inputs: &[Value]) -> Vec<f32> {
    let out = run(text, inputs);
    assert_eq!(out.len(), 1);
    read_f32(&out[0]).unwrap()
}

#[test]
fn dot_golden() {
    let text = r#"
HloModule golden_dot

ENTRY main.1 {
  a = f32[2,3] parameter(0)
  b = f32[3,2] parameter(1)
  ROOT dot.1 = f32[2,2] dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
    let a = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
    let b = lit_f32(&[3, 2], &[7., 8., 9., 10., 11., 12.]).unwrap();
    // [[1·7+2·9+3·11, 1·8+2·10+3·12], [4·7+5·9+6·11, 4·8+5·10+6·12]]
    assert_eq!(run1(text, &[a, b]), vec![58., 64., 139., 154.]);
}

#[test]
fn dot_batched_golden() {
    let text = r#"
HloModule golden_bdot

ENTRY main.1 {
  a = f32[2,1,2] parameter(0)
  b = f32[2,2,1] parameter(1)
  ROOT dot.1 = f32[2,1,1] dot(a, b), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={1}
}
"#;
    let a = lit_f32(&[2, 1, 2], &[1., 2., 3., 4.]).unwrap();
    let b = lit_f32(&[2, 2, 1], &[5., 6., 7., 8.]).unwrap();
    // batch0: 1·5+2·6 = 17; batch1: 3·7+4·8 = 53
    assert_eq!(run1(text, &[a, b]), vec![17., 53.]);
}

#[test]
fn conv_im2col_golden() {
    // NCHW 3×3 input, 2×2 kernel, stride 1, no pad:
    //   input  = [[0,1,2],[3,4,5],[6,7,8]], kernel = [[1,2],[3,4]]
    //   out[0,0] = 0·1+1·2+3·3+4·4 = 27     out[0,1] = 1+4+12+20 = 37
    //   out[1,0] = 3+8+18+28 = 57           out[1,1] = 4+10+21+32 = 67
    let text = r#"
HloModule golden_conv

ENTRY main.1 {
  x = f32[1,1,3,3] parameter(0)
  k = f32[1,1,2,2] parameter(1)
  ROOT conv.1 = f32[1,1,2,2] convolution(x, k), window={size=2x2 stride=1x1 pad=0_0x0_0}, dim_labels=bf01_oi01->bf01
}
"#;
    let x =
        lit_f32(&[1, 1, 3, 3], &[0., 1., 2., 3., 4., 5., 6., 7., 8.]).unwrap();
    let k = lit_f32(&[1, 1, 2, 2], &[1., 2., 3., 4.]).unwrap();
    assert_eq!(run1(text, &[x, k]), vec![27., 37., 57., 67.]);
}

#[test]
fn conv_strided_padded_golden() {
    // Same input, stride 2, pad 1 on both sides → 2×2 output of the
    // padded 5×5 image sampled at (0,0),(0,2),(2,0),(2,2):
    //   out[0,0] = 4·0 = 0      (only kernel[1][1] overlaps)
    //   wait — hand-compute each window over zero-padded input.
    let text = r#"
HloModule golden_conv2

ENTRY main.1 {
  x = f32[1,1,3,3] parameter(0)
  k = f32[1,1,2,2] parameter(1)
  ROOT conv.1 = f32[1,1,2,2] convolution(x, k), window={size=2x2 stride=2x2 pad=1_0x1_0}, dim_labels=bf01_oi01->bf01
}
"#;
    let x =
        lit_f32(&[1, 1, 3, 3], &[0., 1., 2., 3., 4., 5., 6., 7., 8.]).unwrap();
    let k = lit_f32(&[1, 1, 2, 2], &[1., 2., 3., 4.]).unwrap();
    // windows start at padded coords (0,0),(0,2),(2,0),(2,2); padded
    // image has the input at [1..4, 1..4].
    // w(0,0): cells p(0,0),p(0,1),p(1,0),p(1,1) = 0,0,0,in(0,0)=0 → 4·0 = 0
    // w(0,2): p(0,2),p(0,3),p(1,2),p(1,3) = 0,0,in(0,1),in(0,2) → 3·1+4·2 = 11
    // w(2,0): p(2,0),p(2,1),p(3,0),p(3,1) = 0,in(1,0),0,in(2,0) → 2·3+4·6 = 30
    // w(2,2): in(1,1),in(1,2),in(2,1),in(2,2) → 1·4+2·5+3·7+4·8 = 67
    assert_eq!(run1(text, &[x, k]), vec![0., 11., 30., 67.]);
}

#[test]
fn reduce_golden() {
    let text = r#"
HloModule golden_reduce

region_0.1 {
  p0 = f32[] parameter(0)
  p1 = f32[] parameter(1)
  ROOT add.1 = f32[] add(p0, p1)
}

ENTRY main.2 {
  x = f32[2,3] parameter(0)
  c = f32[] constant(0)
  ROOT reduce.2 = f32[2] reduce(x, c), dimensions={1}, to_apply=region_0.1
}
"#;
    let x = lit_f32(&[2, 3], &[1., 2., 3., 10., 20., 30.]).unwrap();
    assert_eq!(run1(text, &[x]), vec![6., 60.]);
}

#[test]
fn reduce_max_with_init_golden() {
    let text = r#"
HloModule golden_rmax

region_0.1 {
  p0 = f32[] parameter(0)
  p1 = f32[] parameter(1)
  ROOT max.1 = f32[] maximum(p0, p1)
}

ENTRY main.2 {
  x = f32[2,2] parameter(0)
  c = f32[] constant(-inf)
  ROOT reduce.2 = f32[2] reduce(x, c), dimensions={1}, to_apply=region_0.1
}
"#;
    let x = lit_f32(&[2, 2], &[-3., -1., 5., 2.]).unwrap();
    assert_eq!(run1(text, &[x]), vec![-1., 5.]);
}

#[test]
fn softmax_composition_golden() {
    // softmax as the artifacts spell it: max-reduce, subtract, exp,
    // sum-reduce, divide — all composed ops, no fused primitive.
    let text = r#"
HloModule golden_softmax

region_max.1 {
  p0 = f32[] parameter(0)
  p1 = f32[] parameter(1)
  ROOT max.1 = f32[] maximum(p0, p1)
}

region_add.2 {
  p2 = f32[] parameter(0)
  p3 = f32[] parameter(1)
  ROOT add.2 = f32[] add(p2, p3)
}

ENTRY main.3 {
  x = f32[2,4] parameter(0)
  ninf = f32[] constant(-inf)
  zero = f32[] constant(0)
  m = f32[2] reduce(x, ninf), dimensions={1}, to_apply=region_max.1
  mb = f32[2,4] broadcast(m), dimensions={0}
  shifted = f32[2,4] subtract(x, mb)
  e = f32[2,4] exponential(shifted)
  s = f32[2] reduce(e, zero), dimensions={1}, to_apply=region_add.2
  sb = f32[2,4] broadcast(s), dimensions={0}
  ROOT out = f32[2,4] divide(e, sb)
}
"#;
    let xs = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 0.5];
    let x = lit_f32(&[2, 4], &xs).unwrap();
    let got = run1(text, &[x]);
    // reference: identical operation order in plain Rust
    let mut want = vec![0f32; 8];
    for r in 0..2 {
        let row = &xs[r * 4..(r + 1) * 4];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
        let s: f32 = e.iter().sum();
        for c in 0..4 {
            want[r * 4 + c] = e[c] / s;
        }
    }
    assert_eq!(got, want, "composed softmax must be bit-identical");
    for r in 0..2 {
        let sum: f32 = got[r * 4..(r + 1) * 4].iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }
}

#[test]
fn convert_f16_bit_exact_vs_scalar_reference() {
    let text = r#"
HloModule golden_cvt_f16

ENTRY main.1 {
  x = f32[6] parameter(0)
  ROOT cvt.1 = f16[6] convert(x)
}
"#;
    let xs = [0.1f32, -2.0, 65504.0, 1e-8, f32::INFINITY, 0.099975586];
    let x = lit_f32(&[6], &xs).unwrap();
    let out = run(text, &[x]);
    let got = out[0].bytes();
    for (i, &v) in xs.iter().enumerate() {
        let want = F16::from_f32(v).0;
        let g = u16::from_ne_bytes([got[2 * i], got[2 * i + 1]]);
        assert_eq!(g, want, "f16 convert of {v} (elem {i})");
    }
}

#[test]
fn convert_bf16_bit_exact_vs_scalar_reference() {
    let text = r#"
HloModule golden_cvt_bf16

ENTRY main.1 {
  x = f32[5] parameter(0)
  ROOT cvt.1 = bf16[5] convert(x)
}
"#;
    let xs = [0.1f32, -3.14159, 3.3895314e38, 1e-40, -0.0];
    let x = lit_f32(&[5], &xs).unwrap();
    let out = run(text, &[x]);
    let got = out[0].bytes();
    for (i, &v) in xs.iter().enumerate() {
        let want = Bf16::from_f32(v).0;
        let g = u16::from_ne_bytes([got[2 * i], got[2 * i + 1]]);
        assert_eq!(g, want, "bf16 convert of {v} (elem {i})");
    }
}

#[test]
fn convert_roundtrip_half_widths() {
    // f32 → f16 → f32: the widening leg is exact, so the composite
    // equals one RTNE rounding — bit-identical to the scalar ref.
    let text = r#"
HloModule golden_cvt_rt

ENTRY main.1 {
  x = f32[4] parameter(0)
  h = f16[4] convert(x)
  ROOT back.1 = f32[4] convert(h)
}
"#;
    let xs = [0.1f32, 1.0 / 3.0, -1234.56, 2.5e-6];
    let x = lit_f32(&[4], &xs).unwrap();
    let got = run1(text, &[x]);
    for (i, &v) in xs.iter().enumerate() {
        assert_eq!(got[i].to_bits(), F16::from_f32(v).to_f32().to_bits());
    }
}

#[test]
fn threefry_integer_ops_bit_exact() {
    // The init artifacts' threefry body is u32 adds, xors, rotations
    // built from shift-left / shift-right-logical, and or — all must
    // be bit-exact (wrapping, shift-past-width → 0).
    let text = r#"
HloModule golden_threefry

ENTRY main.1 {
  a = u32[4] parameter(0)
  b = u32[4] parameter(1)
  s = u32[4] parameter(2)
  sum = u32[4] add(a, b)
  x = u32[4] xor(sum, b)
  l = u32[4] shift-left(x, s)
  r = u32[4] shift-right-logical(x, s)
  ROOT rot = u32[4] or(l, r)
}
"#;
    let av = [0xdeadbeefu32, u32::MAX, 0x9e3779b9, 7];
    let bv = [0x12345678u32, 1, 0xbb67ae85, 11];
    let sv = [13u32, 32, 1, 0];
    let mk = |v: &[u32; 4]| {
        Value::new(
            crate::pytree::DType::U32,
            vec![4],
            v.iter().flat_map(|x| x.to_ne_bytes()).collect(),
        )
        .unwrap()
    };
    let out = run(text, &[mk(&av), mk(&bv), mk(&sv)]);
    let got: Vec<u32> = out[0]
        .bytes()
        .chunks_exact(4)
        .map(|c| u32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    for i in 0..4 {
        let sum = av[i].wrapping_add(bv[i]);
        let x = sum ^ bv[i];
        let l = x.checked_shl(sv[i]).unwrap_or(0);
        let r = x.checked_shr(sv[i]).unwrap_or(0);
        assert_eq!(got[i], l | r, "lane {i}");
    }
}

#[test]
fn select_compare_broadcast_golden() {
    let text = r#"
HloModule golden_select

ENTRY main.1 {
  x = f32[4] parameter(0)
  zero = f32[] constant(0)
  zb = f32[4] broadcast(zero), dimensions={}
  mask = pred[4] compare(x, zb), direction=GE
  ROOT relu = f32[4] select(mask, x, zb)
}
"#;
    let x = lit_f32(&[4], &[-1.5, 0.0, 2.5, -0.25]).unwrap();
    assert_eq!(run1(text, &[x]), vec![0.0, 0.0, 2.5, 0.0]);
}

#[test]
fn gather_cross_entropy_row_pick() {
    // The grads artifacts' label-pick gather: operand [B,C] logits,
    // batched indices [B,1] → output [B] picking logits[b, label[b]].
    let text = r#"
HloModule golden_gather

ENTRY main.1 {
  logits = f32[2,3] parameter(0)
  labels = s32[2,1] parameter(1)
  ROOT g.1 = f32[2,1] gather(logits, labels), offset_dims={}, collapsed_slice_dims={1}, start_index_map={1}, operand_batching_dims={0}, start_indices_batching_dims={0}, index_vector_dim=2, slice_sizes={1,1}
}
"#;
    let logits =
        lit_f32(&[2, 3], &[10., 11., 12., 20., 21., 22.]).unwrap();
    let labels = lit_i32(&[2, 1], &[2, 0]).unwrap();
    assert_eq!(run1(text, &[logits, labels]), vec![12., 20.]);
}

#[test]
fn while_loop_counts() {
    let text = r#"
HloModule golden_while

region_cond.1 {
  pc = (s32[]) parameter(0)
  i = s32[] get-tuple-element(pc), index=0
  lim = s32[] constant(5)
  ROOT lt.1 = pred[] compare(i, lim), direction=LT
}

region_body.2 {
  pb = (s32[]) parameter(0)
  j = s32[] get-tuple-element(pb), index=0
  one = s32[] constant(1)
  nxt = s32[] add(j, one)
  ROOT t.2 = (s32[]) tuple(nxt)
}

ENTRY main.3 {
  z = s32[] parameter(0)
  st = (s32[]) tuple(z)
  w = (s32[]) while(st), condition=region_cond.1, body=region_body.2
  ROOT out = s32[] get-tuple-element(w), index=0
}
"#;
    let z = lit_i32(&[], &[0]).unwrap();
    let out = run(text, &[z]);
    assert_eq!(
        crate::runtime::value::read_scalar_i32(&out[0]).unwrap(),
        5
    );
}

#[test]
fn unknown_opcode_named_in_error() {
    let text = r#"
HloModule golden_bad

ENTRY main.1 {
  x = f32[2] parameter(0)
  ROOT s.1 = f32[2] sort(x), dimensions={0}
}
"#;
    let err = HostExecutable::compile(text).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sort"), "error must name the opcode: {msg}");
    assert!(msg.contains("unsupported opcode"), "{msg}");
}
