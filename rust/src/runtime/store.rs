//! Artifact registry: manifest + compiled executable, cached by name,
//! parameterized over the runtime backend.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::pytree::Manifest;
use crate::runtime::{Backend, BackendKind, Executable, Value};

/// One loaded artifact: parsed manifest + compiled executable.
pub struct Artifact {
    pub manifest: Manifest,
    exe: Box<dyn Executable>,
}

impl Artifact {
    /// Execute on flat input leaves (manifest order); returns flat
    /// output leaves. Accepts any iterable of `&Value` so both
    /// `&Vec<Value>` and collected `Vec<&Value>` call sites work.
    pub fn execute<'a, I>(&self, inputs: I) -> Result<Vec<Value>>
    where
        I: IntoIterator<Item = &'a Value>,
    {
        let refs: Vec<&Value> = inputs.into_iter().collect();
        if refs.len() != self.manifest.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, manifest wants {}",
                self.manifest.name,
                refs.len(),
                self.manifest.inputs.len()
            );
        }
        let out = self.exe.execute(&refs)?;
        if out.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest wants {}",
                self.manifest.name,
                out.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(out)
    }
}

/// Loads artifacts from a directory, compiling each at most once.
pub struct ArtifactStore {
    dir: PathBuf,
    backend: Box<dyn Backend>,
    kind: BackendKind,
    cache: HashMap<String, Arc<Artifact>>,
}

impl ArtifactStore {
    /// Open with the build's default backend (xla when compiled in,
    /// host otherwise).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore> {
        Self::open_with(dir, BackendKind::default_kind())
    }

    /// Open with an explicit backend.
    pub fn open_with(
        dir: impl Into<PathBuf>,
        kind: BackendKind,
    ) -> Result<ArtifactStore> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} not found — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(ArtifactStore {
            dir,
            backend: kind.create()?,
            kind,
            cache: HashMap::new(),
        })
    }

    /// Default location: `$MPX_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        Self::open_default_with(BackendKind::default_kind())
    }

    pub fn open_default_with(kind: BackendKind) -> Result<ArtifactStore> {
        let dir = std::env::var("MPX_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open_with(dir, kind)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Which backend this store compiles with.
    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    /// Parse a manifest without compiling (memory model, inspector).
    pub fn manifest(&self, name: &str) -> Result<Manifest> {
        let path = self.dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
            .with_context(|| format!("parse manifest {name}"))
    }

    /// Raw HLO text of an artifact (memory census path).
    pub fn hlo_text(&self, name: &str) -> Result<String> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))
    }

    /// Load + compile (cached).
    pub fn load(&mut self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let manifest = self.manifest(name)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let exe = self.backend.compile_hlo_file(&hlo_path)?;
        eprintln!(
            "[runtime] compiled {name} ({}) in {}",
            self.backend.name(),
            crate::util::human_duration(t0.elapsed())
        );
        let artifact = Arc::new(Artifact { manifest, exe });
        self.cache.insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// All artifact names present on disk.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".manifest.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}
