//! Artifact registry: manifest + compiled executable, cached by name.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::pytree::Manifest;
use crate::runtime::{Runtime, SharedExecutable};

/// One loaded artifact: parsed manifest + compiled executable.
pub struct Artifact {
    pub manifest: Manifest,
    pub exe: SharedExecutable,
}

impl Artifact {
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, manifest wants {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let out = self.exe.execute_leaves(inputs)?;
        if out.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest wants {}",
                self.manifest.name,
                out.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(out)
    }
}

/// Loads artifacts from a directory, compiling each at most once.
pub struct ArtifactStore {
    dir: PathBuf,
    runtime: Runtime,
    cache: HashMap<String, Arc<Artifact>>,
}

impl ArtifactStore {
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} not found — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(ArtifactStore {
            dir,
            runtime: Runtime::cpu()?,
            cache: HashMap::new(),
        })
    }

    /// Default location: `$MPX_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("MPX_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Parse a manifest without compiling (memory model, inspector).
    pub fn manifest(&self, name: &str) -> Result<Manifest> {
        let path = self.dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
            .with_context(|| format!("parse manifest {name}"))
    }

    /// Raw HLO text of an artifact (memory census path).
    pub fn hlo_text(&self, name: &str) -> Result<String> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))
    }

    /// Load + compile (cached).
    pub fn load(&mut self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let manifest = self.manifest(name)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let exe = self.runtime.compile_hlo_file(&hlo_path)?;
        eprintln!(
            "[runtime] compiled {name} in {}",
            crate::util::human_duration(t0.elapsed())
        );
        let artifact =
            Arc::new(Artifact { manifest, exe: SharedExecutable(exe) });
        self.cache.insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// All artifact names present on disk.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".manifest.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}
