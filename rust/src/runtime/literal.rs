//! Literal pack/unpack helpers.
//!
//! By design the Rust↔artifact boundary moves only f32/s32/pred data
//! (half-precision casts happen *inside* the compiled graphs — see
//! `python/compile/aot.py`), so these helpers cover exactly that
//! surface plus byte-level constructors for checkpoints.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

use crate::pytree::{DType, LeafSpec};

fn as_bytes<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            xs.as_ptr() as *const u8,
            std::mem::size_of_val(xs),
        )
    }
}

/// f32 literal of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("lit_f32: shape {shape:?} wants {n} elems, got {}", data.len());
    }
    Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        as_bytes(data),
    )
    .context("create f32 literal")
}

/// s32 literal of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("lit_i32: shape {shape:?} wants {n} elems, got {}", data.len());
    }
    Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        as_bytes(data),
    )
    .context("create s32 literal")
}

pub fn lit_scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn lit_scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Build a literal for a manifest leaf from raw bytes (checkpoint
/// restore path — works for any dtype including f16/bf16).
pub fn lit_from_bytes(leaf: &LeafSpec, bytes: &[u8]) -> Result<Literal> {
    if bytes.len() != leaf.bytes() {
        bail!(
            "leaf {}: want {} bytes, got {}",
            leaf.name,
            leaf.bytes(),
            bytes.len()
        );
    }
    let ty = match leaf.dtype {
        DType::F32 => ElementType::F32,
        DType::F16 => ElementType::F16,
        DType::Bf16 => ElementType::Bf16,
        DType::S32 => ElementType::S32,
        DType::U32 => ElementType::U32,
        DType::S8 => ElementType::S8,
        DType::U8 => ElementType::U8,
        DType::Pred => ElementType::Pred,
    };
    Literal::create_from_shape_and_untyped_data(ty, &leaf.shape, bytes)
        .context("create literal from bytes")
}

/// Read an f32 literal back to a host vector.
pub fn read_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("read f32 literal")
}

pub fn read_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("read s32 literal")
}

pub fn read_scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("read f32 scalar")
}

pub fn read_scalar_i32(lit: &Literal) -> Result<i32> {
    lit.get_first_element::<i32>().context("read s32 scalar")
}

/// Read a PRED scalar (grads_finite flag).
pub fn read_scalar_pred(lit: &Literal) -> Result<bool> {
    // PRED has no Rust NativeType in this crate; convert to S32 first.
    let as_i32 = lit
        .convert(xla::PrimitiveType::S32)
        .context("convert pred→s32")?;
    Ok(as_i32.get_first_element::<i32>().context("read pred scalar")? != 0)
}

/// Raw bytes of a literal (checkpoint save path).
///
/// Covers every dtype [`lit_from_bytes`] can restore, so save and
/// restore are symmetric — mixed-precision checkpoints with f16/bf16
/// leaves round-trip instead of bailing on save.
pub fn literal_bytes(lit: &Literal) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    literal_bytes_into(lit, &mut out)?;
    Ok(out)
}

/// [`literal_bytes`] into a caller-owned buffer (cleared first) — the
/// checkpoint writer cycles one pooled buffer across all leaves.
///
/// Half-precision leaves go through a (convert → f32 → batch
/// down-cast) staging path because this PJRT binding exposes no
/// native 16-bit host type: exact for every finite and infinite
/// value (the round-trip is bit-exact — exhaustively tested in
/// `numerics::f16`), while NaN payloads keep their top bits but come
/// back quieted.  Integer leaves stage through s32, which preserves
/// bits for every width ≤ 32 (XLA integer converts are mod-2^n).
pub fn literal_bytes_into(lit: &Literal, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    match lit.ty().context("literal type")? {
        ElementType::F32 => {
            out.extend_from_slice(as_bytes(&lit.to_vec::<f32>()?));
        }
        ElementType::S32 => {
            out.extend_from_slice(as_bytes(&lit.to_vec::<i32>()?));
        }
        ElementType::F16 => {
            let wide = lit
                .convert(xla::PrimitiveType::F32)
                .context("convert f16→f32")?
                .to_vec::<f32>()?;
            crate::hostkernel::cast::f32_to_f16_bytes(&wide, out);
        }
        ElementType::Bf16 => {
            let wide = lit
                .convert(xla::PrimitiveType::F32)
                .context("convert bf16→f32")?
                .to_vec::<f32>()?;
            crate::hostkernel::cast::f32_to_bf16_bytes(&wide, out);
        }
        ElementType::U32 => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert u32→s32")?
                .to_vec::<i32>()?;
            out.extend_from_slice(as_bytes(&v));
        }
        ElementType::S8 | ElementType::U8 => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert 8-bit→s32")?
                .to_vec::<i32>()?;
            out.extend(v.iter().map(|&x| x as u8));
        }
        ElementType::Pred => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert pred→s32")?
                .to_vec::<i32>()?;
            out.extend(v.iter().map(|&x| (x != 0) as u8));
        }
        other => bail!("checkpoint save: unsupported dtype {other:?}"),
    }
    Ok(())
}
