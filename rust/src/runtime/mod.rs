//! PJRT runtime: load AOT artifacts, compile once, execute many.
//!
//! The training hot path works on flat `Vec<xla::Literal>` leaf
//! vectors in manifest order:
//!
//! ```text
//! artifacts/<name>.hlo.txt          HloModuleProto::from_text_file
//!   └── XlaComputation  ── client.compile ──►  PjRtLoadedExecutable
//! step:  state leaves + batch leaves ─ execute ─► 1 tuple buffer
//!        └── to_literal_sync + decompose_tuple ─► output leaves
//! ```
//!
//! This PJRT build returns the whole output as **one tuple buffer**
//! (the CPU client does not untuple), so state makes a host hop per
//! step; `runtime_overhead` benches that hop, and §Perf records the
//! mitigation history.

pub mod literal;
pub mod store;

pub use literal::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, literal_bytes,
    literal_bytes_into, read_f32, read_i32, read_scalar_f32,
    read_scalar_i32, read_scalar_pred,
};
pub use store::{Artifact, ArtifactStore};

use anyhow::{Context, Result};

/// Wrapper owning the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn compile_hlo_file(
        &self,
        path: &std::path::Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }
}

/// Execute an artifact on flat input leaves; returns flat output
/// leaves (manifest order).
pub fn execute_leaves<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<L>(inputs).context("execute")?;
    let buffer = &result[0][0];
    let mut tuple = buffer
        .to_literal_sync()
        .context("fetch output tuple to host")?;
    tuple.decompose_tuple().context("decompose output tuple")
}

/// `Send`/`Sync` wrapper for sharing one compiled executable across
/// shard threads.
///
/// SAFETY: `PjRtLoadedExecutable` wraps a C++ `PjRtLoadedExecutable*`;
/// PJRT explicitly documents `Execute` as thread-safe (the CPU client
/// runs each invocation on its own thread pool slot), and the wrapper
/// never exposes `&mut`.  The `xla` crate merely never added the
/// marker.  Destruction still happens on one thread (the owner).
pub struct SharedExecutable(pub xla::PjRtLoadedExecutable);

unsafe impl Send for SharedExecutable {}
unsafe impl Sync for SharedExecutable {}

impl SharedExecutable {
    pub fn execute_leaves<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        execute_leaves(&self.0, inputs)
    }
}
