//! Runtime HAL: compile AOT artifacts once, execute many — behind a
//! backend trait so every consumer (trainer, serve, examples, tests)
//! is backend-agnostic.
//!
//! ```text
//! artifacts/<name>.hlo.txt ── Backend::compile_hlo_file ──► Executable
//! step: state leaves + batch leaves ─ execute ─► output leaves
//! ```
//!
//! Leaves are [`Value`]s — dtype + shape + native-layout bytes — in
//! manifest order on both sides. Two backends implement the trait:
//!
//! * [`host`] (always available): a pure-Rust interpreter over the
//!   deep HLO parser, running on `hostkernel`'s kernels. Makes every
//!   artifact-gated suite runnable under `--no-default-features`.
//! * `xla` (behind the `xla` cargo feature): the PJRT CPU client.
//!   This PJRT build returns the whole output as **one tuple buffer**
//!   (the CPU client does not untuple), so state makes a host hop per
//!   step; `runtime_overhead` benches that hop.
//!
//! `backend_cross_check.rs` runs the same artifact on both and pins
//! the agreement (bit-exact for integer/convert paths, per-dtype
//! tolerance where accumulation order differs).

pub mod host;
pub mod store;
pub mod value;
#[cfg(feature = "xla")]
pub mod xla_backend;

pub use host::HostBackend;
pub use store::{Artifact, ArtifactStore};
pub use value::{
    lit_f32, lit_from_bytes, lit_i32, lit_scalar_f32, lit_scalar_i32,
    literal_bytes, literal_bytes_from, literal_bytes_into, read_f32,
    read_f32_from, read_i32, read_scalar_f32, read_scalar_i32,
    read_scalar_pred, Value,
};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

use std::path::Path;

use anyhow::{bail, Result};

/// A compiled artifact, ready to execute. Inputs and outputs are flat
/// leaf vectors in manifest order.
pub trait Executable: Send + Sync {
    fn execute(&self, inputs: &[&Value]) -> Result<Vec<Value>>;
}

/// A compilation backend: turns HLO text on disk into an executable.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;
    fn compile_hlo_file(&self, path: &Path) -> Result<Box<dyn Executable>>;
}

/// Which backend to use. Both variants always parse; creating
/// [`BackendKind::Xla`] without the `xla` feature is a runtime error
/// with a build hint, so config files stay portable across builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Host,
    Xla,
}

impl BackendKind {
    /// The build's default: xla when compiled in, host otherwise.
    pub fn default_kind() -> BackendKind {
        if cfg!(feature = "xla") {
            BackendKind::Xla
        } else {
            BackendKind::Host
        }
    }

    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.trim() {
            "host" => Ok(BackendKind::Host),
            "xla" => Ok(BackendKind::Xla),
            other => bail!("unknown backend {other:?} (want \"xla\" or \"host\")"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Xla => "xla",
        }
    }

    /// Is this kind usable in the current build?
    pub fn available(self) -> bool {
        match self {
            BackendKind::Host => true,
            BackendKind::Xla => cfg!(feature = "xla"),
        }
    }

    /// Instantiate the backend.
    pub fn create(self) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Host => Ok(Box::new(HostBackend)),
            #[cfg(feature = "xla")]
            BackendKind::Xla => Ok(Box::new(XlaBackend::cpu()?)),
            #[cfg(not(feature = "xla"))]
            BackendKind::Xla => bail!(
                "backend \"xla\" is not compiled in — \
                 build with `--features xla` or use backend = \"host\""
            ),
        }
    }
}

impl Default for BackendKind {
    fn default() -> Self {
        Self::default_kind()
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        assert_eq!(BackendKind::parse("host").unwrap(), BackendKind::Host);
        assert_eq!(BackendKind::parse(" xla ").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Host.name(), "host");
        assert_eq!(BackendKind::Xla.to_string(), "xla");
    }

    #[test]
    fn host_always_available() {
        assert!(BackendKind::Host.available());
        assert!(BackendKind::Host.create().is_ok());
    }

    #[test]
    fn default_matches_build() {
        let d = BackendKind::default_kind();
        assert!(d.available());
        if cfg!(feature = "xla") {
            assert_eq!(d, BackendKind::Xla);
        } else {
            assert_eq!(d, BackendKind::Host);
        }
    }

    #[test]
    fn xla_unavailable_names_feature() {
        if !cfg!(feature = "xla") {
            let err = BackendKind::Xla.create().unwrap_err();
            assert!(format!("{err}").contains("--features xla"));
        }
    }
}
