//! Backend-agnostic leaf literal: dtype + shape + native-layout bytes.
//!
//! [`Value`] is the unit the [`super::Executable`] trait moves across
//! the artifact boundary — the host interpreter consumes it directly,
//! the PJRT backend converts it at the edge. Bytes are dense row-major
//! in each dtype's native encoding (f16/bf16 are raw 16-bit words),
//! which is exactly the manifest/checkpoint byte contract, so
//! [`literal_bytes`]/[`lit_from_bytes`] are plain copies for every
//! dtype.
//!
//! The reader helpers keep the names they had when they worked on
//! `xla::Literal`s (`read_f32`, `lit_f32`, …) so trainer/serve call
//! sites are backend-independent. The vector-returning readers stage
//! through the global [`BufferPool`] (`read_into` underneath): a
//! caller that returns its buffers via `put_f32`/`put_u8` reads leaves
//! with zero steady-state allocation.

use anyhow::{bail, Context, Result};

use crate::hostkernel::BufferPool;
use crate::pytree::{DType, LeafSpec};

/// One typed host tensor (a "leaf literal").
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    dtype: DType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

pub(crate) fn as_bytes<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            xs.as_ptr() as *const u8,
            std::mem::size_of_val(xs),
        )
    }
}

impl Value {
    /// Build from raw native-layout bytes (validated against shape).
    pub fn new(dtype: DType, shape: Vec<usize>, bytes: Vec<u8>) -> Result<Value> {
        let elems: usize = shape.iter().product::<usize>().max(1);
        if bytes.len() != elems * dtype.bytes() {
            bail!(
                "value {}{shape:?}: want {} bytes, got {}",
                dtype.name(),
                elems * dtype.bytes(),
                bytes.len()
            );
        }
        Ok(Value { dtype, shape, bytes })
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Raw native-layout bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    fn expect_dtype(&self, want: DType) -> Result<()> {
        if self.dtype != want {
            bail!(
                "value is {}, caller wants {}",
                self.dtype.name(),
                want.name()
            );
        }
        Ok(())
    }

    /// Read f32 elements into a caller-owned buffer (cleared first).
    pub fn read_f32_into(&self, out: &mut Vec<f32>) -> Result<()> {
        self.expect_dtype(DType::F32)?;
        out.clear();
        out.reserve(self.elems());
        out.extend(
            self.bytes
                .chunks_exact(4)
                .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    }

    pub fn read_i32_into(&self, out: &mut Vec<i32>) -> Result<()> {
        self.expect_dtype(DType::S32)?;
        out.clear();
        out.reserve(self.elems());
        out.extend(
            self.bytes
                .chunks_exact(4)
                .map(|c| i32::from_ne_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    }

    /// Raw bytes into a caller-owned buffer (cleared first).
    pub fn bytes_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&self.bytes);
    }
}

/// f32 value of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Value> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("lit_f32: shape {shape:?} wants {n} elems, got {}", data.len());
    }
    Value::new(DType::F32, shape.to_vec(), as_bytes(data).to_vec())
}

/// s32 value of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Value> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("lit_i32: shape {shape:?} wants {n} elems, got {}", data.len());
    }
    Value::new(DType::S32, shape.to_vec(), as_bytes(data).to_vec())
}

pub fn lit_scalar_f32(x: f32) -> Value {
    Value {
        dtype: DType::F32,
        shape: Vec::new(),
        bytes: x.to_ne_bytes().to_vec(),
    }
}

pub fn lit_scalar_i32(x: i32) -> Value {
    Value {
        dtype: DType::S32,
        shape: Vec::new(),
        bytes: x.to_ne_bytes().to_vec(),
    }
}

/// Build a value for a manifest leaf from raw bytes (checkpoint
/// restore path — any dtype including f16/bf16, which stay bitwise).
pub fn lit_from_bytes(leaf: &LeafSpec, bytes: &[u8]) -> Result<Value> {
    if bytes.len() != leaf.bytes() {
        bail!(
            "leaf {}: want {} bytes, got {}",
            leaf.name,
            leaf.bytes(),
            bytes.len()
        );
    }
    Value::new(leaf.dtype, leaf.shape.clone(), bytes.to_vec())
}

/// Read an f32 value back to a host vector, staged through `pool`.
///
/// The returned vector *is* a pool buffer: hand it back with
/// `pool.put_f32` when done and the next read reuses the allocation.
pub fn read_f32_from(v: &Value, pool: &BufferPool) -> Result<Vec<f32>> {
    let mut out = pool.take_f32(v.elems());
    v.read_f32_into(&mut out)?;
    Ok(out)
}

/// Read an f32 value back to a host vector (global-pool staging).
pub fn read_f32(v: &Value) -> Result<Vec<f32>> {
    read_f32_from(v, BufferPool::global())
}

pub fn read_i32(v: &Value) -> Result<Vec<i32>> {
    let mut out = BufferPool::global().take_i32(v.elems());
    v.read_i32_into(&mut out)?;
    Ok(out)
}

pub fn read_scalar_f32(v: &Value) -> Result<f32> {
    v.expect_dtype(DType::F32)?;
    let b = &v.bytes;
    if b.len() < 4 {
        bail!("empty f32 value");
    }
    Ok(f32::from_ne_bytes([b[0], b[1], b[2], b[3]]))
}

pub fn read_scalar_i32(v: &Value) -> Result<i32> {
    v.expect_dtype(DType::S32)?;
    let b = &v.bytes;
    if b.len() < 4 {
        bail!("empty s32 value");
    }
    Ok(i32::from_ne_bytes([b[0], b[1], b[2], b[3]]))
}

/// Read a PRED scalar (grads_finite flag).
pub fn read_scalar_pred(v: &Value) -> Result<bool> {
    v.expect_dtype(DType::Pred)?;
    let b = v.bytes.first().context("empty pred value")?;
    Ok(*b != 0)
}

/// Raw bytes of a value, staged through `pool` (checkpoint save path;
/// return with `pool.put_u8` to recycle).
pub fn literal_bytes_from(v: &Value, pool: &BufferPool) -> Result<Vec<u8>> {
    let mut out = pool.take_u8(v.bytes.len());
    v.bytes_into(&mut out);
    Ok(out)
}

/// Raw bytes of a value (global-pool staging).
pub fn literal_bytes(v: &Value) -> Result<Vec<u8>> {
    literal_bytes_from(v, BufferPool::global())
}

/// [`literal_bytes`] into a caller-owned buffer (cleared first) — the
/// checkpoint writer cycles one pooled buffer across all leaves.
/// Bitwise for every dtype: `Value` stores native encodings, so
/// f16/bf16 leaves round-trip exactly (NaN payloads included).
pub fn literal_bytes_into(v: &Value, out: &mut Vec<u8>) -> Result<()> {
    v.bytes_into(out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let v = lit_f32(&[2, 2], &[1.0, -2.5, 3.0, 0.25]).unwrap();
        assert_eq!(v.dtype(), DType::F32);
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(read_f32(&v).unwrap(), vec![1.0, -2.5, 3.0, 0.25]);
        assert_eq!(read_scalar_f32(&v).unwrap(), 1.0);
    }

    #[test]
    fn roundtrip_i32_and_scalars() {
        let v = lit_i32(&[3], &[7, -1, 42]).unwrap();
        assert_eq!(read_i32(&v).unwrap(), vec![7, -1, 42]);
        assert_eq!(read_scalar_i32(&lit_scalar_i32(-9)).unwrap(), -9);
        assert_eq!(read_scalar_f32(&lit_scalar_f32(0.5)).unwrap(), 0.5);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[3], &[1.0]).is_err());
        assert!(Value::new(DType::F32, vec![2], vec![0u8; 7]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let v = lit_i32(&[1], &[1]).unwrap();
        assert!(read_f32(&v).is_err());
        assert!(read_scalar_pred(&v).is_err());
    }

    #[test]
    fn pred_scalar() {
        let spec = LeafSpec {
            name: "finite".into(),
            dtype: DType::Pred,
            shape: vec![],
            group: "flags".into(),
            trainable: false,
        };
        let v = lit_from_bytes(&spec, &[1]).unwrap();
        assert!(read_scalar_pred(&v).unwrap());
    }

    #[test]
    fn bytes_roundtrip_any_dtype() {
        let spec = LeafSpec {
            name: "w".into(),
            dtype: DType::F16,
            shape: vec![4],
            group: "params".into(),
            trainable: true,
        };
        let raw: Vec<u8> = vec![0x00, 0x3c, 0x00, 0xc0, 0xff, 0x7b, 0x01, 0x00];
        let v = lit_from_bytes(&spec, &raw).unwrap();
        assert_eq!(literal_bytes(&v).unwrap(), raw);
    }

    /// Satellite: pooled read path — the second read of the same
    /// leaf reuses the first read's allocation when the caller
    /// recycles it (zero-alloc steady state).
    #[test]
    fn pooled_read_reuses_allocation() {
        let pool = BufferPool::new();
        let v = lit_f32(&[256], &vec![1.5f32; 256]).unwrap();

        let first = read_f32_from(&v, &pool).unwrap();
        let ptr = first.as_ptr();
        let cap = first.capacity();
        pool.put_f32(first);

        let second = read_f32_from(&v, &pool).unwrap();
        assert_eq!(second.as_ptr(), ptr, "second read must reuse the buffer");
        assert_eq!(second.capacity(), cap);
        assert_eq!(second.len(), 256);

        let stats = pool.stats();
        assert_eq!(stats.hits, 1, "second take must be a pool hit");
        assert_eq!(stats.misses, 1, "only the first take may allocate");
    }

    #[test]
    fn pooled_bytes_reuses_allocation() {
        let pool = BufferPool::new();
        let v = lit_i32(&[64], &vec![3i32; 64]).unwrap();
        let first = literal_bytes_from(&v, &pool).unwrap();
        let ptr = first.as_ptr();
        pool.put_u8(first);
        let second = literal_bytes_from(&v, &pool).unwrap();
        assert_eq!(second.as_ptr(), ptr);
        assert_eq!(second.len(), 64 * 4);
    }
}
