//! PJRT (XLA CPU) backend — the `xla`-feature implementation of the
//! runtime HAL.
//!
//! Values cross the boundary bitwise: `Value` stores native-layout
//! bytes for every dtype, and `Literal::create_from_shape_and_untyped_
//! data` accepts exactly that encoding (f16/bf16 are raw 16-bit
//! words). On the way back this PJRT binding exposes no native 16-bit
//! host type, so half-precision outputs stage through a (convert →
//! f32 → batch RTNE down-cast) path: exact for every finite and
//! infinite value (round-trip bit-exactness is exhaustively tested in
//! `numerics::f16`), while NaN payloads keep their top bits but come
//! back quieted. Integer outputs stage through s32, which preserves
//! bits for every width ≤ 32.
//!
//! Output dtypes/shapes come from our own HLO parser (the root tuple
//! of the ENTRY computation), not from PJRT shape introspection — the
//! same source of truth the host backend uses.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

use crate::hlo::graph::{GShape, HloProgram};
use crate::pytree::DType;
use crate::runtime::value::{as_bytes, Value};
use crate::runtime::{Backend, Executable};

/// Backend owning the PJRT CPU client.
pub struct XlaBackend {
    client: xla::PjRtClient,
}

impl XlaBackend {
    pub fn cpu() -> Result<XlaBackend> {
        let client =
            xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaBackend { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn compile_hlo_file(&self, path: &Path) -> Result<Box<dyn Executable>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read HLO text {}", path.display()))?;
        let out_specs = parse_out_specs(&text)
            .with_context(|| format!("output signature {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Box::new(XlaExecutable { exe: SharedExecutable(exe), out_specs }))
    }
}

/// Entry-root dtypes/shapes, one per output leaf.
fn parse_out_specs(text: &str) -> Result<Vec<(DType, Vec<usize>)>> {
    let program = HloProgram::parse(text)?;
    let entry = program.entry()?;
    let root = &entry.instrs[entry.root_index()?];
    match &root.shape {
        GShape::Tuple(parts) => parts
            .iter()
            .map(|p| Ok((p.dtype()?, p.dims()?.to_vec())))
            .collect(),
        s @ GShape::Array { .. } => Ok(vec![(s.dtype()?, s.dims()?.to_vec())]),
    }
}

/// `Send`/`Sync` wrapper for sharing one compiled executable across
/// shard threads.
///
/// SAFETY: `PjRtLoadedExecutable` wraps a C++ `PjRtLoadedExecutable*`;
/// PJRT explicitly documents `Execute` as thread-safe (the CPU client
/// runs each invocation on its own thread pool slot), and the wrapper
/// never exposes `&mut`.  The `xla` crate merely never added the
/// marker.  Destruction still happens on one thread (the owner).
struct SharedExecutable(xla::PjRtLoadedExecutable);

unsafe impl Send for SharedExecutable {}
unsafe impl Sync for SharedExecutable {}

/// A PJRT-compiled artifact.
pub struct XlaExecutable {
    exe: SharedExecutable,
    out_specs: Vec<(DType, Vec<usize>)>,
}

impl Executable for XlaExecutable {
    fn execute(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|v| value_to_literal(v))
            .collect::<Result<_>>()?;
        let result = self.exe.0.execute::<Literal>(&lits).context("execute")?;
        let buffer = &result[0][0];
        let mut tuple = buffer
            .to_literal_sync()
            .context("fetch output tuple to host")?;
        let outs = tuple.decompose_tuple().context("decompose output tuple")?;
        if outs.len() != self.out_specs.len() {
            bail!(
                "xla execute: produced {} outputs, entry declares {}",
                outs.len(),
                self.out_specs.len()
            );
        }
        outs.iter()
            .zip(&self.out_specs)
            .map(|(lit, (dt, dims))| literal_to_value(lit, *dt, dims))
            .collect()
    }
}

fn element_type(d: DType) -> ElementType {
    match d {
        DType::F32 => ElementType::F32,
        DType::F16 => ElementType::F16,
        DType::Bf16 => ElementType::Bf16,
        DType::S32 => ElementType::S32,
        DType::U32 => ElementType::U32,
        DType::S8 => ElementType::S8,
        DType::U8 => ElementType::U8,
        DType::Pred => ElementType::Pred,
    }
}

/// Native-layout bytes → literal, bitwise for every dtype.
fn value_to_literal(v: &Value) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(
        element_type(v.dtype()),
        v.shape(),
        v.bytes(),
    )
    .with_context(|| {
        format!("create {}{:?} literal", v.dtype().name(), v.shape())
    })
}

/// Literal → native-layout bytes (the staging notes are on the module
/// doc); shape comes from the parsed HLO signature.
fn literal_to_value(lit: &Literal, dt: DType, dims: &[usize]) -> Result<Value> {
    let mut out = Vec::new();
    match dt {
        DType::F32 => {
            out.extend_from_slice(as_bytes(&lit.to_vec::<f32>()?));
        }
        DType::S32 => {
            out.extend_from_slice(as_bytes(&lit.to_vec::<i32>()?));
        }
        DType::F16 => {
            let wide = lit
                .convert(xla::PrimitiveType::F32)
                .context("convert f16→f32")?
                .to_vec::<f32>()?;
            crate::hostkernel::cast::f32_to_f16_bytes(&wide, &mut out);
        }
        DType::Bf16 => {
            let wide = lit
                .convert(xla::PrimitiveType::F32)
                .context("convert bf16→f32")?
                .to_vec::<f32>()?;
            crate::hostkernel::cast::f32_to_bf16_bytes(&wide, &mut out);
        }
        DType::U32 => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert u32→s32")?
                .to_vec::<i32>()?;
            out.extend_from_slice(as_bytes(&v));
        }
        DType::S8 | DType::U8 => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert 8-bit→s32")?
                .to_vec::<i32>()?;
            out.extend(v.iter().map(|&x| x as u8));
        }
        DType::Pred => {
            let v = lit
                .convert(xla::PrimitiveType::S32)
                .context("convert pred→s32")?
                .to_vec::<i32>()?;
            out.extend(v.iter().map(|&x| (x != 0) as u8));
        }
    }
    Value::new(dt, dims.to_vec(), out)
}
