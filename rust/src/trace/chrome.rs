//! Chrome trace-event JSON export (the `chrome://tracing` /
//! [Perfetto](https://ui.perfetto.dev) format).
//!
//! Built as a [`Json`] tree and serialized with [`Json::dump`], so
//! the output round-trips through the crate's own parser by
//! construction (asserted in tests and by the host-only CI job).  No
//! serde.
//!
//! Spans are laid out on virtual threads ("tracks"): one group of
//! tracks per [`SpanKind`], and within a kind a greedy first-fit
//! assignment guarantees the spans on any single track never overlap.
//! Non-overlapping spans emitted in time order make every `B`/`E`
//! pair on a track trivially well nested — the property the snapshot
//! test checks and Perfetto requires to render without warnings.
//!
//! Timestamps are integer microseconds from the run clock's epoch
//! (`ts` in the trace-event spec); [`Json::dump`] prints integers
//! below 2^53 exactly, so virtual-clock traces are byte-stable.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::{Span, SpanKind};
use crate::util::json::Json;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>(),
    )
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn us(d: std::time::Duration) -> u64 {
    d.as_micros().min(u64::MAX as u128) as u64
}

/// Trace-event category: which pipeline the span belongs to.
fn category(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Admit
        | SpanKind::QueueWait
        | SpanKind::Service
        | SpanKind::Execute
        | SpanKind::Pack
        | SpanKind::Egress
        | SpanKind::Accept
        | SpanKind::ReadDeadline
        | SpanKind::Replan => "serve",
        _ => "train",
    }
}

/// Greedy first-fit track assignment within one kind: spans arrive
/// sorted by start; each goes on the first track whose previous span
/// already ended.  Returns the tracks, each a non-overlapping
/// time-ordered span list.
fn assign_tracks(mut spans: Vec<Span>) -> Vec<Vec<Span>> {
    spans.sort_by_key(|s| (s.start, s.end, s.seq));
    let mut tracks: Vec<Vec<Span>> = Vec::new();
    for span in spans {
        let slot = tracks
            .iter_mut()
            .find(|t| t.last().map(|p| p.end <= span.start).unwrap_or(true));
        match slot {
            Some(track) => track.push(span),
            None => tracks.push(vec![span]),
        }
    }
    tracks
}

/// Build the Chrome trace document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}`.
///
/// `dropped` is the tracer's overflow count — zero means the ring
/// held the whole timeline; non-zero tells the reader the *oldest*
/// spans are missing.
pub fn chrome_trace(spans: &[Span], dropped: u64) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() * 2 + 8);
    events.push(obj(vec![
        ("ph", Json::Str("M".into())),
        ("pid", num(1)),
        ("tid", num(0)),
        ("name", Json::Str("process_name".into())),
        ("args", obj(vec![("name", Json::Str("mpx".into()))])),
    ]));

    // Stable kind order: group the snapshot's kinds by first
    // appearance, preserving the global (start, seq) sort.
    let mut kinds: Vec<SpanKind> = Vec::new();
    for s in spans {
        if !kinds.contains(&s.kind) {
            kinds.push(s.kind);
        }
    }

    let mut tid = 0u64;
    for kind in kinds {
        let of_kind: Vec<Span> =
            spans.iter().copied().filter(|s| s.kind == kind).collect();
        for track in assign_tracks(of_kind) {
            tid += 1;
            events.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("pid", num(1)),
                ("tid", num(tid)),
                ("name", Json::Str("thread_name".into())),
                ("args", obj(vec![(
                    "name",
                    Json::Str(format!("{} #{tid}", kind.name())),
                )])),
            ]));
            for span in track {
                let names = span.kind.attr_names();
                let mut args: Vec<(&str, Json)> =
                    vec![("seq", num(span.seq))];
                for (name, value) in
                    names.iter().zip([span.a, span.b, span.c])
                {
                    if *name != "_" {
                        args.push((name, num(value)));
                    }
                }
                events.push(obj(vec![
                    ("ph", Json::Str("B".into())),
                    ("pid", num(1)),
                    ("tid", num(tid)),
                    ("ts", num(us(span.start))),
                    ("name", Json::Str(span.kind.name().into())),
                    ("cat", Json::Str(category(span.kind).into())),
                    ("args", obj(args)),
                ]));
                events.push(obj(vec![
                    ("ph", Json::Str("E".into())),
                    ("pid", num(1)),
                    ("tid", num(tid)),
                    ("ts", num(us(span.end))),
                ]));
            }
        }
    }

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
        ("otherData", obj(vec![
            ("spans", num(spans.len() as u64)),
            ("dropped", num(dropped)),
        ])),
    ])
}

/// Serialize and write the trace to `path`.
pub fn write_chrome_trace(
    path: &Path,
    spans: &[Span],
    dropped: u64,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace(spans, dropped).dump() + "\n")
        .with_context(|| format!("write trace {}", path.display()))
}

/// Verify B/E well-nestedness per tid by replaying the event array in
/// order: every `E` must close the `B` opened last on its track.
/// Used by the snapshot tests and cheap enough for debug assertions.
pub fn check_nesting(doc: &Json) -> Result<usize> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("no traceEvents array")?;
    let mut open: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut pairs = 0usize;
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).context("event without ph")?;
        let tid = ev
            .get("tid")
            .and_then(Json::as_i64)
            .context("event without tid")? as u64;
        match ph {
            "B" => {
                let name = ev
                    .get("name")
                    .and_then(Json::as_str)
                    .context("B without name")?;
                open.entry(tid).or_default().push(name.to_string());
            }
            "E" => {
                open.get_mut(&tid)
                    .and_then(Vec::pop)
                    .with_context(|| format!("unmatched E on tid {tid}"))?;
                pairs += 1;
            }
            "M" => {}
            other => anyhow::bail!("unexpected phase {other:?}"),
        }
    }
    for (tid, stack) in open {
        anyhow::ensure!(
            stack.is_empty(),
            "unclosed B events on tid {tid}: {stack:?}"
        );
    }
    Ok(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(
        kind: SpanKind,
        start_ms: u64,
        end_ms: u64,
        seq: u64,
    ) -> Span {
        Span {
            kind,
            start: Duration::from_millis(start_ms),
            end: Duration::from_millis(end_ms),
            seq,
            a: 0,
            b: seq,
            c: 0,
        }
    }

    #[test]
    fn overlapping_spans_get_separate_tracks() {
        // Three queue waits overlapping in time: no single track may
        // hold two of them.
        let spans = vec![
            span(SpanKind::QueueWait, 0, 10, 0),
            span(SpanKind::QueueWait, 2, 8, 1),
            span(SpanKind::QueueWait, 4, 6, 2),
            span(SpanKind::QueueWait, 10, 12, 3), // fits after the first
        ];
        let tracks = assign_tracks(spans);
        assert_eq!(tracks.len(), 3);
        assert_eq!(tracks[0].len(), 2); // 0–10 then 10–12
        for track in &tracks {
            for pair in track.windows(2) {
                assert!(pair[0].end <= pair[1].start);
            }
        }
    }

    #[test]
    fn export_roundtrips_and_nests() {
        let spans = vec![
            span(SpanKind::Admit, 0, 0, 0),
            span(SpanKind::QueueWait, 0, 5, 1),
            span(SpanKind::QueueWait, 1, 5, 2),
            span(SpanKind::Execute, 5, 7, 3),
            span(SpanKind::Service, 5, 7, 4),
        ];
        let doc = chrome_trace(&spans, 0);
        // Round-trip through the crate's own parser.
        let parsed = Json::parse(&doc.dump()).unwrap();
        assert_eq!(parsed, doc);
        let pairs = check_nesting(&parsed).unwrap();
        assert_eq!(pairs, spans.len());
        // integer microsecond timestamps
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let exec_b = events
            .iter()
            .find(|e| {
                e.get("ph").and_then(Json::as_str) == Some("B")
                    && e.get("name").and_then(Json::as_str) == Some("execute")
            })
            .unwrap();
        assert_eq!(exec_b.get("ts").unwrap().as_i64(), Some(5000));
        assert_eq!(
            exec_b.get("args").unwrap().get("bucket").unwrap().as_i64(),
            Some(3)
        );
        assert_eq!(
            parsed.get("otherData").unwrap().get("dropped").unwrap().as_i64(),
            Some(0)
        );
    }

    #[test]
    fn nesting_checker_catches_imbalance() {
        let doc = Json::parse(
            r#"{"traceEvents":[
                {"ph":"B","pid":1,"tid":1,"ts":0,"name":"x"},
                {"ph":"E","pid":1,"tid":2,"ts":1}
            ]}"#,
        )
        .unwrap();
        assert!(check_nesting(&doc).is_err());
    }
}
