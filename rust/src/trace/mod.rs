//! `mpx::trace` — always-on span tracing for the serve and trainer
//! pipelines.
//!
//! Design constraints, in order:
//!
//! 1. **Cheap enough to leave on.**  Recording a span is: one atomic
//!    load (enabled?), one atomic fetch-add (sequence number), one
//!    shard mutex lock, one fixed-slot write into a preallocated
//!    ring.  No allocation after [`Tracer::new`], no syscalls, no
//!    formatting — timestamps are the [`Clock`]'s `Duration` offsets
//!    and attributes are three raw `u64`s whose meaning is fixed per
//!    [`SpanKind`].  The saturated-regime overhead is measured by
//!    `benches/serve_throughput.rs` (`BENCH_trace.json`) and held
//!    under 2%.
//! 2. **Bounded memory.**  Each shard is a fixed-capacity ring that
//!    drops the *oldest* span on overflow (a live service wants the
//!    recent timeline); the drop count is kept so exports can say
//!    what is missing.
//! 3. **Deterministic under the virtual clock.**  The tracer reads
//!    time through the same [`Clock`] the engine runs on, so the
//!    simulation harness ([`crate::serve::sched::simulate`]) produces
//!    bit-identical traces run-to-run, and tests assert span
//!    arithmetic as exact equalities (queue-wait + service ==
//!    observed latency — see `rust/tests/serve_sim.rs`).
//!
//! Exports (see [`chrome`]): Chrome trace-event JSON for Perfetto,
//! the `GET /debug/trace` transport endpoint, and the
//! [`ServiceSample`] records the ROADMAP's closed-loop planner
//! consumes as its calibration input.

pub mod chrome;

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::serve::clock::Clock;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// The `[trace]` config table (see `docs/CONFIG.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Record spans at all.  Off by default: the engine behaves
    /// identically either way, tracing only adds the record calls.
    pub enabled: bool,
    /// Ring capacity in spans, split across the tracer's shards.
    /// Memory is `buffer_spans × size_of::<Span>()` (64 B), bounded
    /// for the life of the process.
    pub buffer_spans: usize,
    /// Write a Chrome trace-event JSON file here at the end of the
    /// run (`mpx serve --trace-out trace.json`; load in Perfetto).
    pub trace_out: Option<String>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, buffer_spans: 65_536, trace_out: None }
    }
}

impl TraceConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.enabled && self.buffer_spans == 0 {
            anyhow::bail!("trace.buffer_spans must be ≥ 1 when enabled");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Span model
// ---------------------------------------------------------------------------

/// What a span measures.  The taxonomy is fixed (an enum, not
/// strings) so spans stay `Copy` and the hot path never formats.
/// Attribute meaning per kind is documented on the variant; the
/// Chrome exporter names them (`docs/OBSERVABILITY.md` has the full
/// table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Instant: a request entered a lane queue.  `a`=lane, `b`=request id.
    Admit,
    /// Admission → dispatch into a batch.  `a`=lane, `b`=request id.
    QueueWait,
    /// Dispatch → completion, per request.  `a`=lane, `b`=request id.
    Service,
    /// Dispatch → completion, per *batch* — the calibration signal
    /// ([`ServiceSample`]).  `a`=lane, `b`=bucket (padded rows),
    /// `c`=real rows taken.
    Execute,
    /// Worker-side batch padding/packing.  `a`=lane, `b`=bucket,
    /// `c`=real rows.
    Pack,
    /// Transport wrote the result chunk to the client socket — one
    /// span per request, so a keep-alive connection shows one egress
    /// per streamed completion.  `a`=lane, `b`=request id.
    Egress,
    /// Instant: the reactor accepted a connection.  `a`=connection
    /// ordinal (the running `connections` counter).
    Accept,
    /// Instant: a connection was evicted with `408` because its
    /// whole-request deadline or inter-byte read budget ran out.
    /// `a`=connection ordinal.
    ReadDeadline,
    /// One whole trainer step.  `a`=step index, `b`=grads finite (0/1).
    TrainStep,
    /// Trainer phase: parameter/input cast. `a`=step index.
    Cast,
    /// Trainer phase: forward. `a`=step index.
    Forward,
    /// Trainer phase: backward. `a`=step index.
    Backward,
    /// Trainer phase: fused unscale + finiteness scan. `a`=step index.
    UnscaleScan,
    /// Trainer phase: optimizer update. `a`=step index.
    Optim,
    /// Instant: the loss scale moved.  `a`=old scale (f32 bits),
    /// `b`=new scale (f32 bits), `c`=reason (0 overflow backoff,
    /// 1 periodic growth).
    LossScale,
}

impl SpanKind {
    /// Stable display name (Chrome event `name`).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Service => "service",
            SpanKind::Execute => "execute",
            SpanKind::Pack => "pack",
            SpanKind::Egress => "egress",
            SpanKind::Accept => "accept",
            SpanKind::ReadDeadline => "read_deadline",
            SpanKind::TrainStep => "train_step",
            SpanKind::Cast => "cast",
            SpanKind::Forward => "forward",
            SpanKind::Backward => "backward",
            SpanKind::UnscaleScan => "unscale_scan",
            SpanKind::Optim => "optim",
            SpanKind::LossScale => "loss_scale",
        }
    }

    /// Attribute names for (`a`, `b`, `c`), in order (Chrome `args`).
    pub fn attr_names(self) -> [&'static str; 3] {
        match self {
            SpanKind::Admit | SpanKind::QueueWait | SpanKind::Service => {
                ["lane", "id", "_"]
            }
            SpanKind::Execute | SpanKind::Pack => ["lane", "bucket", "rows"],
            SpanKind::Egress => ["lane", "id", "_"],
            SpanKind::Accept | SpanKind::ReadDeadline => ["conn", "_", "_"],
            SpanKind::TrainStep => ["step", "finite", "_"],
            SpanKind::Cast
            | SpanKind::Forward
            | SpanKind::Backward
            | SpanKind::UnscaleScan
            | SpanKind::Optim => ["step", "_", "_"],
            SpanKind::LossScale => ["old_bits", "new_bits", "grew"],
        }
    }

    /// Zero-duration marker kinds (exported as instants).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Admit
                | SpanKind::LossScale
                | SpanKind::Accept
                | SpanKind::ReadDeadline
        )
    }
}

/// One recorded span.  64 bytes, `Copy`: rings hold these inline and
/// snapshots are `memcpy`s.  Times are [`Clock`] offsets (`Duration`
/// since the clock's epoch), *not* wall datetimes — which is exactly
/// what makes virtual-clock traces bit-deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    pub start: Duration,
    pub end: Duration,
    /// Global record order (monotone across threads).
    pub seq: u64,
    /// First attribute — see [`SpanKind`] for meaning.
    pub a: u64,
    /// Second attribute.
    pub b: u64,
    /// Third attribute.
    pub c: u64,
}

impl Span {
    pub fn duration(&self) -> Duration {
        self.end.saturating_sub(self.start)
    }
}

const PLACEHOLDER: Span = Span {
    kind: SpanKind::Admit,
    start: Duration::ZERO,
    end: Duration::ZERO,
    seq: 0,
    a: 0,
    b: 0,
    c: 0,
};

// ---------------------------------------------------------------------------
// Ring + Tracer
// ---------------------------------------------------------------------------

/// Fixed-capacity drop-oldest span ring.  `spans` is fully
/// preallocated at construction; `write` wraps and the `dropped`
/// counter says how many old spans the wrap overwrote.
struct Ring {
    spans: Vec<Span>,
    /// Next write slot.
    next: usize,
    /// Live spans (≤ capacity).
    len: usize,
    dropped: u64,
}

impl Ring {
    fn with_capacity(cap: usize) -> Ring {
        Ring { spans: vec![PLACEHOLDER; cap], next: 0, len: 0, dropped: 0 }
    }

    fn push(&mut self, span: Span) {
        let cap = self.spans.len();
        if cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.len == cap {
            self.dropped += 1; // overwriting the oldest
        } else {
            self.len += 1;
        }
        self.spans[self.next] = span;
        self.next = (self.next + 1) % cap;
    }

    /// Live spans oldest-first.
    fn drain_ordered(&self, out: &mut Vec<Span>) {
        let cap = self.spans.len();
        if cap == 0 {
            return;
        }
        let start = (self.next + cap - self.len) % cap;
        for k in 0..self.len {
            out.push(self.spans[(start + k) % cap]);
        }
    }
}

/// How many independent rings a tracer keeps.  Threads hash onto
/// shards so concurrent workers rarely contend on one mutex; a
/// single-threaded run (the virtual-clock simulation) always lands on
/// one shard and its ring order *is* record order.
const SHARDS: usize = 16;

/// The span recorder handle.  Cloned via `Arc` into every component
/// that instruments itself; all methods take `&self`.
pub struct Tracer {
    clock: Arc<dyn Clock>,
    enabled: AtomicBool,
    seq: AtomicU64,
    shards: Vec<Mutex<Ring>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.is_enabled())
            .field("spans", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl Tracer {
    /// A tracer with `buffer_spans` total capacity split evenly
    /// across the shards (each shard gets at least one slot).
    pub fn new(clock: Arc<dyn Clock>, buffer_spans: usize) -> Tracer {
        let per_shard = (buffer_spans / SHARDS).max(1);
        Tracer {
            clock,
            enabled: AtomicBool::new(true),
            seq: AtomicU64::new(0),
            shards: (0..SHARDS)
                .map(|_| Mutex::new(Ring::with_capacity(per_shard)))
                .collect(),
        }
    }

    /// Build from config: `None` when tracing is disabled, so callers
    /// carry an `Option<Arc<Tracer>>` and pay nothing when off.
    pub fn from_config(
        clock: Arc<dyn Clock>,
        cfg: &TraceConfig,
    ) -> Option<Arc<Tracer>> {
        cfg.enabled.then(|| Arc::new(Tracer::new(clock, cfg.buffer_spans)))
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording at runtime (snapshot/export keeps working).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The tracer's clock — instrumentation sites read timestamps
    /// here so engine and tracer share one time base.
    pub fn now(&self) -> Duration {
        self.clock.now()
    }

    /// Record a span with explicit timestamps (the caller read them
    /// off the shared clock around the work being measured).
    pub fn record(
        &self,
        kind: SpanKind,
        start: Duration,
        end: Duration,
        a: u64,
        b: u64,
        c: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let span = Span { kind, start, end, seq, a, b, c };
        let mut hasher = DefaultHasher::new();
        std::thread::current().id().hash(&mut hasher);
        let shard = (hasher.finish() as usize) % self.shards.len();
        self.shards[shard].lock().unwrap().push(span);
    }

    /// Record an instant marker (`start == end == at`).
    pub fn instant(&self, kind: SpanKind, at: Duration, a: u64, b: u64, c: u64) {
        self.record(kind, at, at, a, b, c);
    }

    /// Live span count across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped to ring overflow (or recorded against a
    /// zero-capacity ring).
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().dropped).sum()
    }

    /// Copy out every live span, ordered by `(start, seq)` — a total
    /// deterministic order: `seq` is globally monotone, so even spans
    /// sharing a start instant sort identically run-to-run under the
    /// virtual clock.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            shard.lock().unwrap().drain_ordered(&mut out);
        }
        out.sort_by_key(|s| (s.start, s.seq));
        out
    }
}

// ---------------------------------------------------------------------------
// ServiceSample — the planner's calibration input
// ---------------------------------------------------------------------------

/// One measured batch execution, in exactly the shape the
/// `[serve.planner]` linear service model (`overhead_us + per_row_us ×
/// rows`) fits against: padded batch rows in, measured microseconds
/// out.  Derived from [`SpanKind::Execute`] spans and persisted next
/// to the serving artifacts (`service_samples.json`) so the
/// ROADMAP's closed-loop planner has real data instead of config
/// constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceSample {
    /// Lane index (order matches the run's lane list).
    pub lane: usize,
    /// Padded rows executed (the bucket size — what the compiled
    /// executable actually ran, hence what cost scales with).
    pub batch_rows: usize,
    /// Measured execution time, microseconds.
    pub exec_us: u64,
}

/// Extract the calibration records from a span snapshot.
pub fn service_samples(spans: &[Span]) -> Vec<ServiceSample> {
    spans
        .iter()
        .filter(|s| s.kind == SpanKind::Execute)
        .map(|s| ServiceSample {
            lane: s.a as usize,
            batch_rows: s.b as usize,
            exec_us: s.duration().as_micros().min(u64::MAX as u128) as u64,
        })
        .collect()
}

/// Serialize samples as the documented JSON schema
/// (`{"service_samples": [{"lane": .., "batch_rows": .., "exec_us": ..}]}`).
pub fn samples_json(samples: &[ServiceSample]) -> Json {
    let rows = samples
        .iter()
        .map(|s| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("lane".to_string(), Json::Num(s.lane as f64));
            m.insert("batch_rows".to_string(), Json::Num(s.batch_rows as f64));
            m.insert("exec_us".to_string(), Json::Num(s.exec_us as f64));
            Json::Obj(m)
        })
        .collect();
    let mut top = std::collections::BTreeMap::new();
    top.insert("service_samples".to_string(), Json::Arr(rows));
    Json::Obj(top)
}

/// Write `samples_json` to `path` (pretty enough: one compact line).
pub fn write_service_samples(
    path: &std::path::Path,
    samples: &[ServiceSample],
) -> anyhow::Result<()> {
    std::fs::write(path, samples_json(samples).dump() + "\n")
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::clock::VirtualClock;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn test_tracer(cap: usize) -> Tracer {
        Tracer::new(Arc::new(VirtualClock::new()), cap)
    }

    #[test]
    fn records_and_snapshots_in_time_order() {
        let t = test_tracer(1024);
        t.record(SpanKind::Service, ms(5), ms(9), 0, 1, 0);
        t.record(SpanKind::QueueWait, ms(1), ms(5), 0, 1, 0);
        t.instant(SpanKind::Admit, ms(1), 0, 1, 0);
        let spans = t.snapshot();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].kind, SpanKind::QueueWait); // earlier seq wins tie
        assert_eq!(spans[1].kind, SpanKind::Admit);
        assert_eq!(spans[2].kind, SpanKind::Service);
        assert_eq!(spans[0].duration(), ms(4));
        assert_eq!(spans[2].duration(), ms(4));
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        // Single thread → single shard: per-shard capacity is
        // buffer/SHARDS, so 32 total gives this thread exactly 2 slots.
        let t = test_tracer(32);
        for i in 0..5u64 {
            t.record(SpanKind::Execute, ms(i), ms(i + 1), 0, 8, 8);
        }
        let spans = t.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(t.dropped(), 3);
        // the *newest* spans survive
        assert_eq!(spans[0].start, ms(3));
        assert_eq!(spans[1].start, ms(4));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = test_tracer(64);
        t.set_enabled(false);
        t.record(SpanKind::Service, ms(0), ms(1), 0, 0, 0);
        assert!(t.is_empty());
        t.set_enabled(true);
        t.record(SpanKind::Service, ms(0), ms(1), 0, 0, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn service_samples_come_from_execute_spans_only() {
        let t = test_tracer(1024);
        t.record(SpanKind::QueueWait, ms(0), ms(4), 1, 7, 0);
        t.record(SpanKind::Execute, ms(4), ms(6), 1, 8, 5);
        t.record(SpanKind::Execute, ms(6), ms(9), 0, 16, 16);
        let samples = service_samples(&t.snapshot());
        assert_eq!(
            samples,
            vec![
                ServiceSample { lane: 1, batch_rows: 8, exec_us: 2000 },
                ServiceSample { lane: 0, batch_rows: 16, exec_us: 3000 },
            ]
        );
        let doc = Json::parse(&samples_json(&samples).dump()).unwrap();
        let rows = doc.get("service_samples").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("batch_rows").unwrap().as_i64(), Some(8));
        assert_eq!(rows[1].get("exec_us").unwrap().as_i64(), Some(3000));
    }

    #[test]
    fn concurrent_recording_keeps_every_span() {
        let t = Arc::new(test_tracer(SHARDS * 64));
        std::thread::scope(|scope| {
            for w in 0..4u64 {
                let t = t.clone();
                scope.spawn(move || {
                    for i in 0..16u64 {
                        t.record(
                            SpanKind::Service,
                            ms(w * 100 + i),
                            ms(w * 100 + i + 1),
                            w,
                            i,
                            0,
                        );
                    }
                });
            }
        });
        let spans = t.snapshot();
        assert_eq!(spans.len() as u64 + t.dropped(), 64);
        // snapshot order is globally sorted
        for pair in spans.windows(2) {
            assert!(pair[0].start <= pair[1].start);
        }
    }

    #[test]
    fn config_validation() {
        let mut cfg = TraceConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.enabled = true;
        cfg.buffer_spans = 0;
        assert!(cfg.validate().is_err());
        cfg.buffer_spans = 1;
        assert!(cfg.validate().is_ok());
        // from_config: disabled → no tracer
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        assert!(Tracer::from_config(clock.clone(), &TraceConfig::default())
            .is_none());
        assert!(Tracer::from_config(clock, &cfg).is_some());
    }
}
