//! `mpx::trace` — always-on span tracing for the serve and trainer
//! pipelines.
//!
//! Design constraints, in order:
//!
//! 1. **Cheap enough to leave on.**  Recording a span is: one atomic
//!    load (enabled?), one atomic fetch-add (sequence number), one
//!    shard mutex lock, one fixed-slot write into a preallocated
//!    ring.  No allocation after [`Tracer::new`], no syscalls, no
//!    formatting — timestamps are the [`Clock`]'s `Duration` offsets
//!    and attributes are three raw `u64`s whose meaning is fixed per
//!    [`SpanKind`].  The saturated-regime overhead is measured by
//!    `benches/serve_throughput.rs` (`BENCH_trace.json`) and held
//!    under 2%.
//! 2. **Bounded memory.**  Each shard is a fixed-capacity ring that
//!    drops the *oldest* span on overflow (a live service wants the
//!    recent timeline); the drop count is kept so exports can say
//!    what is missing.
//! 3. **Deterministic under the virtual clock.**  The tracer reads
//!    time through the same [`Clock`] the engine runs on, so the
//!    simulation harness ([`crate::serve::sched::simulate`]) produces
//!    bit-identical traces run-to-run, and tests assert span
//!    arithmetic as exact equalities (queue-wait + service ==
//!    observed latency — see `rust/tests/serve_sim.rs`).
//!
//! Exports (see [`chrome`]): Chrome trace-event JSON for Perfetto,
//! the `GET /debug/trace` transport endpoint, and the
//! [`ServiceSample`] records the ROADMAP's closed-loop planner
//! consumes as its calibration input.

pub mod chrome;

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::serve::clock::Clock;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// The `[trace]` config table (see `docs/CONFIG.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Record spans at all.  Off by default: the engine behaves
    /// identically either way, tracing only adds the record calls.
    pub enabled: bool,
    /// Ring capacity in spans, split across the tracer's shards.
    /// Memory is `buffer_spans × size_of::<Span>()` (64 B), bounded
    /// for the life of the process.
    pub buffer_spans: usize,
    /// Write a Chrome trace-event JSON file here at the end of the
    /// run (`mpx serve --trace-out trace.json`; load in Perfetto).
    pub trace_out: Option<String>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, buffer_spans: 65_536, trace_out: None }
    }
}

impl TraceConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.enabled && self.buffer_spans == 0 {
            anyhow::bail!("trace.buffer_spans must be ≥ 1 when enabled");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Span model
// ---------------------------------------------------------------------------

/// What a span measures.  The taxonomy is fixed (an enum, not
/// strings) so spans stay `Copy` and the hot path never formats.
/// Attribute meaning per kind is documented on the variant; the
/// Chrome exporter names them (`docs/OBSERVABILITY.md` has the full
/// table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Instant: a request entered a lane queue.  `a`=lane, `b`=request id.
    Admit,
    /// Admission → dispatch into a batch.  `a`=lane, `b`=request id.
    QueueWait,
    /// Dispatch → completion, per request.  `a`=lane, `b`=request id.
    Service,
    /// Dispatch → completion, per *batch* — the calibration signal
    /// ([`ServiceSample`]).  `a`=lane, `b`=bucket (padded rows),
    /// `c`=real rows taken.
    Execute,
    /// Worker-side batch padding/packing.  `a`=lane, `b`=bucket,
    /// `c`=real rows.
    Pack,
    /// Transport wrote the result chunk to the client socket — one
    /// span per request, so a keep-alive connection shows one egress
    /// per streamed completion.  `a`=lane, `b`=request id.
    Egress,
    /// Instant: the reactor accepted a connection.  `a`=connection
    /// ordinal (the running `connections` counter).
    Accept,
    /// Instant: a connection was evicted with `408` because its
    /// whole-request deadline or inter-byte read budget ran out.
    /// `a`=connection ordinal.
    ReadDeadline,
    /// Instant: the drift monitor adopted a new plan
    /// (`Scheduler::adopt_plan`).  `a`=replan ordinal, `b`=lanes whose
    /// bucket set or flush timeout changed, `c`=1 when the full plan
    /// was adopted / 0 when uncompiled buckets forced the feasible
    /// subset fallback.
    Replan,
    /// One whole trainer step.  `a`=step index, `b`=grads finite (0/1).
    TrainStep,
    /// Trainer phase: parameter/input cast. `a`=step index.
    Cast,
    /// Trainer phase: forward. `a`=step index.
    Forward,
    /// Trainer phase: backward. `a`=step index.
    Backward,
    /// Trainer phase: fused unscale + finiteness scan. `a`=step index.
    UnscaleScan,
    /// Trainer phase: optimizer update. `a`=step index.
    Optim,
    /// Instant: a loss scale moved.  `a`=old scale (f32 bits),
    /// `b`=new scale (f32 bits), `c`=`grew | (group_idx << 1)` —
    /// bit 0 is the reason (0 overflow backoff, 1 periodic growth),
    /// the rest is the scaling-policy group index (0 for the global
    /// policies, so their emitted values are unchanged; the adaptive
    /// policy emits one instant per layer group whose scale moved).
    LossScale,
}

impl SpanKind {
    /// Stable display name (Chrome event `name`).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Service => "service",
            SpanKind::Execute => "execute",
            SpanKind::Pack => "pack",
            SpanKind::Egress => "egress",
            SpanKind::Accept => "accept",
            SpanKind::ReadDeadline => "read_deadline",
            SpanKind::Replan => "replan",
            SpanKind::TrainStep => "train_step",
            SpanKind::Cast => "cast",
            SpanKind::Forward => "forward",
            SpanKind::Backward => "backward",
            SpanKind::UnscaleScan => "unscale_scan",
            SpanKind::Optim => "optim",
            SpanKind::LossScale => "loss_scale",
        }
    }

    /// Attribute names for (`a`, `b`, `c`), in order (Chrome `args`).
    pub fn attr_names(self) -> [&'static str; 3] {
        match self {
            SpanKind::Admit | SpanKind::QueueWait | SpanKind::Service => {
                ["lane", "id", "_"]
            }
            SpanKind::Execute | SpanKind::Pack => ["lane", "bucket", "rows"],
            SpanKind::Egress => ["lane", "id", "_"],
            SpanKind::Accept | SpanKind::ReadDeadline => ["conn", "_", "_"],
            SpanKind::Replan => ["replan", "lanes_changed", "full"],
            SpanKind::TrainStep => ["step", "finite", "_"],
            SpanKind::Cast
            | SpanKind::Forward
            | SpanKind::Backward
            | SpanKind::UnscaleScan
            | SpanKind::Optim => ["step", "_", "_"],
            SpanKind::LossScale => ["old_bits", "new_bits", "grew_group"],
        }
    }

    /// Zero-duration marker kinds (exported as instants).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Admit
                | SpanKind::LossScale
                | SpanKind::Accept
                | SpanKind::ReadDeadline
                | SpanKind::Replan
        )
    }
}

/// One recorded span.  64 bytes, `Copy`: rings hold these inline and
/// snapshots are `memcpy`s.  Times are [`Clock`] offsets (`Duration`
/// since the clock's epoch), *not* wall datetimes — which is exactly
/// what makes virtual-clock traces bit-deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    pub start: Duration,
    pub end: Duration,
    /// Global record order (monotone across threads).
    pub seq: u64,
    /// First attribute — see [`SpanKind`] for meaning.
    pub a: u64,
    /// Second attribute.
    pub b: u64,
    /// Third attribute.
    pub c: u64,
}

impl Span {
    pub fn duration(&self) -> Duration {
        self.end.saturating_sub(self.start)
    }
}

const PLACEHOLDER: Span = Span {
    kind: SpanKind::Admit,
    start: Duration::ZERO,
    end: Duration::ZERO,
    seq: 0,
    a: 0,
    b: 0,
    c: 0,
};

// ---------------------------------------------------------------------------
// Ring + Tracer
// ---------------------------------------------------------------------------

/// Fixed-capacity drop-oldest span ring.  `spans` is fully
/// preallocated at construction; `write` wraps and the `dropped`
/// counter says how many old spans the wrap overwrote.
struct Ring {
    spans: Vec<Span>,
    /// Next write slot.
    next: usize,
    /// Live spans (≤ capacity).
    len: usize,
    dropped: u64,
}

impl Ring {
    fn with_capacity(cap: usize) -> Ring {
        Ring { spans: vec![PLACEHOLDER; cap], next: 0, len: 0, dropped: 0 }
    }

    fn push(&mut self, span: Span) {
        let cap = self.spans.len();
        if cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.len == cap {
            self.dropped += 1; // overwriting the oldest
        } else {
            self.len += 1;
        }
        self.spans[self.next] = span;
        self.next = (self.next + 1) % cap;
    }

    /// Live spans oldest-first.
    fn drain_ordered(&self, out: &mut Vec<Span>) {
        let cap = self.spans.len();
        if cap == 0 {
            return;
        }
        let start = (self.next + cap - self.len) % cap;
        for k in 0..self.len {
            out.push(self.spans[(start + k) % cap]);
        }
    }
}

/// How many independent rings a tracer keeps.  Threads hash onto
/// shards so concurrent workers rarely contend on one mutex; a
/// single-threaded run (the virtual-clock simulation) always lands on
/// one shard and its ring order *is* record order.
const SHARDS: usize = 16;

/// The span recorder handle.  Cloned via `Arc` into every component
/// that instruments itself; all methods take `&self`.
pub struct Tracer {
    clock: Arc<dyn Clock>,
    enabled: AtomicBool,
    seq: AtomicU64,
    shards: Vec<Mutex<Ring>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.is_enabled())
            .field("spans", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl Tracer {
    /// A tracer with `buffer_spans` total capacity split evenly
    /// across the shards (each shard gets at least one slot).
    pub fn new(clock: Arc<dyn Clock>, buffer_spans: usize) -> Tracer {
        let per_shard = (buffer_spans / SHARDS).max(1);
        Tracer {
            clock,
            enabled: AtomicBool::new(true),
            seq: AtomicU64::new(0),
            shards: (0..SHARDS)
                .map(|_| Mutex::new(Ring::with_capacity(per_shard)))
                .collect(),
        }
    }

    /// Build from config: `None` when tracing is disabled, so callers
    /// carry an `Option<Arc<Tracer>>` and pay nothing when off.
    pub fn from_config(
        clock: Arc<dyn Clock>,
        cfg: &TraceConfig,
    ) -> Option<Arc<Tracer>> {
        cfg.enabled.then(|| Arc::new(Tracer::new(clock, cfg.buffer_spans)))
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording at runtime (snapshot/export keeps working).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The tracer's clock — instrumentation sites read timestamps
    /// here so engine and tracer share one time base.
    pub fn now(&self) -> Duration {
        self.clock.now()
    }

    /// Record a span with explicit timestamps (the caller read them
    /// off the shared clock around the work being measured).
    pub fn record(
        &self,
        kind: SpanKind,
        start: Duration,
        end: Duration,
        a: u64,
        b: u64,
        c: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let span = Span { kind, start, end, seq, a, b, c };
        let mut hasher = DefaultHasher::new();
        std::thread::current().id().hash(&mut hasher);
        let shard = (hasher.finish() as usize) % self.shards.len();
        self.shards[shard].lock().unwrap().push(span);
    }

    /// Record an instant marker (`start == end == at`).
    pub fn instant(&self, kind: SpanKind, at: Duration, a: u64, b: u64, c: u64) {
        self.record(kind, at, at, a, b, c);
    }

    /// Live span count across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped to ring overflow (or recorded against a
    /// zero-capacity ring).
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().dropped).sum()
    }

    /// Copy out every live span, ordered by `(start, seq)` — a total
    /// deterministic order: `seq` is globally monotone, so even spans
    /// sharing a start instant sort identically run-to-run under the
    /// virtual clock.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            shard.lock().unwrap().drain_ordered(&mut out);
        }
        out.sort_by_key(|s| (s.start, s.seq));
        out
    }
}

// ---------------------------------------------------------------------------
// ServiceSample — the planner's calibration input
// ---------------------------------------------------------------------------

/// A run's lane identity, index-aligned with the scheduler's lane
/// order: the lane *name* (e.g. `vit_tiny/chat`) plus the precision
/// tag of its artifacts.  Execute spans carry only the run-local lane
/// index; the identity list maps that index to a key that stays
/// stable across runs whose lane order differs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneId {
    pub name: String,
    pub precision: String,
}

impl LaneId {
    pub fn new(name: impl Into<String>, precision: impl Into<String>) -> LaneId {
        LaneId { name: name.into(), precision: precision.into() }
    }
}

/// One measured batch execution, in exactly the shape the
/// `[serve.planner]` linear service model (`overhead_us + per_row_us ×
/// rows`) fits against: padded batch rows in, measured microseconds
/// out.  Derived from [`SpanKind::Execute`] spans and persisted next
/// to the serving artifacts (`service_samples.json`) for
/// [`crate::serve::calibrate`] to fit.  Records key on the lane
/// *name* + precision tag, not the run-local lane index — indices
/// mis-attribute samples across runs whose lane order differs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceSample {
    /// Lane name (stable across runs; see [`LaneId`]).
    pub lane: String,
    /// Precision tag of the lane's artifacts (`fp32` / `mixed_f16` /
    /// `mixed_bf16`).
    pub precision: String,
    /// Padded rows executed (the bucket size — what the compiled
    /// executable actually ran, hence what cost scales with).
    pub batch_rows: usize,
    /// Measured execution time, microseconds.
    pub exec_us: u64,
}

impl ServiceSample {
    /// The calibration key: one fit per (lane, precision).
    pub fn lane_key(&self) -> (&str, &str) {
        (&self.lane, &self.precision)
    }
}

/// Extract the calibration records from a span snapshot.  `lanes`
/// maps each Execute span's run-local lane index to its stable
/// identity; an out-of-range index (malformed span) gets a synthetic
/// `#<index>` name rather than silently vanishing.
pub fn service_samples(spans: &[Span], lanes: &[LaneId]) -> Vec<ServiceSample> {
    spans
        .iter()
        .filter(|s| s.kind == SpanKind::Execute)
        .map(|s| {
            let (lane, precision) = match lanes.get(s.a as usize) {
                Some(id) => (id.name.clone(), id.precision.clone()),
                None => (format!("#{}", s.a), "unknown".to_string()),
            };
            ServiceSample {
                lane,
                precision,
                batch_rows: s.b as usize,
                exec_us: s.duration().as_micros().min(u64::MAX as u128) as u64,
            }
        })
        .collect()
}

/// Serialize samples as the documented JSON schema:
/// `{"service_samples": [{"lane": "...", "precision": "...",
/// "batch_rows": .., "exec_us": ..}]}`.
pub fn samples_json(samples: &[ServiceSample]) -> Json {
    let rows = samples
        .iter()
        .map(|s| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("lane".to_string(), Json::Str(s.lane.clone()));
            m.insert("precision".to_string(), Json::Str(s.precision.clone()));
            m.insert("batch_rows".to_string(), Json::Num(s.batch_rows as f64));
            m.insert("exec_us".to_string(), Json::Num(s.exec_us as f64));
            Json::Obj(m)
        })
        .collect();
    let mut top = std::collections::BTreeMap::new();
    top.insert("service_samples".to_string(), Json::Arr(rows));
    Json::Obj(top)
}

/// Parse a `service_samples.json` document back into records.
/// Malformed rows — and rows in the legacy integer-`lane` schema,
/// which cannot be attributed to a named lane — are skipped, not
/// fatal: one bad record must not void a calibration history.
pub fn parse_service_samples(doc: &Json) -> Vec<ServiceSample> {
    let Some(rows) = doc.get("service_samples").and_then(|v| v.as_arr()) else {
        return Vec::new();
    };
    rows.iter()
        .filter_map(|r| {
            Some(ServiceSample {
                lane: r.get("lane")?.as_str()?.to_string(),
                precision: r.get("precision")?.as_str()?.to_string(),
                batch_rows: r.get("batch_rows")?.as_i64()?.try_into().ok()?,
                exec_us: r.get("exec_us")?.as_i64()?.try_into().ok()?,
            })
        })
        .collect()
}

/// Read and parse `service_samples.json`; a missing file is an empty
/// history (first run), an unparseable one is an error.
pub fn read_service_samples(
    path: &std::path::Path,
) -> anyhow::Result<Vec<ServiceSample>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(e) => anyhow::bail!("read {}: {e}", path.display()),
    };
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    Ok(parse_service_samples(&doc))
}

/// Default per-lane bound for the persisted sample history
/// ([`merge_service_samples`]).
pub const SERVICE_SAMPLE_CAP: usize = 4096;

/// Append `new` to `existing` under a per-(lane, precision) cap:
/// records stay in file order (oldest first) and when a lane exceeds
/// `cap` its *oldest* records drop — deterministically, so the same
/// history + run always persists the same file.
pub fn merge_service_samples(
    existing: Vec<ServiceSample>,
    new: &[ServiceSample],
    cap: usize,
) -> Vec<ServiceSample> {
    let mut all = existing;
    all.extend(new.iter().cloned());
    let mut counts: std::collections::BTreeMap<(String, String), usize> =
        std::collections::BTreeMap::new();
    for s in &all {
        *counts
            .entry((s.lane.clone(), s.precision.clone()))
            .or_insert(0) += 1;
    }
    // Per lane, skip the first (count − cap) records: drop-oldest.
    let mut to_skip: std::collections::BTreeMap<(String, String), usize> =
        counts
            .into_iter()
            .map(|(k, n)| (k, n.saturating_sub(cap)))
            .collect();
    all.retain(|s| {
        let skip = to_skip
            .get_mut(&(s.lane.clone(), s.precision.clone()))
            .expect("every sample was counted");
        if *skip > 0 {
            *skip -= 1;
            false
        } else {
            true
        }
    });
    all
}

/// Write `samples_json` to `path` (pretty enough: one compact line).
pub fn write_service_samples(
    path: &std::path::Path,
    samples: &[ServiceSample],
) -> anyhow::Result<()> {
    std::fs::write(path, samples_json(samples).dump() + "\n")
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::clock::VirtualClock;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn test_tracer(cap: usize) -> Tracer {
        Tracer::new(Arc::new(VirtualClock::new()), cap)
    }

    #[test]
    fn records_and_snapshots_in_time_order() {
        let t = test_tracer(1024);
        t.record(SpanKind::Service, ms(5), ms(9), 0, 1, 0);
        t.record(SpanKind::QueueWait, ms(1), ms(5), 0, 1, 0);
        t.instant(SpanKind::Admit, ms(1), 0, 1, 0);
        let spans = t.snapshot();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].kind, SpanKind::QueueWait); // earlier seq wins tie
        assert_eq!(spans[1].kind, SpanKind::Admit);
        assert_eq!(spans[2].kind, SpanKind::Service);
        assert_eq!(spans[0].duration(), ms(4));
        assert_eq!(spans[2].duration(), ms(4));
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        // Single thread → single shard: per-shard capacity is
        // buffer/SHARDS, so 32 total gives this thread exactly 2 slots.
        let t = test_tracer(32);
        for i in 0..5u64 {
            t.record(SpanKind::Execute, ms(i), ms(i + 1), 0, 8, 8);
        }
        let spans = t.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(t.dropped(), 3);
        // the *newest* spans survive
        assert_eq!(spans[0].start, ms(3));
        assert_eq!(spans[1].start, ms(4));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = test_tracer(64);
        t.set_enabled(false);
        t.record(SpanKind::Service, ms(0), ms(1), 0, 0, 0);
        assert!(t.is_empty());
        t.set_enabled(true);
        t.record(SpanKind::Service, ms(0), ms(1), 0, 0, 0);
        assert_eq!(t.len(), 1);
    }

    fn sample(
        lane: &str,
        precision: &str,
        batch_rows: usize,
        exec_us: u64,
    ) -> ServiceSample {
        ServiceSample {
            lane: lane.into(),
            precision: precision.into(),
            batch_rows,
            exec_us,
        }
    }

    #[test]
    fn service_samples_come_from_execute_spans_only() {
        let t = test_tracer(1024);
        t.record(SpanKind::QueueWait, ms(0), ms(4), 1, 7, 0);
        t.record(SpanKind::Execute, ms(4), ms(6), 1, 8, 5);
        t.record(SpanKind::Execute, ms(6), ms(9), 0, 16, 16);
        let lanes = vec![
            LaneId::new("vit_tiny/bulk", "fp32"),
            LaneId::new("vit_tiny/chat", "mixed_f16"),
        ];
        let samples = service_samples(&t.snapshot(), &lanes);
        assert_eq!(
            samples,
            vec![
                sample("vit_tiny/chat", "mixed_f16", 8, 2000),
                sample("vit_tiny/bulk", "fp32", 16, 3000),
            ]
        );
        assert_eq!(samples[0].lane_key(), ("vit_tiny/chat", "mixed_f16"));
        let doc = Json::parse(&samples_json(&samples).dump()).unwrap();
        let rows = doc.get("service_samples").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("lane").unwrap().as_str(),
            Some("vit_tiny/chat")
        );
        assert_eq!(
            rows[0].get("precision").unwrap().as_str(),
            Some("mixed_f16")
        );
        assert_eq!(rows[0].get("batch_rows").unwrap().as_i64(), Some(8));
        assert_eq!(rows[1].get("exec_us").unwrap().as_i64(), Some(3000));
        // The schema round-trips through its own parser.
        assert_eq!(parse_service_samples(&doc), samples);
        // An out-of-range lane index degrades to a synthetic name
        // instead of dropping the measurement.
        let orphan = service_samples(&t.snapshot(), &lanes[..1]);
        assert_eq!(orphan[0].lane, "#1");
        assert_eq!(orphan[0].precision, "unknown");
    }

    #[test]
    fn legacy_integer_lane_records_are_skipped_on_parse() {
        // The pre-name schema persisted run-local lane *indices*; they
        // cannot be attributed to a named lane, so a merge must drop
        // them rather than guess.
        let doc = Json::parse(
            r#"{"service_samples":[
                {"lane":0,"batch_rows":8,"exec_us":1320},
                {"lane":"m/chat","precision":"mixed_f16","batch_rows":4,"exec_us":840}
            ]}"#,
        )
        .unwrap();
        let parsed = parse_service_samples(&doc);
        assert_eq!(parsed, vec![sample("m/chat", "mixed_f16", 4, 840)]);
        // Entirely-foreign documents parse to empty, not errors.
        assert!(parse_service_samples(&Json::parse("{}").unwrap()).is_empty());
    }

    #[test]
    fn merge_keeps_order_and_drops_oldest_per_lane() {
        let existing = vec![
            sample("m/a", "fp32", 1, 100),
            sample("m/b", "fp32", 2, 200),
            sample("m/a", "fp32", 3, 300),
        ];
        let new = vec![
            sample("m/a", "fp32", 4, 400),
            sample("m/b", "fp32", 5, 500),
        ];
        // Cap 2 per lane: m/a has 3 records → its oldest (batch 1)
        // drops; m/b has 2 → both stay.  Relative order preserved.
        let merged = merge_service_samples(existing.clone(), &new, 2);
        assert_eq!(
            merged,
            vec![
                sample("m/b", "fp32", 2, 200),
                sample("m/a", "fp32", 3, 300),
                sample("m/a", "fp32", 4, 400),
                sample("m/b", "fp32", 5, 500),
            ]
        );
        // Same inputs → same output, bit for bit (deterministic
        // drop-oldest, no hashing).
        assert_eq!(merged, merge_service_samples(existing, &new, 2));
        // A generous cap keeps everything in order.
        let all = merge_service_samples(
            vec![sample("m/a", "fp32", 1, 100)],
            &[sample("m/a", "fp32", 2, 200)],
            SERVICE_SAMPLE_CAP,
        );
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].batch_rows, 1);
        // Same lane name at different precisions are distinct keys.
        let split = merge_service_samples(
            vec![sample("m/a", "fp32", 1, 100)],
            &[sample("m/a", "mixed_f16", 2, 200)],
            1,
        );
        assert_eq!(split.len(), 2);
    }

    #[test]
    fn read_service_samples_roundtrips_and_tolerates_absence() {
        let dir = std::env::temp_dir().join("mpx_trace_samples_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("service_samples.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_service_samples(&path).unwrap().is_empty());
        let samples = vec![
            sample("m/chat", "mixed_f16", 8, 1320),
            sample("m/bulk", "fp32", 4, 840),
        ];
        write_service_samples(&path, &samples).unwrap();
        assert_eq!(read_service_samples(&path).unwrap(), samples);
        std::fs::write(&path, "not json").unwrap();
        assert!(read_service_samples(&path).is_err());
    }

    #[test]
    fn replan_is_an_instant_serve_span() {
        assert!(SpanKind::Replan.is_instant());
        assert_eq!(SpanKind::Replan.name(), "replan");
        assert_eq!(
            SpanKind::Replan.attr_names(),
            ["replan", "lanes_changed", "full"]
        );
    }

    #[test]
    fn concurrent_recording_keeps_every_span() {
        let t = Arc::new(test_tracer(SHARDS * 64));
        std::thread::scope(|scope| {
            for w in 0..4u64 {
                let t = t.clone();
                scope.spawn(move || {
                    for i in 0..16u64 {
                        t.record(
                            SpanKind::Service,
                            ms(w * 100 + i),
                            ms(w * 100 + i + 1),
                            w,
                            i,
                            0,
                        );
                    }
                });
            }
        });
        let spans = t.snapshot();
        assert_eq!(spans.len() as u64 + t.dropped(), 64);
        // snapshot order is globally sorted
        for pair in spans.windows(2) {
            assert!(pair[0].start <= pair[1].start);
        }
    }

    #[test]
    fn config_validation() {
        let mut cfg = TraceConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.enabled = true;
        cfg.buffer_spans = 0;
        assert!(cfg.validate().is_err());
        cfg.buffer_spans = 1;
        assert!(cfg.validate().is_ok());
        // from_config: disabled → no tracer
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        assert!(Tracer::from_config(clock.clone(), &TraceConfig::default())
            .is_none());
        assert!(Tracer::from_config(clock, &cfg).is_some());
    }
}
