//! `mpx` — the MPX training framework launcher.
//!
//! Subcommands:
//!
//! * `train`          — run the fused single-device trainer.
//! * `train-ddp`      — run the simulated multi-device data-parallel
//!                      trainer (paper's cluster configuration).
//! * `list-artifacts` — what `make artifacts` produced.
//! * `inspect`        — manifest + HLO census of one artifact.
//! * `memory-report`  — Fig. 2-style memory table for a model preset.
//! * `scaling-sim`    — dynamic loss-scaling state-machine simulator.
//! * `serve`          — batched-inference serving engine
//!                      ([`mpx::serve`]): request queue, dynamic
//!                      batching, worker pool, latency report.

use anyhow::{Context, Result};

use mpx::cli::Args;
use mpx::config::{
    machine_profile, model_preset, Precision, ServeConfig, TrainConfig,
};
use mpx::data::SyntheticDataset;
use mpx::hlo::HloModule;
use mpx::memmodel::{roofline, ActivationModel};
use mpx::metrics::{train_prometheus, RunMetrics};
use mpx::pytree::DType;
use mpx::runtime::{
    read_scalar_f32, read_scalar_i32, ArtifactStore, BackendKind,
};
use mpx::scaling::{
    GroupState, LossScaler, OverflowInjector, PolicyKind, ScalingSpec,
};
use mpx::trainer::{checkpoint, DataParallelTrainer, FusedTrainer};
use mpx::util::{human_bytes, human_duration, rng::Rng};

const USAGE: &str = "usage: mpx <train|train-ddp|list-artifacts|inspect|memory-report|scaling-sim|serve> [flags]
  train          --model M --precision P --batch B --steps N [--seed S] [--config cfg.toml]
                 [--backend xla|host] [--checkpoint-every K --checkpoint-dir D]
                 [--metrics-csv path] [--metrics-prom path] [--resume ckpt]
                 [--scaling-policy dynamic|pinned|adaptive]  (preset override
                           for the [train.scaling] table; the fused trainer
                           accepts only its compiled-in policy)
  train-ddp      same flags, plus --shards N (--batch is per shard); owns the
                 scaling policy host-side, so `adaptive` keeps one scale per
                 layer group and checkpoints carry the per-group scaler
                 record (schema v2; v1 files still load)
  inspect        --artifact NAME
  memory-report  --model M [--batches 8,16,...] [--machine desktop|cluster]
  scaling-sim    [--steps N] [--overflow-prob p] [--period N]
  serve          --model M --precision P [--batch B --workers W --requests N]
                 [--backend xla|host]
                 [--max-workers W --autoscale-depth D] [--policy continuous|form_first]
                 [--precisions p1,p2 --lane-weights w1,w2] (multi-model lanes)
                 [--rate req_per_s --open-loop] [--queue-cap N --flush-ms T]
                 [--deadline-ms T] [--seed S] [--config cfg.toml]
                 [--listen ADDR]  serve over HTTP instead of synthetic load:
                           an event-driven reactor multiplexes keep-alive and
                           pipelined connections on one thread; POST /v1/infer
                           streams each completion back the moment its batch
                           finishes; GET /healthz + /metrics (Prometheus) +
                           /debug/trace (when tracing is on); SIGINT drains
                           gracefully.  Knobs in [serve.transport]
                           (max_connections, max_pipelined, read/request/
                           idle/drain timeouts)
                 [--trace-out PATH]  enable span tracing and write a Chrome
                           trace-event JSON file at the end of the run (load
                           it in Perfetto); ring size via [trace] buffer_spans
                 [--plan]  print the latency-aware bucket plan (which batch
                           sizes to AOT-compile, per-lane flush timeouts)
                           and exit; per-lane SLOs come from the config's
                           [serve.lanes.*] tables";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args, false),
        Some("train-ddp") => cmd_train(&args, true),
        Some("list-artifacts") => cmd_list(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("memory-report") => cmd_memory_report(&args),
        Some("scaling-sim") => cmd_scaling_sim(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn train_config_from(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get_str("config") {
        Some(path) => TrainConfig::from_toml_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.get_str("model") {
        cfg.model = m.to_string();
    }
    if let Some(p) = args.get_str("precision") {
        cfg.precision = Precision::parse(p)?;
    }
    if let Some(b) = args.get_usize("batch")? {
        cfg.batch = b;
    }
    if let Some(s) = args.get_u64("steps")? {
        cfg.steps = s;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(s) = args.get_usize("shards")? {
        cfg.shards = s;
    }
    if let Some(d) = args.get_str("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(b) = args.get_str("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(k) = args.get_u64("checkpoint-every")? {
        cfg.checkpoint_every = k;
    }
    if let Some(d) = args.get_str("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.to_string());
    }
    if let Some(e) = args.get_u64("log-every")? {
        cfg.log_every = e;
    }
    if let Some(p) = args.get_str("scaling-policy") {
        // Flag = preset override: replaces whatever the config's
        // [train.scaling] table said.  Knob tuning stays in TOML.
        cfg.scaling = Some(ScalingSpec::preset(PolicyKind::parse(p)?));
    }
    model_preset(&cfg.model)?;
    Ok(cfg)
}

fn cmd_train(args: &Args, ddp: bool) -> Result<()> {
    let cfg = train_config_from(args)?;
    let metrics_csv = args.get_str("metrics-csv").map(str::to_string);
    let metrics_prom = args.get_str("metrics-prom").map(str::to_string);
    let resume = args.get_str("resume").map(str::to_string);
    args.finish()?;

    let preset = model_preset(&cfg.model)?;
    let dataset = SyntheticDataset::new(&preset, cfg.seed);
    let mut metrics = match &metrics_csv {
        Some(p) => RunMetrics::with_csv(p)?,
        None => RunMetrics::new(),
    };

    let mut store =
        ArtifactStore::open_with(&cfg.artifacts_dir, cfg.backend)?;
    eprintln!(
        "[mpx] {} | model {} | precision {} | batch {}{} | {} steps | {} backend",
        if ddp { "data-parallel" } else { "fused" },
        cfg.model,
        cfg.precision.tag(),
        cfg.batch,
        if ddp { format!(" ×{} shards", cfg.shards) } else { String::new() },
        cfg.steps,
        cfg.backend,
    );

    let ckpt_every = cfg.checkpoint_every;
    let total = cfg.steps;
    let ckpt_dir = || {
        cfg.checkpoint_dir
            .clone()
            .unwrap_or_else(|| "checkpoints".into())
    };
    if ddp {
        let mut trainer = DataParallelTrainer::new(&mut store, cfg.clone())?;
        if let Some(path) = &resume {
            trainer.resume(path)?;
            eprintln!(
                "[mpx] resumed from {path} at step {}",
                trainer.step_index
            );
        }
        if ckpt_every > 0 {
            let dir = ckpt_dir();
            let mut done = 0;
            while done < total {
                let chunk = ckpt_every.min(total - done);
                trainer.run(&dataset, chunk, &mut metrics)?;
                done += chunk;
                let path = format!(
                    "{dir}/{}_ddp_{}.ckpt",
                    cfg.model, trainer.step_index
                );
                trainer.save_checkpoint(&path)?;
                eprintln!("[mpx] checkpoint → {path}");
            }
        } else {
            trainer.run(&dataset, total, &mut metrics)?;
        }
        write_metrics_prom(
            &metrics_prom,
            &metrics,
            &trainer.scaling_rows(),
        )?;
        persist_train_trace(&cfg.trace, trainer.tracer());
        summarize(&metrics);
    } else {
        let mut trainer = FusedTrainer::new(&mut store, cfg.clone())?;
        if let Some(path) = resume {
            let specs = trainer.manifest().inputs[..trainer.state().len()]
                .to_vec();
            // The fused machine round-trips through its state leaves;
            // the scaler record is the schema-v2 sidecar for tooling.
            let (step, leaves, _scaler) = checkpoint::load(&path, &specs)?;
            trainer.set_state(leaves)?;
            trainer.step_index = step;
            eprintln!("[mpx] resumed from {path} at step {step}");
        }
        if ckpt_every > 0 {
            let dir = ckpt_dir();
            let mut done = 0;
            while done < total {
                let chunk = ckpt_every.min(total - done);
                trainer.run(&dataset, chunk, &mut metrics)?;
                done += chunk;
                let path = format!(
                    "{dir}/{}_{}.ckpt",
                    cfg.model, trainer.step_index
                );
                let specs = trainer.manifest().inputs
                    [..trainer.state().len()]
                    .to_vec();
                checkpoint::save(
                    &path,
                    trainer.step_index,
                    &specs,
                    trainer.state(),
                    &fused_scaler_record(&trainer)?,
                )?;
                eprintln!("[mpx] checkpoint → {path}");
            }
        } else {
            trainer.run(&dataset, total, &mut metrics)?;
        }
        let rows = vec![(
            "global".to_string(),
            trainer.loss_scale()?,
            metrics.skipped_steps() as u64,
        )];
        write_metrics_prom(&metrics_prom, &metrics, &rows)?;
        persist_train_trace(&cfg.trace, trainer.tracer());
        summarize(&metrics);
    }
    Ok(())
}

/// `--metrics-prom PATH`: dump the run as a Prometheus textfile.
fn write_metrics_prom(
    path: &Option<String>,
    metrics: &RunMetrics,
    scaling: &[(String, f32, u64)],
) -> Result<()> {
    if let Some(path) = path {
        std::fs::write(path, train_prometheus(metrics, scaling))
            .with_context(|| format!("write metrics textfile {path}"))?;
        eprintln!("[mpx] metrics → {path}");
    }
    Ok(())
}

/// The fused trainer's scaling machine as a schema-v2 scaler record.
/// The state leaves already carry the machine bit-exactly through
/// save/restore; the record additionally keeps fused checkpoints
/// readable by the same v2 tooling that inspects DDP ones.
fn fused_scaler_record(trainer: &FusedTrainer) -> Result<Vec<GroupState>> {
    let m = trainer.manifest();
    let range = m.input_group("scaling");
    let mut scale = None;
    let mut counter = 0u32;
    for (i, spec) in m.inputs[range.clone()].iter().enumerate() {
        let v = &trainer.state()[range.start + i];
        match spec.dtype {
            DType::F32 => scale = Some(read_scalar_f32(v)?),
            DType::S32 => counter = read_scalar_i32(v)? as u32,
            _ => {}
        }
    }
    Ok(match scale {
        Some(scale) => {
            vec![GroupState { name: "global".into(), scale, counter }]
        }
        None => Vec::new(),
    })
}

/// Export the trainer's step-phase spans when `[trace] trace_out` is
/// set (the serve path has its own artifact-aware exporter).
fn persist_train_trace(
    cfg: &mpx::trace::TraceConfig,
    tracer: Option<&std::sync::Arc<mpx::trace::Tracer>>,
) {
    if let (Some(out), Some(t)) = (&cfg.trace_out, tracer) {
        let spans = t.snapshot();
        if spans.is_empty() {
            return;
        }
        match mpx::trace::chrome::write_chrome_trace(
            std::path::Path::new(out),
            &spans,
            t.dropped(),
        ) {
            Ok(()) => eprintln!(
                "[mpx] trace: wrote {} spans to {out}",
                spans.len()
            ),
            Err(e) => eprintln!("[mpx] trace: export failed: {e}"),
        }
    }
}

fn summarize(metrics: &RunMetrics) {
    let n = metrics.records.len();
    if n == 0 {
        return;
    }
    let mean = metrics.mean_step_time(n.min(3)).unwrap_or_default();
    eprintln!(
        "[mpx] done: {} steps in {}, mean step {} (post-warmup), final loss {:.4}, {} skipped",
        n,
        human_duration(metrics.elapsed()),
        human_duration(mean),
        metrics.recent_loss(10).unwrap_or(f32::NAN),
        metrics.skipped_steps(),
    );
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = args
        .get_str("artifacts-dir")
        .unwrap_or("artifacts")
        .to_string();
    args.finish()?;
    let store = ArtifactStore::open(&dir)?;
    for name in store.list()? {
        let m = store.manifest(&name)?;
        println!(
            "{name:<44} {:<10} {:>4} in → {:>4} out",
            m.kind,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let name = args
        .get_str("artifact")
        .context("--artifact NAME required")?
        .to_string();
    let dir = args
        .get_str("artifacts-dir")
        .unwrap_or("artifacts")
        .to_string();
    args.finish()?;

    let store = ArtifactStore::open(&dir)?;
    let m = store.manifest(&name)?;
    println!("artifact   : {name}");
    println!("kind       : {}", m.kind);
    if let Some(model) = &m.model {
        println!("model      : {model}");
    }
    if let Some(p) = &m.precision {
        println!("precision  : {p}");
    }
    if let Some(b) = m.batch {
        println!("batch      : {b}");
    }
    println!("-- input bytes by group:");
    for (group, bytes) in m.bytes_by_group(mpx::pytree::Which::Inputs) {
        println!("   {group:<12} {}", human_bytes(bytes));
    }
    println!("-- output bytes by group:");
    for (group, bytes) in m.bytes_by_group(mpx::pytree::Which::Outputs) {
        println!("   {group:<12} {}", human_bytes(bytes));
    }

    let hlo = HloModule::parse(&store.hlo_text(&name)?)?;
    println!("-- HLO census:");
    println!("   entry instructions : {}", hlo.entry_instructions().count());
    println!("   parameter bytes    : {}", human_bytes(hlo.parameter_bytes()));
    for (dtype, bytes) in hlo.workspace_bytes_by_dtype() {
        println!("   workspace {dtype:<8} : {}", human_bytes(bytes));
    }
    let hist = hlo.opcode_histogram();
    let mut top: Vec<_> = hist.iter().collect();
    top.sort_by_key(|(_, &c)| std::cmp::Reverse(c));
    println!("-- top opcodes:");
    for (op, count) in top.iter().take(8) {
        println!("   {op:<16} {count}");
    }
    Ok(())
}

fn cmd_memory_report(args: &Args) -> Result<()> {
    let model = args.get_str("model").unwrap_or("vit_desktop").to_string();
    let batches = args
        .get_usize_list("batches")?
        .unwrap_or_else(|| vec![8, 16, 32, 64, 128, 256]);
    let machine =
        machine_profile(args.get_str("machine").unwrap_or("desktop"))?;
    args.finish()?;

    let preset = model_preset(&model)?;
    let am = ActivationModel::new(preset);
    println!(
        "memory model: {} ({} params) on {}",
        model,
        am.param_count(),
        machine.name
    );
    println!(
        "{:>7} {:>14} {:>14} {:>7} {:>11} {:>11}",
        "batch", "fp32", "mixed_f16", "ratio", "proj_fp32", "proj_mixed"
    );
    for &b in &batches {
        let full = am.estimate(Precision::Fp32, b);
        let mixed = am.estimate(Precision::MixedF16, b);
        let wf = roofline::step_work(&preset, Precision::Fp32, b);
        let wm = roofline::step_work(&preset, Precision::MixedF16, b);
        println!(
            "{:>7} {:>14} {:>14} {:>6.2}x {:>9.2}ms {:>9.2}ms",
            b,
            human_bytes(full.total_bytes()),
            human_bytes(mixed.total_bytes()),
            full.total_bytes() as f64 / mixed.total_bytes() as f64,
            roofline::projected_step_time(&wf, &machine, Precision::Fp32)
                * 1e3,
            roofline::projected_step_time(&wm, &machine, Precision::MixedF16)
                * 1e3,
        );
    }
    Ok(())
}

fn cmd_scaling_sim(args: &Args) -> Result<()> {
    let steps = args.get_u64("steps")?.unwrap_or(200);
    let prob = args.get_f64("overflow-prob")?.unwrap_or(0.02);
    let period = args.get_u64("period")?.unwrap_or(50) as u32;
    args.finish()?;

    let mut scaler = LossScaler::new(mpx::scaling::ScalingConfig {
        period,
        ..Default::default()
    });
    let mut inj = OverflowInjector::Random { prob, rng: Rng::new(7) };
    println!("step,scale,counter,finite");
    for step in 0..steps {
        let finite = !inj.fires(step);
        scaler.adjust(finite);
        println!(
            "{step},{},{},{}",
            scaler.scale(),
            scaler.counter(),
            finite as u8
        );
    }
    eprintln!(
        "[sim] {} steps: {} overflows, {} growths, final scale {}",
        steps, scaler.overflows, scaler.growths,
        scaler.scale()
    );
    Ok(())
}

/// Thin shim over [`mpx::serve`]: flags/TOML → `ServeConfig`, then
/// the subsystem does the queueing, batching, and reporting.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get_str("config") {
        Some(path) => ServeConfig::from_toml_file(path)?,
        None => ServeConfig::default(),
    };
    if let Some(m) = args.get_str("model") {
        cfg.model = m.to_string();
    }
    if let Some(p) = args.get_str("precision") {
        cfg.precision = Precision::parse(p)?;
    }
    if let Some(b) = args.get_usize("batch")? {
        cfg.max_batch = b;
    }
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    if let Some(w) = args.get_usize("max-workers")? {
        cfg.max_workers = w;
    }
    if let Some(d) = args.get_usize("autoscale-depth")? {
        cfg.autoscale_depth = d;
    }
    if let Some(p) = args.get_str("policy") {
        cfg.policy = mpx::serve::SchedPolicy::parse(p)?;
    }
    if let Some(list) = args.get_str("precisions") {
        cfg.lane_precisions = list
            .split(',')
            .map(|s| Precision::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        if let Some(&first) = cfg.lane_precisions.first() {
            cfg.precision = first;
        }
    }
    if let Some(ws) = args.get_usize_list("lane-weights")? {
        cfg.lane_weights = ws.into_iter().map(|w| w as u64).collect();
    }
    if let Some(n) = args.get_u64("requests")? {
        cfg.requests = n;
    }
    if let Some(r) = args.get_f64("rate")? {
        cfg.arrival_rate = r;
    }
    if let Some(c) = args.get_usize("queue-cap")? {
        cfg.queue_capacity = c;
    }
    if let Some(t) = args.get_u64("flush-ms")? {
        cfg.flush_timeout_ms = t;
    }
    if let Some(d) = args.get_u64("deadline-ms")? {
        cfg.deadline_ms = d;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(d) = args.get_str("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(b) = args.get_str("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if args.has_switch("open-loop") {
        cfg.open_loop = true;
    }
    if let Some(path) = args.get_str("trace-out") {
        cfg.trace.trace_out = Some(path.to_string());
        cfg.trace.enabled = true;
    }
    let listen = args.get_str("listen").map(str::to_string);
    let plan_only = args.has_switch("plan");
    args.finish()?;
    if let Some(addr) = &listen {
        cfg.transport.addr = addr.clone();
    }
    cfg.validate()?;

    if plan_only {
        return cmd_serve_plan(&cfg);
    }
    if listen.is_some() {
        let mut store =
            ArtifactStore::open_with(&cfg.artifacts_dir, cfg.backend)?;
        let report =
            mpx::serve::run_transport_with_artifacts(&mut store, &cfg)?;
        report.print();
        return Ok(());
    }

    let lanes = cfg
        .lane_configs()
        .iter()
        .map(|l| format!("{}×{}", l.name, l.weight))
        .collect::<Vec<_>>()
        .join(",");
    eprintln!(
        "[mpx] serve | model {} | lanes {} | {} batching | batch ≤{} | {} \
         workers{} | {} requests {}",
        cfg.model,
        lanes,
        cfg.policy.tag(),
        cfg.max_batch,
        cfg.workers,
        if cfg.max_workers > cfg.workers {
            format!(" (≤{} autoscaled)", cfg.max_workers)
        } else {
            String::new()
        },
        cfg.requests,
        if cfg.arrival_rate > 0.0 {
            format!(
                "| {} {:.0} req/s",
                if cfg.open_loop { "open-loop" } else { "closed-loop" },
                cfg.arrival_rate
            )
        } else {
            "| back-to-back".to_string()
        },
    );
    let mut store =
        ArtifactStore::open_with(&cfg.artifacts_dir, cfg.backend)?;
    let report = mpx::serve::run_with_artifacts(&mut store, &cfg)?;
    report.print(&format!(
        "{} {} b{}×{}w",
        cfg.model,
        cfg.precision.tag(),
        cfg.max_batch,
        cfg.workers
    ));
    Ok(())
}

/// `mpx serve --plan`: run the latency-aware bucket planner over the
/// configured lane profiles, print the chosen buckets / flush
/// timeouts / predicted p99 per lane, check which planned artifacts
/// are already compiled, and exit without serving.
fn cmd_serve_plan(cfg: &ServeConfig) -> Result<()> {
    let plan = mpx::serve::plan_for_config(cfg)?;
    eprintln!(
        "[mpx] serve --plan | model {} | {} lanes | {} workers | candidates \
         up to b{}",
        cfg.model,
        plan.lanes.len(),
        cfg.workers,
        cfg.max_batch,
    );
    plan.print();

    // Measured-vs-config service model report: what the calibrated
    // fit says each lane actually costs, next to the `[serve.planner]`
    // constants the static plan would use.  Printed for both sources
    // — with `source = "config"` it shows what switching to
    // "calibrated" would change; with "calibrated" it shows which
    // lanes actually had a fit to use.
    let cal_path = std::path::Path::new(&cfg.artifacts_dir)
        .join(mpx::serve::CALIBRATION_FILE);
    match mpx::serve::Calibration::read(&cal_path) {
        Ok(cal) if !cal.is_empty() => {
            println!(
                "[plan] service model source: {} ({})",
                cfg.planner.source.tag(),
                cal_path.display()
            );
            for id in mpx::serve::lane_identities(cfg) {
                match cal.get(&id.name, &id.precision) {
                    Some(fit) => {
                        let d_over = fit.overhead_us as i64
                            - cfg.planner.overhead_us as i64;
                        let d_row = fit.per_row_us as i64
                            - cfg.planner.per_row_us as i64;
                        println!(
                            "[plan] lane {}: measured overhead {}us \
                             ({:+}us vs config), per-row {}us ({:+}us vs \
                             config), {} samples",
                            id.name,
                            fit.overhead_us,
                            d_over,
                            fit.per_row_us,
                            d_row,
                            fit.samples,
                        );
                    }
                    None => println!(
                        "[plan] lane {}: no calibrated fit — using config \
                         constants (overhead {}us, per-row {}us)",
                        id.name,
                        cfg.planner.overhead_us,
                        cfg.planner.per_row_us,
                    ),
                }
            }
        }
        Ok(_) => println!(
            "[plan] no calibration at {} — serve with [trace] enabled to \
             record service samples, then `source = \"calibrated\"` uses \
             the measured fit",
            cal_path.display()
        ),
        Err(e) => println!(
            "[plan] calibration unreadable ({e:#}); using config constants"
        ),
    }

    // Best-effort artifact presence report: the plan says what should
    // exist, the store says what does.
    match ArtifactStore::open_with(&cfg.artifacts_dir, cfg.backend) {
        Ok(store) => {
            for (lp, lc) in plan.lanes.iter().zip(cfg.lane_configs()) {
                let missing = mpx::serve::missing_planned_artifacts(
                    &store,
                    cfg,
                    lc.precision,
                    lp,
                );
                if missing.is_empty() {
                    if !lp.buckets.is_empty() {
                        println!(
                            "[plan] lane {}: all planned artifacts compiled",
                            lp.name
                        );
                    }
                } else {
                    println!(
                        "[plan] lane {}: missing artifacts for buckets {:?} \
                         (e.g. {}) — run `make artifacts`",
                        lp.name,
                        missing,
                        cfg.fwd_artifact_for(lc.precision, missing[0]),
                    );
                }
            }
        }
        Err(e) => {
            println!("[plan] artifact store unavailable ({e:#}); skipping the presence check");
        }
    }
    if !plan.is_feasible() {
        anyhow::bail!(
            "plan infeasible for at least one lane (see reasons above)"
        );
    }
    Ok(())
}
