//! Leaf inventories — the manifest contract between `aot.py` and the
//! runtime.
//!
//! An AOT artifact's inputs and outputs are *flattened PyTree leaves*
//! in deterministic (sorted-attribute) order; the manifest names each
//! leaf, records dtype/shape/group, and marks trainability.  The Rust
//! side never re-derives structure — it slices the flat leaf vectors
//! by `group` ("params", "opt_state", "scaling", "images", ...).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element types artifacts move (subset of XLA's PrimitiveType).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    Bf16,
    S32,
    U32,
    S8,
    U8,
    Pred,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "f16" => DType::F16,
            "bf16" => DType::Bf16,
            "s32" => DType::S32,
            "u32" => DType::U32,
            "s8" => DType::S8,
            "u8" => DType::U8,
            "pred" => DType::Pred,
            _ => bail!("unknown dtype {s:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
            DType::S32 => "s32",
            DType::U32 => "u32",
            DType::S8 => "s8",
            DType::U8 => "u8",
            DType::Pred => "pred",
        }
    }

    pub fn bytes(self) -> usize {
        match self {
            DType::F32 | DType::S32 | DType::U32 => 4,
            DType::F16 | DType::Bf16 => 2,
            DType::S8 | DType::U8 | DType::Pred => 1,
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16 | DType::Bf16)
    }
}

/// One flattened PyTree leaf.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub group: String,
    pub trainable: bool,
}

impl LeafSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }

    fn from_json(v: &Json) -> Result<LeafSpec> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("leaf missing name"))?
            .to_string();
        let dtype = DType::parse(
            v.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("leaf {name}: missing dtype"))?,
        )?;
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("leaf {name}: missing shape"))?
            .iter()
            .map(|d| {
                d.as_i64()
                    .filter(|&d| d >= 0)
                    .map(|d| d as usize)
                    .ok_or_else(|| anyhow!("leaf {name}: bad dim"))
            })
            .collect::<Result<Vec<_>>>()?;
        let group = v
            .get("group")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let trainable = v
            .get("trainable")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        Ok(LeafSpec { name, dtype, shape, group, trainable })
    }
}

/// Parsed `<name>.manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub model: Option<String>,
    pub precision: Option<String>,
    pub batch: Option<usize>,
    pub inputs: Vec<LeafSpec>,
    pub outputs: Vec<LeafSpec>,
    pub loss_scaling_init: f32,
    pub loss_scaling_period: u64,
    pub meta: Json,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("manifest json")?;
        let meta = v
            .get("meta")
            .cloned()
            .ok_or_else(|| anyhow!("manifest missing meta"))?;
        let get_meta_str = |k: &str| {
            meta.get(k).and_then(Json::as_str).map(str::to_string)
        };
        let inputs = v
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing inputs"))?
            .iter()
            .map(LeafSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .get("outputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing outputs"))?
            .iter()
            .map(LeafSpec::from_json)
            .collect::<Result<Vec<_>>>()?;

        let scaling = meta.get("loss_scaling");
        Ok(Manifest {
            name: get_meta_str("name").unwrap_or_default(),
            kind: get_meta_str("kind").unwrap_or_default(),
            model: get_meta_str("model"),
            precision: get_meta_str("precision"),
            batch: meta
                .get("batch")
                .and_then(Json::as_i64)
                .map(|b| b as usize),
            inputs,
            outputs,
            loss_scaling_init: scaling
                .and_then(|s| s.get("init"))
                .and_then(Json::as_f64)
                .unwrap_or(1.0) as f32,
            loss_scaling_period: scaling
                .and_then(|s| s.get("period"))
                .and_then(Json::as_f64)
                .unwrap_or(f64::from(u32::MAX)) as u64,
            meta,
        })
    }

    /// Index range (contiguous) of an input group.
    pub fn input_group(&self, group: &str) -> std::ops::Range<usize> {
        group_range(&self.inputs, group)
    }

    pub fn output_group(&self, group: &str) -> std::ops::Range<usize> {
        group_range(&self.outputs, group)
    }

    /// Total bytes by group (the Fig. 2 memory accounting input).
    pub fn bytes_by_group(&self, which: Which) -> BTreeMap<String, u64> {
        let leaves = match which {
            Which::Inputs => &self.inputs,
            Which::Outputs => &self.outputs,
        };
        let mut m = BTreeMap::new();
        for leaf in leaves {
            *m.entry(leaf.group.clone()).or_insert(0) += leaf.bytes() as u64;
        }
        m
    }
}

#[derive(Debug, Clone, Copy)]
pub enum Which {
    Inputs,
    Outputs,
}

fn group_range(leaves: &[LeafSpec], group: &str) -> std::ops::Range<usize> {
    let start = leaves.iter().position(|l| l.group == group);
    match start {
        None => 0..0,
        Some(s) => {
            let mut e = s;
            while e < leaves.len() && leaves[e].group == group {
                e += 1;
            }
            // groups are contiguous by construction (aot.py flattens
            // one top-level arg at a time)
            debug_assert!(
                leaves[e..].iter().all(|l| l.group != group),
                "group {group} not contiguous"
            );
            s..e
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "inputs": [
        {"name": "params.w", "dtype": "f32", "shape": [4, 3],
         "group": "params", "trainable": true},
        {"name": "params.step", "dtype": "s32", "shape": [],
         "group": "params", "trainable": false},
        {"name": "images", "dtype": "f32", "shape": [8, 3, 32, 32],
         "group": "images"}
      ],
      "outputs": [
        {"name": "loss", "dtype": "f32", "shape": [], "group": "loss"}
      ],
      "meta": {"name": "t", "kind": "step_fused", "model": "vit_tiny",
               "precision": "mixed_f16", "batch": 8,
               "loss_scaling": {"init": 32768.0, "period": 2000}}
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.kind, "step_fused");
        assert_eq!(m.batch, Some(8));
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[0].elems(), 12);
        assert_eq!(m.inputs[0].bytes(), 48);
        assert!(m.inputs[0].trainable);
        assert!(!m.inputs[1].trainable);
        assert_eq!(m.loss_scaling_init, 32768.0);
    }

    #[test]
    fn group_ranges() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.input_group("params"), 0..2);
        assert_eq!(m.input_group("images"), 2..3);
        assert_eq!(m.input_group("nope"), 0..0);
        assert_eq!(m.output_group("loss"), 0..1);
    }

    #[test]
    fn bytes_by_group() {
        let m = Manifest::parse(DOC).unwrap();
        let b = m.bytes_by_group(Which::Inputs);
        assert_eq!(b["params"], 48 + 4);
        assert_eq!(b["images"], 8 * 3 * 32 * 32 * 4);
    }

    #[test]
    fn scalar_leaf_has_one_elem() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.inputs[1].elems(), 1);
        assert_eq!(m.outputs[0].elems(), 1);
    }

    #[test]
    fn dtype_table() {
        assert_eq!(DType::parse("bf16").unwrap().bytes(), 2);
        assert_eq!(DType::parse("pred").unwrap().bytes(), 1);
        assert!(DType::parse("f64").is_err());
        assert!(DType::F16.is_float());
        assert!(!DType::S32.is_float());
    }
}
