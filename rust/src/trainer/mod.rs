//! Training loops — the L3 coordinator proper.
//!
//! Two modes, matching the paper's two experiments:
//!
//! * [`fused::FusedTrainer`] — single-device (paper's desktop run):
//!   the whole §2.1 recipe is one compiled HLO program; Rust owns the
//!   loop, data, logging and checkpoints, and *observes* the
//!   loss-scaling state the graph carries.
//! * [`ddp::DataParallelTrainer`] — simulated multi-device (paper's
//!   4×H100 run): per-shard `grads` executables + deterministic
//!   all-reduce + Rust AdamW on fp32 master weights + the Rust
//!   [`crate::scaling::LossScaler`].  Equivalence against the fused
//!   mode is an integration test.

pub mod checkpoint;
pub mod ddp;
pub mod fused;

pub use ddp::DataParallelTrainer;
pub use fused::FusedTrainer;
