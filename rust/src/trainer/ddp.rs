//! Simulated multi-device data-parallel trainer (paper's cluster run).
//!
//! Topology per step (N shards ≙ the paper's 4 H100s):
//!
//! ```text
//!   masters (f32, host) ──► per-shard param literals (replicated)
//!   shard s: grads_exe(params, scale, batch_s) ─► (grads_s, loss_s, finite_s)
//!   all_reduce_mean(grads) ── AND(finite) ── LossScaler.adjust
//!   finite ⇒ AdamW.update(masters, ḡ)       (else skip, paper §2.1 6a)
//! ```
//!
//! Shards run on OS threads over the one shared compiled executable
//! (the `Executable` trait is `Send + Sync`; on PJRT, `Execute` is
//! documented thread-safe, and the host interpreter keeps all
//! per-call state on the stack).
//! The all-reduce is a deterministic tree ([`crate::collective`]), the
//! optimizer is Rust AdamW over fp32 masters ([`crate::optim`]), and
//! the scale adjustment is the Rust [`LossScaler`] — together the
//! exact decomposition a real multi-accelerator MPX deployment uses.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::collective::{all_reduce_finite, all_reduce_mean, mean_loss};
use crate::config::TrainConfig;
use crate::data::SyntheticDataset;
use crate::hostkernel::scan::stats_tensors;
use crate::metrics::{RunMetrics, StepRecord};
use crate::optim::{AdamW, AdamWConfig};
use crate::pytree::DType;
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, read_f32,
    read_scalar_f32, read_scalar_pred, Artifact, ArtifactStore,
};
use crate::scaling::LossScaler;
use crate::serve::clock::{Clock, WallClock};
use crate::trace::{SpanKind, Tracer};

pub struct DataParallelTrainer {
    grads_artifact: Arc<Artifact>,
    /// fp32 master copies of the trainable leaves (manifest order).
    pub masters: Vec<Vec<f32>>,
    master_shapes: Vec<Vec<usize>>,
    optimizer: AdamW,
    pub scaler: LossScaler,
    pub step_index: u64,
    pub config: TrainConfig,
    num_shards: usize,
    /// Time base for trace spans: `Duration` offsets since trainer
    /// construction (the [`Tracer`] contract), not raw `Instant`s.
    clock: Arc<WallClock>,
    tracer: Option<Arc<Tracer>>,
}

impl DataParallelTrainer {
    pub fn new(store: &mut ArtifactStore, config: TrainConfig) -> Result<Self> {
        if config.shards == 0 {
            bail!("shards must be ≥ 1");
        }
        let init = store.load(&config.init_artifact())?;
        let grads_artifact = store.load(&config.grads_artifact())?;
        let gm = &grads_artifact.manifest;

        // The grads artifact's params group must be all-f32 (master
        // weights live here) — guaranteed by the model definition.
        let prange = gm.input_group("params");
        for spec in &gm.inputs[prange.clone()] {
            if spec.dtype != DType::F32 {
                bail!("non-f32 param leaf {} in grads artifact", spec.name);
            }
        }

        // Initialize masters from the init artifact's params group.
        let init_state = init
            .execute(&[lit_scalar_i32(config.seed as i32)])
            .context("run init artifact")?;
        let ip = init.manifest.output_group("params");
        if ip.len() != prange.len() {
            bail!(
                "init params {} leaves vs grads artifact {}",
                ip.len(),
                prange.len()
            );
        }
        let mut masters = Vec::with_capacity(prange.len());
        let mut master_shapes = Vec::with_capacity(prange.len());
        for (k, spec) in gm.inputs[prange.clone()].iter().enumerate() {
            masters.push(read_f32(&init_state[ip.start + k])?);
            master_shapes.push(spec.shape.clone());
        }

        let sizes: Vec<usize> = masters.iter().map(Vec::len).collect();
        let optimizer = AdamW::new(
            AdamWConfig {
                lr: config.lr as f32,
                weight_decay: config.weight_decay as f32,
                ..Default::default()
            },
            &sizes,
        );
        let scaler = LossScaler::new(config.precision.scaling_config());

        let clock = Arc::new(WallClock::new());
        let tracer = Tracer::from_config(
            clock.clone() as Arc<dyn Clock>,
            &config.trace,
        );
        Ok(DataParallelTrainer {
            grads_artifact,
            masters,
            master_shapes,
            optimizer,
            scaler,
            step_index: 0,
            num_shards: config.shards,
            config,
            clock,
            tracer,
        })
    }

    /// The step-phase span recorder (`None` when `[trace]` is off).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    pub fn manifest(&self) -> &crate::pytree::Manifest {
        &self.grads_artifact.manifest
    }

    /// One data-parallel step over global batch index `index`.
    pub fn step(&mut self, dataset: &SyntheticDataset) -> Result<StepRecord> {
        let t0 = Instant::now();
        let span_start = self.clock.now();
        let gm = &self.grads_artifact.manifest;
        let per_shard_batch = gm
            .batch
            .context("grads artifact missing batch meta")?;
        let global_batch = per_shard_batch * self.num_shards;
        let scale = self.scaler.scale();

        let grange = gm.output_group("grads");
        let loss_idx = gm
            .output_group("loss")
            .next_back()
            .context("no loss output")?;
        let finite_idx = gm
            .output_group("finite")
            .next_back()
            .context("no finite output")?;

        // -- fan out: one thread per shard ------------------------------
        let masters = &self.masters;
        let shapes = &self.master_shapes;
        let artifact = &self.grads_artifact;
        let index = self.step_index;
        let seed = self.config.seed;
        let n = self.num_shards;

        let shard_results: Vec<Result<(Vec<Vec<f32>>, f32, bool)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..n)
                    .map(|s| {
                        let grange = grange.clone();
                        scope.spawn(move || -> Result<_> {
                            let batch = dataset.shard_batch(
                                index,
                                global_batch,
                                seed,
                                s,
                                n,
                            );
                            // Replicate params into this "device"'s
                            // literals (each device holds its copy).
                            let mut inputs = Vec::with_capacity(
                                masters.len() + 3,
                            );
                            for (m, shape) in masters.iter().zip(shapes) {
                                inputs.push(lit_f32(shape, m)?);
                            }
                            inputs.push(lit_scalar_f32(scale));
                            let img_elems = batch.image_elems;
                            let b = batch.batch;
                            // image shape from manifest
                            let img_spec = &artifact.manifest.inputs
                                [artifact.manifest.input_group("images")
                                    .next_back()
                                    .context("no images input")?];
                            debug_assert_eq!(
                                img_spec.elems(),
                                img_elems * b
                            );
                            inputs.push(lit_f32(
                                &img_spec.shape,
                                &batch.images,
                            )?);
                            inputs.push(lit_i32(&[b], &batch.labels)?);
                            // Packed into literals — buffers go back
                            // to the pool for the next step's batch.
                            batch.recycle();

                            let out = artifact.execute(&inputs)?;
                            let grads = grange
                                .clone()
                                .map(|i| read_f32(&out[i]))
                                .collect::<Result<Vec<_>>>()?;
                            let loss = read_scalar_f32(&out[loss_idx])?;
                            let finite =
                                read_scalar_pred(&out[finite_idx])?;
                            Ok((grads, loss, finite))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked"))
                    .collect()
            });

        let mut grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
        let mut losses = Vec::with_capacity(n);
        let mut finites = Vec::with_capacity(n);
        for r in shard_results {
            let (g, l, f) = r?;
            grads.push(g);
            losses.push(l);
            finites.push(f);
        }

        // -- reduce + update --------------------------------------------
        // Non-finite shard gradients may contain inf/nan; the finite
        // flag already tells us, and the mean would poison masters, so
        // gate the reduce+update on global finiteness (paper §2.1 6a).
        let step = self.step_index + 1;
        let reduce_start = self.clock.now();
        let grads_finite = all_reduce_finite(&finites);
        if grads_finite {
            all_reduce_mean(&mut grads);
            let log_every = self.config.log_every.max(1);
            if (self.step_index + 1) % log_every == 0 {
                // Gradient health in one read-only fused traversal of
                // the reduced gradient (already unscaled in-graph);
                // the buffer reaches the optimizer untouched.
                let s = stats_tensors(&grads[0]);
                eprintln!(
                    "[ddp x{}] grad health: |g| in [{:.3e}, {:.3e}] \
                     mean {:.3e}, {:.1}% zero (scale {:.0})",
                    self.num_shards,
                    s.min_abs_nonzero,
                    s.max_abs,
                    s.mean_abs,
                    100.0 * s.zeros as f64 / s.count.max(1) as f64,
                    scale,
                );
            }
            let optim_start = self.clock.now();
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::UnscaleScan,
                    reduce_start,
                    optim_start,
                    step,
                    0,
                    0,
                );
            }
            self.optimizer.update(&mut self.masters, &grads[0]);
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::Optim,
                    optim_start,
                    self.clock.now(),
                    step,
                    0,
                    0,
                );
            }
        } else {
            // Overflow step: one fused scan per poisoned shard says
            // *which* shard blew up and how — the §2.1 loss-scaling
            // diagnostic (the buffers are discarded afterwards).
            for (shard, g) in grads.iter().enumerate() {
                if !finites[shard] {
                    let s = stats_tensors(g);
                    eprintln!(
                        "[ddp x{}] overflow in shard {shard}: {} inf, \
                         {} nan of {} grads (scale {:.0} → backing off)",
                        self.num_shards, s.infs, s.nans, s.count, scale,
                    );
                }
            }
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::UnscaleScan,
                    reduce_start,
                    self.clock.now(),
                    step,
                    0,
                    0,
                );
            }
        }
        let applied = self.scaler.adjust(grads_finite);
        debug_assert_eq!(applied, grads_finite);
        let new_scale = self.scaler.scale();
        if let Some(t) = &self.tracer {
            // `scale` is the pre-adjust value read at the top of step.
            if new_scale != scale {
                t.instant(
                    SpanKind::LossScale,
                    t.now(),
                    scale.to_bits() as u64,
                    new_scale.to_bits() as u64,
                    (new_scale > scale) as u64,
                );
            }
            t.record(
                SpanKind::TrainStep,
                span_start,
                t.now(),
                step,
                grads_finite as u64,
                0,
            );
        }

        self.step_index += 1;
        Ok(StepRecord {
            step: self.step_index,
            loss: mean_loss(&losses),
            grads_finite,
            loss_scale: self.scaler.scale(),
            step_time: t0.elapsed(),
        })
    }

    pub fn run(
        &mut self,
        dataset: &SyntheticDataset,
        steps: u64,
        metrics: &mut RunMetrics,
    ) -> Result<()> {
        let log_every = self.config.log_every.max(1);
        for _ in 0..steps {
            let rec = self.step(dataset)?;
            if rec.step % log_every == 0 || rec.step == 1 {
                eprintln!(
                    "[ddp x{}] step {:>5}  loss {:>8.4}  scale {:>9.0}  {}{}",
                    self.num_shards,
                    rec.step,
                    rec.loss,
                    rec.loss_scale,
                    crate::util::human_duration(rec.step_time),
                    if rec.grads_finite { "" } else { "  (overflow, skipped)" },
                );
            }
            metrics.record(rec)?;
        }
        Ok(())
    }
}
