//! Simulated multi-device data-parallel trainer (paper's cluster run).
//!
//! Topology per step (N shards ≙ the paper's 4 H100s):
//!
//! ```text
//!   masters (f32, host) ──► per-shard param literals (replicated)
//!   shard s: grads_exe(params, scale, batch_s) ─► (grads_s, loss_s, finite_s)
//!            + per-group census (underflow/overflow/max|g|) at each
//!              group's scale, via the fused hostkernel scan
//!   all_reduce_group_stats ── AND(finite) ── ScalingPolicy.adjust
//!   applied ⇒ [adaptive: per-group scale → ] all_reduce_mean
//!             [ → unscale] → AdamW.update(masters, ḡ)
//!   (else skip, paper §2.1 6a)
//! ```
//!
//! Shards run on OS threads over the one shared compiled executable
//! (the `Executable` trait is `Send + Sync`; on PJRT, `Execute` is
//! documented thread-safe, and the host interpreter keeps all
//! per-call state on the stack).
//! The all-reduce is a deterministic tree ([`crate::collective`]), the
//! optimizer is Rust AdamW over fp32 masters ([`crate::optim`]), and
//! scale control is a [`ScalingPolicy`] — the trainer owns it
//! host-side, so per-layer policies ([`crate::scaling::adaptive`])
//! work even though the compiled graph takes a single scalar scale:
//! every shard's per-group statistics are merged by the deterministic
//! stats all-reduce, so every rank computes identical per-group
//! scales.  Under the adaptive policy the gradient comms are staged at
//! each group's scale (power-of-two multiplies through the
//! [`crate::hostkernel::reduce`] batch kernels — exact, no per-element
//! scalar path), emulating per-layer-scaled f16 transport.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::collective::{
    all_reduce_finite, all_reduce_group_stats, all_reduce_mean, mean_loss,
};
use crate::config::TrainConfig;
use crate::data::SyntheticDataset;
use crate::hostkernel::reduce::scale_in_place;
use crate::hostkernel::scan::{scaled_f16_census, stats_tensors, StatsAcc};
use crate::metrics::{RunMetrics, StepRecord};
use crate::optim::{AdamW, AdamWConfig};
use crate::pytree::{DType, LeafSpec};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, read_f32,
    read_scalar_f32, read_scalar_i32, read_scalar_pred, Artifact,
    ArtifactStore, Value,
};
use crate::scaling::{
    build_policy, derive_groups, restore_policy, spike_overflows, GroupStats,
    OverflowInjector, PolicyKind, ScalingPolicy, ScalingSpec,
};
use crate::serve::clock::{Clock, WallClock};
use crate::trace::{SpanKind, Tracer};

pub struct DataParallelTrainer {
    grads_artifact: Arc<Artifact>,
    /// fp32 master copies of the trainable leaves (manifest order).
    pub masters: Vec<Vec<f32>>,
    master_shapes: Vec<Vec<usize>>,
    optimizer: AdamW,
    /// The scaling controller (dynamic / pinned / adaptive).
    pub policy: Box<dyn ScalingPolicy>,
    spec: ScalingSpec,
    /// Per-layer leaf groups derived from the grads manifest
    /// (first-appearance order — identical on every rank).
    groups: Vec<String>,
    /// grads output leaf index → group index.
    leaf_group: Vec<usize>,
    injector: Option<OverflowInjector>,
    pub step_index: u64,
    pub config: TrainConfig,
    num_shards: usize,
    /// Time base for trace spans: `Duration` offsets since trainer
    /// construction (the [`Tracer`] contract), not raw `Instant`s.
    clock: Arc<WallClock>,
    tracer: Option<Arc<Tracer>>,
}

impl DataParallelTrainer {
    pub fn new(store: &mut ArtifactStore, config: TrainConfig) -> Result<Self> {
        if config.shards == 0 {
            bail!("shards must be ≥ 1");
        }
        let spec = config.scaling_spec()?;
        let init = store.load(&config.init_artifact())?;
        let grads_artifact = store.load(&config.grads_artifact())?;
        let gm = &grads_artifact.manifest;

        // The grads artifact's params group must be all-f32 (master
        // weights live here) — guaranteed by the model definition.
        let prange = gm.input_group("params");
        for spec in &gm.inputs[prange.clone()] {
            if spec.dtype != DType::F32 {
                bail!("non-f32 param leaf {} in grads artifact", spec.name);
            }
        }

        // Initialize masters from the init artifact's params group.
        let init_state = init
            .execute(&[lit_scalar_i32(config.seed as i32)])
            .context("run init artifact")?;
        let ip = init.manifest.output_group("params");
        if ip.len() != prange.len() {
            bail!(
                "init params {} leaves vs grads artifact {}",
                ip.len(),
                prange.len()
            );
        }
        let mut masters = Vec::with_capacity(prange.len());
        let mut master_shapes = Vec::with_capacity(prange.len());
        for (k, spec) in gm.inputs[prange.clone()].iter().enumerate() {
            masters.push(read_f32(&init_state[ip.start + k])?);
            master_shapes.push(spec.shape.clone());
        }

        let sizes: Vec<usize> = masters.iter().map(Vec::len).collect();
        let optimizer = AdamW::new(
            AdamWConfig {
                lr: config.lr as f32,
                weight_decay: config.weight_decay as f32,
                ..Default::default()
            },
            &sizes,
        );

        let grange = gm.output_group("grads");
        let (groups, leaf_group) =
            derive_groups(gm.outputs[grange].iter().map(|s| s.name.as_str()));
        let policy = build_policy(&spec, &groups);

        let clock = Arc::new(WallClock::new());
        let tracer = Tracer::from_config(
            clock.clone() as Arc<dyn Clock>,
            &config.trace,
        );
        Ok(DataParallelTrainer {
            grads_artifact,
            masters,
            master_shapes,
            optimizer,
            policy,
            spec,
            groups,
            leaf_group,
            injector: None,
            step_index: 0,
            num_shards: config.shards,
            config,
            clock,
            tracer,
        })
    }

    /// The step-phase span recorder (`None` when `[trace]` is off).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    pub fn manifest(&self) -> &crate::pytree::Manifest {
        &self.grads_artifact.manifest
    }

    /// The scalar scale the compiled graph sees this step.
    pub fn loss_scale(&self) -> f32 {
        self.policy.graph_scale()
    }

    /// The derived per-layer group names (stats/index order).
    pub fn groups(&self) -> &[String] {
        &self.groups
    }

    /// Per-policy-group `(name, scale, skipped)` rows for the
    /// Prometheus export ([`crate::metrics::train_prometheus`]).
    pub fn scaling_rows(&self) -> Vec<(String, f32, u64)> {
        self.policy
            .groups()
            .iter()
            .enumerate()
            .map(|(g, name)| {
                (name.clone(), self.policy.scale_of(g), self.policy.skips_of(g))
            })
            .collect()
    }

    /// Install a deterministic overflow schedule (tests / benches).
    /// A [`OverflowInjector::GroupSpike`] must name a derived group.
    pub fn set_injector(&mut self, inj: OverflowInjector) -> Result<()> {
        if let OverflowInjector::GroupSpike { group, .. } = &inj {
            if !self.groups.iter().any(|g| g == group) {
                bail!(
                    "injector targets unknown group {group:?}; model derives \
                     {:?}",
                    self.groups
                );
            }
        }
        self.injector = Some(inj);
        Ok(())
    }

    /// One data-parallel step over global batch index `index`.
    pub fn step(&mut self, dataset: &SyntheticDataset) -> Result<StepRecord> {
        let t0 = Instant::now();
        let span_start = self.clock.now();
        let gm = &self.grads_artifact.manifest;
        let per_shard_batch = gm
            .batch
            .context("grads artifact missing batch meta")?;
        let global_batch = per_shard_batch * self.num_shards;
        let scale = self.policy.graph_scale();
        // Per-group scales at step entry: the census asks "would this
        // gradient survive f16 at the scale its group runs at?".
        let group_scales: Vec<f32> =
            (0..self.groups.len()).map(|g| self.policy.scale_of(g)).collect();
        // Policy-group scales (for the trace diff after adjust; the
        // global policies expose one pseudo-group).
        let policy_scales: Vec<f32> = (0..self.policy.groups().len())
            .map(|g| self.policy.scale_of(g))
            .collect();

        let grange = gm.output_group("grads");
        let loss_idx = gm
            .output_group("loss")
            .next_back()
            .context("no loss output")?;
        let finite_idx = gm
            .output_group("finite")
            .next_back()
            .context("no finite output")?;

        // -- fan out: one thread per shard ------------------------------
        let masters = &self.masters;
        let shapes = &self.master_shapes;
        let artifact = &self.grads_artifact;
        let leaf_group = &self.leaf_group;
        let scales = &group_scales;
        let num_groups = self.groups.len();
        let index = self.step_index;
        let seed = self.config.seed;
        let n = self.num_shards;

        type ShardOut = (Vec<Vec<f32>>, f32, bool, Vec<GroupStats>);
        let shard_results: Vec<Result<ShardOut>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..n)
                    .map(|s| {
                        let grange = grange.clone();
                        scope.spawn(move || -> Result<_> {
                            let batch = dataset.shard_batch(
                                index,
                                global_batch,
                                seed,
                                s,
                                n,
                            );
                            // Replicate params into this "device"'s
                            // literals (each device holds its copy).
                            let mut inputs = Vec::with_capacity(
                                masters.len() + 3,
                            );
                            for (m, shape) in masters.iter().zip(shapes) {
                                inputs.push(lit_f32(shape, m)?);
                            }
                            inputs.push(lit_scalar_f32(scale));
                            let img_elems = batch.image_elems;
                            let b = batch.batch;
                            // image shape from manifest
                            let img_spec = &artifact.manifest.inputs
                                [artifact.manifest.input_group("images")
                                    .next_back()
                                    .context("no images input")?];
                            debug_assert_eq!(
                                img_spec.elems(),
                                img_elems * b
                            );
                            inputs.push(lit_f32(
                                &img_spec.shape,
                                &batch.images,
                            )?);
                            inputs.push(lit_i32(&[b], &batch.labels)?);
                            // Packed into literals — buffers go back
                            // to the pool for the next step's batch.
                            batch.recycle();

                            let out = artifact.execute(&inputs)?;
                            let grads = grange
                                .clone()
                                .map(|i| read_f32(&out[i]))
                                .collect::<Result<Vec<_>>>()?;
                            let loss = read_scalar_f32(&out[loss_idx])?;
                            let finite =
                                read_scalar_pred(&out[finite_idx])?;

                            // Per-group census over this shard's
                            // gradients: one fused stats pass + the
                            // scaled-f16 range census per leaf, at the
                            // leaf's group scale.
                            let mut accs: Vec<StatsAcc> = (0..num_groups)
                                .map(|_| StatsAcc::default())
                                .collect();
                            let mut under = vec![0u64; num_groups];
                            let mut over = vec![0u64; num_groups];
                            for (i, buf) in grads.iter().enumerate() {
                                let g = leaf_group[i];
                                accs[g].feed(buf);
                                let (u, o) =
                                    scaled_f16_census(buf, scales[g]);
                                under[g] += u;
                                over[g] += o;
                            }
                            let stats: Vec<GroupStats> = accs
                                .into_iter()
                                .enumerate()
                                .map(|(g, a)| {
                                    let s = a.finish();
                                    GroupStats {
                                        count: s.count as u64,
                                        max_abs: s.max_abs,
                                        underflow: under[g],
                                        overflow: over[g],
                                        finite: s.finite,
                                    }
                                })
                                .collect();
                            Ok((grads, loss, finite, stats))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked"))
                    .collect()
            });

        let mut grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
        let mut losses = Vec::with_capacity(n);
        let mut finites = Vec::with_capacity(n);
        let mut shard_stats: Vec<Vec<GroupStats>> = Vec::with_capacity(n);
        for r in shard_results {
            let (g, l, f, st) = r?;
            grads.push(g);
            losses.push(l);
            finites.push(f);
            shard_stats.push(st);
        }

        // -- merge statistics (every rank computes the same view) -------
        let mut merged = all_reduce_group_stats(&shard_stats);
        let step = self.step_index + 1;
        let mut grads_finite = all_reduce_finite(&finites);
        // Injected spikes land on the coordinator's merged view —
        // every rank would fold the identical plan, so determinism
        // holds.  A spike overflows only if the *targeted group's*
        // scale pushes it past f16 saturation (scale-conditioned:
        // this is what separates adaptive from global dynamic under a
        // recurring spike).
        if let Some(inj) = &mut self.injector {
            for (g, magnitude) in inj.spikes(self.step_index, &self.groups) {
                merged[g].count += 1;
                if magnitude.is_finite() {
                    if magnitude > merged[g].max_abs {
                        merged[g].max_abs = magnitude;
                    }
                    if spike_overflows(magnitude, group_scales[g]) {
                        merged[g].overflow += 1;
                        grads_finite = false;
                    }
                } else {
                    merged[g].finite = false;
                    grads_finite = false;
                }
            }
        }

        // -- advance the policy (decides whether the step applies) ------
        let applied = self.policy.adjust(grads_finite, &merged);

        // -- reduce + update --------------------------------------------
        // Non-finite shard gradients may contain inf/nan; the finite
        // flag already tells us, and the mean would poison masters, so
        // gate the reduce+update on global finiteness (paper §2.1 6a —
        // plus, under adaptive, any group's census overflow).
        let reduce_start = self.clock.now();
        if applied {
            // Under the adaptive policy the reduction is staged at
            // each group's scale (per-layer-scaled f16 transport,
            // emulated): scale every shard's group-g leaves by S_g,
            // tree-reduce, unscale the result.  Scales are powers of
            // two, so the round-trip is exact and the reduced
            // gradient is bit-identical to the unstaged path — but it
            // goes through the batch `scale_in_place` kernels, never
            // a per-element scalar loop.
            let staged = self.policy.kind() == PolicyKind::Adaptive;
            if staged {
                for shard in grads.iter_mut() {
                    for (i, buf) in shard.iter_mut().enumerate() {
                        scale_in_place(buf, group_scales[leaf_group[i]]);
                    }
                }
            }
            all_reduce_mean(&mut grads);
            if staged {
                for (i, buf) in grads[0].iter_mut().enumerate() {
                    scale_in_place(buf, 1.0 / group_scales[leaf_group[i]]);
                }
            }
            let log_every = self.config.log_every.max(1);
            if (self.step_index + 1) % log_every == 0 {
                // Gradient health in one read-only fused traversal of
                // the reduced gradient (already unscaled in-graph);
                // the buffer reaches the optimizer untouched.
                let s = stats_tensors(&grads[0]);
                eprintln!(
                    "[ddp x{}] grad health: |g| in [{:.3e}, {:.3e}] \
                     mean {:.3e}, {:.1}% zero (scale {:.0})",
                    self.num_shards,
                    s.min_abs_nonzero,
                    s.max_abs,
                    s.mean_abs,
                    100.0 * s.zeros as f64 / s.count.max(1) as f64,
                    scale,
                );
            }
            let optim_start = self.clock.now();
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::UnscaleScan,
                    reduce_start,
                    optim_start,
                    step,
                    0,
                    0,
                );
            }
            self.optimizer.update(&mut self.masters, &grads[0]);
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::Optim,
                    optim_start,
                    self.clock.now(),
                    step,
                    0,
                    0,
                );
            }
        } else {
            // Skipped step: one fused scan per poisoned shard says
            // *which* shard blew up and how — the §2.1 loss-scaling
            // diagnostic (the buffers are discarded afterwards).
            for (shard, g) in grads.iter().enumerate() {
                if !finites[shard] {
                    let s = stats_tensors(g);
                    eprintln!(
                        "[ddp x{}] overflow in shard {shard}: {} inf, \
                         {} nan of {} grads (scale {:.0} → backing off)",
                        self.num_shards, s.infs, s.nans, s.count, scale,
                    );
                }
            }
            if let Some(t) = &self.tracer {
                t.record(
                    SpanKind::UnscaleScan,
                    reduce_start,
                    self.clock.now(),
                    step,
                    0,
                    0,
                );
            }
        }
        if let Some(t) = &self.tracer {
            // One instant per policy group whose scale moved; `c`
            // packs `grew | (group_idx << 1)`, so the global policies
            // (group 0) emit exactly the values they always did.
            for (g, &old) in policy_scales.iter().enumerate() {
                let new = self.policy.scale_of(g);
                if new != old {
                    t.instant(
                        SpanKind::LossScale,
                        t.now(),
                        old.to_bits() as u64,
                        new.to_bits() as u64,
                        (new > old) as u64 | ((g as u64) << 1),
                    );
                }
            }
            t.record(
                SpanKind::TrainStep,
                span_start,
                t.now(),
                step,
                applied as u64,
                0,
            );
        }

        self.step_index += 1;
        Ok(StepRecord {
            step: self.step_index,
            loss: mean_loss(&losses),
            grads_finite: applied,
            loss_scale: self.policy.graph_scale(),
            step_time: t0.elapsed(),
        })
    }

    pub fn run(
        &mut self,
        dataset: &SyntheticDataset,
        steps: u64,
        metrics: &mut RunMetrics,
    ) -> Result<()> {
        let log_every = self.config.log_every.max(1);
        for _ in 0..steps {
            let rec = self.step(dataset)?;
            if rec.step % log_every == 0 || rec.step == 1 {
                eprintln!(
                    "[ddp x{}] step {:>5}  loss {:>8.4}  scale {:>9.0}  {}{}",
                    self.num_shards,
                    rec.step,
                    rec.loss,
                    rec.loss_scale,
                    crate::util::human_duration(rec.step_time),
                    if rec.grads_finite { "" } else { "  (overflow, skipped)" },
                );
            }
            metrics.record(rec)?;
        }
        Ok(())
    }

    // -- checkpointing ---------------------------------------------------
    //
    // The DDP trainer's persistent state is host-side (the fused
    // trainer's lives in artifact leaves): masters, AdamW moments +
    // step, and the policy's per-group scaler record.  Masters and
    // moments serialize as synthetic f32 leaves named after the
    // grads-manifest params; the scaler record is the checkpoint
    // schema v2 section.

    fn checkpoint_specs(&self) -> Vec<LeafSpec> {
        let gm = &self.grads_artifact.manifest;
        let prange = gm.input_group("params");
        let mut specs = Vec::with_capacity(3 * self.masters.len() + 1);
        for spec in &gm.inputs[prange.clone()] {
            specs.push(spec.clone());
        }
        for prefix in ["opt.mu", "opt.nu"] {
            for spec in &gm.inputs[prange.clone()] {
                let bare =
                    spec.name.strip_prefix("params.").unwrap_or(&spec.name);
                specs.push(LeafSpec {
                    name: format!("{prefix}.{bare}"),
                    dtype: DType::F32,
                    shape: spec.shape.clone(),
                    group: "opt".to_string(),
                    trainable: false,
                });
            }
        }
        specs.push(LeafSpec {
            name: "opt_state.t".to_string(),
            dtype: DType::S32,
            shape: vec![],
            group: "opt_state".to_string(),
            trainable: false,
        });
        specs
    }

    /// Save masters + optimizer + scaler record (schema v2).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let specs = self.checkpoint_specs();
        let (opt_step, mu, nu) = self.optimizer.state();
        let mut leaves: Vec<Value> =
            Vec::with_capacity(3 * self.masters.len() + 1);
        for (buf, shape) in self.masters.iter().zip(&self.master_shapes) {
            leaves.push(lit_f32(shape, buf)?);
        }
        for moments in [mu, nu] {
            for (buf, shape) in moments.iter().zip(&self.master_shapes) {
                leaves.push(lit_f32(shape, buf)?);
            }
        }
        leaves.push(lit_scalar_i32(opt_step as i32));
        super::checkpoint::save(
            path,
            self.step_index,
            &specs,
            &leaves,
            &self.policy.snapshot(),
        )
    }

    /// Resume from a checkpoint written by [`save_checkpoint`] (or a
    /// v1 file, whose global scaler record fans out per group when
    /// the configured policy is adaptive).
    ///
    /// [`save_checkpoint`]: DataParallelTrainer::save_checkpoint
    pub fn resume(&mut self, path: &str) -> Result<()> {
        let specs = self.checkpoint_specs();
        let (step, leaves, scaler) = super::checkpoint::load(path, &specs)?;
        let np = self.masters.len();
        for (i, buf) in self.masters.iter_mut().enumerate() {
            *buf = read_f32(&leaves[i])?;
        }
        let mu = (0..np)
            .map(|i| read_f32(&leaves[np + i]))
            .collect::<Result<Vec<_>>>()?;
        let nu = (0..np)
            .map(|i| read_f32(&leaves[2 * np + i]))
            .collect::<Result<Vec<_>>>()?;
        let opt_step = read_scalar_i32(&leaves[3 * np])? as u64;
        self.optimizer.set_state(opt_step, mu, nu);
        self.policy = restore_policy(&self.spec, &self.groups, &scaler)?;
        self.step_index = step;
        Ok(())
    }
}
