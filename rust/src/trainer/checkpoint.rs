//! Checkpointing — own binary format (no serde offline).
//!
//! Schema v2 layout (little-endian):
//!
//! ```text
//! magic "MPXCKPT2" | u64 step
//! u32 group_count
//! per group: u32 name_len | name utf8 | u32 scale_bits (f32) |
//!            u32 counter
//! u32 leaf_count
//! per leaf: u32 name_len | name utf8 | u8 dtype | u32 rank |
//!           u64 dims[rank] | u64 byte_len | bytes
//! ```
//!
//! v2 adds the versioned **scaler record**: per-group `(name, scale,
//! counter)` so the adaptive policy's per-layer scales survive a
//! restart ([`crate::scaling::GroupState`]).  Global policies write a
//! single `"global"` group.
//!
//! v1 (`MPXCKPT1`) had no scaler section — [`load`] still accepts it
//! and *migrates*: if the leaf set carries the fused trainer's
//! `scaling.loss_scaling` / `scaling.counter` scalars, they become a
//! single-group record (which [`crate::scaling::restore_policy`] fans
//! out to every group when resuming an adaptive run); otherwise the
//! record is empty and the policy starts fresh.
//!
//! Leaves are the trainer's state [`Value`]s in manifest order.  Save
//! and restore are symmetric across every manifest dtype — `Value`
//! already stores native-layout bytes, so serialization is a straight
//! copy and mixed-precision state round-trips bitwise on either
//! runtime backend.  Restore validates name, dtype and shape against
//! the target manifest so stale checkpoints fail loudly instead of
//! silently reshaping.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::hostkernel::BufferPool;
use crate::pytree::{DType, LeafSpec};
use crate::runtime::{
    lit_from_bytes, literal_bytes_into, read_scalar_f32, read_scalar_i32,
    Value,
};
use crate::scaling::GroupState;

const MAGIC_V1: &[u8; 8] = b"MPXCKPT1";
const MAGIC_V2: &[u8; 8] = b"MPXCKPT2";

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::Bf16 => 2,
        DType::S32 => 3,
        DType::U32 => 4,
        DType::S8 => 5,
        DType::U8 => 6,
        DType::Pred => 7,
    }
}

fn dtype_from_code(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F16,
        2 => DType::Bf16,
        3 => DType::S32,
        4 => DType::U32,
        5 => DType::S8,
        6 => DType::U8,
        7 => DType::Pred,
        _ => bail!("bad dtype code {c}"),
    })
}

/// Save state leaves plus the per-group scaler record to `path`
/// (schema v2, atomic tmp+rename).
pub fn save(
    path: &str,
    step: u64,
    specs: &[LeafSpec],
    leaves: &[Value],
    scaler: &[GroupState],
) -> Result<()> {
    if specs.len() != leaves.len() {
        bail!("save: {} specs vs {} leaves", specs.len(), leaves.len());
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("create {tmp}"))?,
        );
        f.write_all(MAGIC_V2)?;
        f.write_all(&step.to_le_bytes())?;
        f.write_all(&(scaler.len() as u32).to_le_bytes())?;
        for g in scaler {
            let name = g.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&g.scale.to_bits().to_le_bytes())?;
            f.write_all(&g.counter.to_le_bytes())?;
        }
        f.write_all(&(specs.len() as u32).to_le_bytes())?;
        // One pooled staging buffer cycles through every leaf, so the
        // periodic checkpoint stops allocating per leaf per save.
        let pool = BufferPool::global();
        let mut bytes = pool.take_u8(0);
        for (spec, lit) in specs.iter().zip(leaves) {
            let name = spec.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&[dtype_code(spec.dtype)])?;
            f.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
            for &d in &spec.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            literal_bytes_into(lit, &mut bytes)
                .with_context(|| format!("serialize leaf {}", spec.name))?;
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
        pool.put_u8(bytes);
    }
    std::fs::rename(&tmp, path).context("atomic rename")?;
    Ok(())
}

/// Restore: returns `(step, leaves, scaler record)` validated against
/// `specs`.  Accepts both schema versions; a v1 file yields a
/// migrated record (see the module docs).
pub fn load(
    path: &str,
    specs: &[LeafSpec],
) -> Result<(u64, Vec<Value>, Vec<GroupState>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    let v2 = &magic == MAGIC_V2;
    if !v2 && &magic != MAGIC_V1 {
        bail!("{path}: not an MPX checkpoint");
    }
    let step = read_u64(&mut f)?;

    let mut scaler = Vec::new();
    if v2 {
        let groups = read_u32(&mut f)? as usize;
        if groups > 65_536 {
            bail!("{path}: implausible scaler group count {groups}");
        }
        for _ in 0..groups {
            let name_len = read_u32(&mut f)? as usize;
            if name_len > 4096 {
                bail!("{path}: implausible group name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("group name utf8")?;
            let scale = f32::from_bits(read_u32(&mut f)?);
            let counter = read_u32(&mut f)?;
            scaler.push(GroupState { name, scale, counter });
        }
    }

    let count = read_u32(&mut f)? as usize;
    if count != specs.len() {
        bail!("{path}: {count} leaves, expected {}", specs.len());
    }

    let mut leaves = Vec::with_capacity(count);
    for spec in specs {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            bail!("{path}: implausible name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("leaf name utf8")?;
        if name != spec.name {
            bail!("{path}: leaf {name:?} where {:?} expected", spec.name);
        }
        let mut code = [0u8; 1];
        f.read_exact(&mut code)?;
        let dtype = dtype_from_code(code[0])?;
        if dtype != spec.dtype {
            bail!("{path}: leaf {name}: dtype {dtype:?} vs {:?}", spec.dtype);
        }
        let rank = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut f)? as usize);
        }
        if shape != spec.shape {
            bail!("{path}: leaf {name}: shape {shape:?} vs {:?}", spec.shape);
        }
        let byte_len = read_u64(&mut f)? as usize;
        if byte_len != spec.bytes() {
            bail!("{path}: leaf {name}: {byte_len} bytes vs {}", spec.bytes());
        }
        let mut bytes = vec![0u8; byte_len];
        f.read_exact(&mut bytes)?;
        leaves.push(lit_from_bytes(spec, &bytes)?);
    }

    if !v2 {
        scaler = migrate_v1_scaler(specs, &leaves)?;
    }
    Ok((step, leaves, scaler))
}

/// The v1 → v2 migration: synthesize a single-group record from the
/// fused trainer's in-graph scaler state if the leaf set carries it.
fn migrate_v1_scaler(
    specs: &[LeafSpec],
    leaves: &[Value],
) -> Result<Vec<GroupState>> {
    let find = |name: &str| {
        specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| &leaves[i])
    };
    let (Some(scale), Some(counter)) =
        (find("scaling.loss_scaling"), find("scaling.counter"))
    else {
        return Ok(Vec::new());
    };
    let scale = read_scalar_f32(scale).context("v1 scaling.loss_scaling")?;
    let counter =
        read_scalar_i32(counter).context("v1 scaling.counter")? as u32;
    Ok(vec![GroupState { name: "global".to_string(), scale, counter }])
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_scalar_f32, lit_scalar_i32, read_f32};

    fn specs() -> Vec<LeafSpec> {
        vec![
            LeafSpec {
                name: "params.w".to_string(),
                dtype: DType::F32,
                shape: vec![2, 2],
                group: "params".to_string(),
                trainable: true,
            },
            LeafSpec {
                name: "scaling.loss_scaling".to_string(),
                dtype: DType::F32,
                shape: vec![],
                group: "scaling".to_string(),
                trainable: false,
            },
            LeafSpec {
                name: "scaling.counter".to_string(),
                dtype: DType::S32,
                shape: vec![],
                group: "scaling".to_string(),
                trainable: false,
            },
        ]
    }

    fn leaves() -> Vec<Value> {
        vec![
            lit_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap(),
            lit_scalar_f32(8192.0),
            lit_scalar_i32(41),
        ]
    }

    /// Hand-written v1 writer — the old on-disk format, byte for
    /// byte, so the migration path is tested against the real thing
    /// rather than against `save`.
    fn write_v1(path: &str, step: u64, specs: &[LeafSpec], leaves: &[Value]) {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        out.extend_from_slice(&step.to_le_bytes());
        out.extend_from_slice(&(specs.len() as u32).to_le_bytes());
        for (spec, lit) in specs.iter().zip(leaves) {
            let name = spec.name.as_bytes();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name);
            out.push(dtype_code(spec.dtype));
            out.extend_from_slice(&(spec.shape.len() as u32).to_le_bytes());
            for &d in &spec.shape {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            let mut bytes = Vec::new();
            literal_bytes_into(lit, &mut bytes).unwrap();
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(&bytes);
        }
        std::fs::write(path, out).unwrap();
    }

    fn tmp_path(name: &str) -> String {
        let dir = std::env::temp_dir().join("mpx_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn v2_round_trips_scaler_record_and_leaves() {
        let path = tmp_path("v2_roundtrip.ckpt");
        let record = vec![
            GroupState { name: "blocks[0]".into(), scale: 512.0, counter: 3 },
            GroupState { name: "head".into(), scale: 32768.0, counter: 0 },
        ];
        save(&path, 17, &specs(), &leaves(), &record).unwrap();
        let (step, loaded, scaler) = load(&path, &specs()).unwrap();
        assert_eq!(step, 17);
        assert_eq!(scaler, record);
        assert_eq!(read_f32(&loaded[0]).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(read_scalar_f32(&loaded[1]).unwrap(), 8192.0);
        assert_eq!(read_scalar_i32(&loaded[2]).unwrap(), 41);
    }

    #[test]
    fn v2_empty_scaler_record_is_fine() {
        let path = tmp_path("v2_empty_record.ckpt");
        save(&path, 5, &specs(), &leaves(), &[]).unwrap();
        let (step, _, scaler) = load(&path, &specs()).unwrap();
        assert_eq!(step, 5);
        assert!(scaler.is_empty());
    }

    #[test]
    fn v1_checkpoint_migrates_scaling_leaves_to_a_global_record() {
        let path = tmp_path("v1_migrate.ckpt");
        write_v1(&path, 9, &specs(), &leaves());
        let (step, loaded, scaler) = load(&path, &specs()).unwrap();
        assert_eq!(step, 9);
        assert_eq!(loaded.len(), 3);
        assert_eq!(
            scaler,
            vec![GroupState {
                name: "global".to_string(),
                scale: 8192.0,
                counter: 41,
            }]
        );
    }

    #[test]
    fn v1_without_scaling_leaves_yields_empty_record() {
        let path = tmp_path("v1_no_scaling.ckpt");
        let specs = vec![specs()[0].clone()];
        let leaves = vec![leaves()[0].clone()];
        write_v1(&path, 2, &specs, &leaves);
        let (step, _, scaler) = load(&path, &specs).unwrap();
        assert_eq!(step, 2);
        assert!(scaler.is_empty());
    }

    #[test]
    fn stale_manifest_fails_loudly() {
        let path = tmp_path("stale.ckpt");
        save(&path, 1, &specs(), &leaves(), &[]).unwrap();
        let mut wrong = specs();
        wrong[0].shape = vec![4];
        let err = load(&path, &wrong).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn garbage_magic_is_rejected() {
        let path = tmp_path("garbage.ckpt");
        std::fs::write(&path, b"NOTMPX00rest").unwrap();
        let err = load(&path, &specs()).unwrap_err().to_string();
        assert!(err.contains("not an MPX checkpoint"), "{err}");
    }
}
