//! Checkpointing — own binary format (no serde offline).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "MPXCKPT1" | u64 step | u32 leaf_count
//! per leaf: u32 name_len | name utf8 | u8 dtype | u32 rank |
//!           u64 dims[rank] | u64 byte_len | bytes
//! ```
//!
//! Leaves are the fused trainer's state [`Value`]s in manifest order.
//! Save and restore are symmetric across every manifest dtype —
//! `Value` already stores native-layout bytes, so serialization is a
//! straight copy and mixed-precision state round-trips bitwise on
//! either runtime backend.
//! Restore validates name, dtype and shape against the target
//! manifest so stale checkpoints fail loudly instead of silently
//! reshaping.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::hostkernel::BufferPool;
use crate::pytree::{DType, LeafSpec};
use crate::runtime::{lit_from_bytes, literal_bytes_into, Value};

const MAGIC: &[u8; 8] = b"MPXCKPT1";

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::Bf16 => 2,
        DType::S32 => 3,
        DType::U32 => 4,
        DType::S8 => 5,
        DType::U8 => 6,
        DType::Pred => 7,
    }
}

fn dtype_from_code(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F16,
        2 => DType::Bf16,
        3 => DType::S32,
        4 => DType::U32,
        5 => DType::S8,
        6 => DType::U8,
        7 => DType::Pred,
        _ => bail!("bad dtype code {c}"),
    })
}

/// Save state leaves to `path`.
pub fn save(
    path: &str,
    step: u64,
    specs: &[LeafSpec],
    leaves: &[Value],
) -> Result<()> {
    if specs.len() != leaves.len() {
        bail!("save: {} specs vs {} leaves", specs.len(), leaves.len());
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("create {tmp}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&step.to_le_bytes())?;
        f.write_all(&(specs.len() as u32).to_le_bytes())?;
        // One pooled staging buffer cycles through every leaf, so the
        // periodic checkpoint stops allocating per leaf per save.
        let pool = BufferPool::global();
        let mut bytes = pool.take_u8(0);
        for (spec, lit) in specs.iter().zip(leaves) {
            let name = spec.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&[dtype_code(spec.dtype)])?;
            f.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
            for &d in &spec.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            literal_bytes_into(lit, &mut bytes)
                .with_context(|| format!("serialize leaf {}", spec.name))?;
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
        pool.put_u8(bytes);
    }
    std::fs::rename(&tmp, path).context("atomic rename")?;
    Ok(())
}

/// Restore: returns `(step, leaves)` validated against `specs`.
pub fn load(path: &str, specs: &[LeafSpec]) -> Result<(u64, Vec<Value>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path}: not an MPX checkpoint");
    }
    let step = read_u64(&mut f)?;
    let count = read_u32(&mut f)? as usize;
    if count != specs.len() {
        bail!("{path}: {count} leaves, expected {}", specs.len());
    }

    let mut leaves = Vec::with_capacity(count);
    for spec in specs {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            bail!("{path}: implausible name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("leaf name utf8")?;
        if name != spec.name {
            bail!("{path}: leaf {name:?} where {:?} expected", spec.name);
        }
        let mut code = [0u8; 1];
        f.read_exact(&mut code)?;
        let dtype = dtype_from_code(code[0])?;
        if dtype != spec.dtype {
            bail!("{path}: leaf {name}: dtype {dtype:?} vs {:?}", spec.dtype);
        }
        let rank = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut f)? as usize);
        }
        if shape != spec.shape {
            bail!("{path}: leaf {name}: shape {shape:?} vs {:?}", spec.shape);
        }
        let byte_len = read_u64(&mut f)? as usize;
        if byte_len != spec.bytes() {
            bail!("{path}: leaf {name}: {byte_len} bytes vs {}", spec.bytes());
        }
        let mut bytes = vec![0u8; byte_len];
        f.read_exact(&mut bytes)?;
        leaves.push(lit_from_bytes(spec, &bytes)?);
    }
    Ok((step, leaves))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
