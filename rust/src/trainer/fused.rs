//! Single-device trainer over the fused step artifact.
//!
//! The compiled step is
//! `(params, opt_state, scaling, images, labels) →
//!  (params', opt_state', scaling', loss, grads_finite)`;
//! Rust threads the state leaves through, attaches fresh batch
//! leaves, and records metrics.  State leaves live as host
//! [`Value`]s between steps (on the xla backend the PJRT build
//! returns one tuple buffer — see `runtime`); the packing cost is
//! measured by `runtime_overhead`.  The loop is backend-agnostic:
//! the artifact store decides whether steps run on PJRT or the host
//! interpreter.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::data::{Batch, Prefetcher, SyntheticDataset};
use crate::metrics::{RunMetrics, StepRecord};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_i32, read_scalar_f32, read_scalar_pred,
    Artifact, ArtifactStore, Value,
};
use crate::serve::clock::{Clock, WallClock};
use crate::trace::{SpanKind, Tracer};

pub struct FusedTrainer {
    step_artifact: Arc<Artifact>,
    /// State leaves in step-input order (params ++ opt_state ++ scaling).
    state: Vec<Value>,
    n_state: usize,
    pub step_index: u64,
    pub config: TrainConfig,
    /// Time base for trace spans (`Duration` offsets since
    /// construction — the [`Tracer`] contract).
    clock: Arc<WallClock>,
    /// The whole step is one compiled HLO here, so only the
    /// step-level span and loss-scale events are observable; the
    /// per-phase spans live in the data-parallel trainer.
    tracer: Option<Arc<Tracer>>,
}

impl FusedTrainer {
    /// Load artifacts and run the in-graph initializer.
    ///
    /// The scaling state machine is baked into the compiled step at
    /// AOT time, so the configured policy must be exactly what the
    /// graph implements — anything else (an `adaptive` policy, tweaked
    /// dynamic knobs) is refused here with a pointer at `train-ddp`,
    /// which owns its policy host-side, rather than silently running
    /// the artifact's built-in machine under a different name.
    pub fn new(store: &mut ArtifactStore, config: TrainConfig) -> Result<Self> {
        let spec = config.scaling_spec()?;
        if !spec.matches_compiled(config.precision.is_f16()) {
            bail!(
                "the fused step artifact for precision \"{}\" implements \
                 only its compiled-in scaling machine; the configured \
                 policy \"{}\" cannot run in-graph — use `mpx train-ddp`, \
                 which owns the scaling policy host-side",
                config.precision.tag(),
                spec.kind.tag(),
            );
        }
        let init = store.load(&config.init_artifact())?;
        let step_artifact = store.load(&config.step_artifact())?;

        // init outputs and step state inputs must agree leaf-for-leaf.
        let m = &step_artifact.manifest;
        let state_groups = ["params", "opt_state", "scaling"];
        let n_state: usize = state_groups
            .iter()
            .map(|g| m.input_group(g).len())
            .sum();
        if n_state == 0 {
            bail!("{}: no state inputs found", m.name);
        }
        if init.manifest.outputs.len() != n_state {
            bail!(
                "init yields {} leaves but step wants {} state inputs",
                init.manifest.outputs.len(),
                n_state
            );
        }
        for (a, b) in init.manifest.outputs.iter().zip(&m.inputs[..n_state]) {
            if a.dtype != b.dtype || a.shape != b.shape {
                bail!(
                    "state leaf mismatch: init {}:{:?}{:?} vs step {}:{:?}{:?}",
                    a.name, a.dtype, a.shape, b.name, b.dtype, b.shape
                );
            }
        }

        let state = init
            .execute(&[lit_scalar_i32(config.seed as i32)])
            .context("run init artifact")?;

        let clock = Arc::new(WallClock::new());
        let tracer = Tracer::from_config(
            clock.clone() as Arc<dyn Clock>,
            &config.trace,
        );
        Ok(FusedTrainer {
            step_artifact,
            state,
            n_state,
            step_index: 0,
            config,
            clock,
            tracer,
        })
    }

    /// The step span recorder (`None` when `[trace]` is off).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    pub fn manifest(&self) -> &crate::pytree::Manifest {
        &self.step_artifact.manifest
    }

    /// Pack a host batch into the step's (images, labels) literals.
    fn batch_literals(&self, batch: &Batch) -> Result<[Value; 2]> {
        let m = &self.step_artifact.manifest;
        let img_spec = &m.inputs[m.input_group("images")
            .next_back()
            .context("step has no images input")?];
        let lbl_spec = &m.inputs[m.input_group("labels")
            .next_back()
            .context("step has no labels input")?];
        Ok([
            lit_f32(&img_spec.shape, &batch.images)?,
            lit_i32(&lbl_spec.shape, &batch.labels)?,
        ])
    }

    /// Run one training step on `batch`.
    pub fn step(&mut self, batch: &Batch) -> Result<StepRecord> {
        let t0 = Instant::now();
        let span_start = self.clock.now();
        // Read the pre-step scale only when someone is listening —
        // it costs a device→host scalar transfer.
        let old_scale = match &self.tracer {
            Some(_) => Some(self.loss_scale()?),
            None => None,
        };
        let [images, labels] = self.batch_literals(batch)?;

        let mut inputs: Vec<&Value> = self.state.iter().collect();
        inputs.push(&images);
        inputs.push(&labels);

        let mut outputs = self.step_artifact.execute(inputs)?;
        let m = &self.step_artifact.manifest;
        if outputs.len() != m.outputs.len() {
            bail!(
                "step returned {} leaves, manifest says {}",
                outputs.len(),
                m.outputs.len()
            );
        }

        // outputs = state' ++ [loss, finite]
        let loss_idx = m
            .output_group("loss")
            .next_back()
            .context("no loss output")?;
        let finite_idx = m
            .output_group("finite")
            .next_back()
            .context("no finite output")?;
        let loss = read_scalar_f32(&outputs[loss_idx])?;
        let grads_finite = read_scalar_pred(&outputs[finite_idx])?;

        outputs.truncate(self.n_state);
        self.state = outputs;
        self.step_index += 1;

        let loss_scale = self.loss_scale()?;
        if let Some(t) = &self.tracer {
            if let Some(old) = old_scale {
                if loss_scale != old {
                    t.instant(
                        SpanKind::LossScale,
                        t.now(),
                        old.to_bits() as u64,
                        loss_scale.to_bits() as u64,
                        (loss_scale > old) as u64,
                    );
                }
            }
            t.record(
                SpanKind::TrainStep,
                span_start,
                t.now(),
                self.step_index,
                grads_finite as u64,
                0,
            );
        }

        Ok(StepRecord {
            step: self.step_index,
            loss,
            grads_finite,
            loss_scale,
            step_time: t0.elapsed(),
        })
    }

    /// Current dynamic loss scale carried in the state.
    pub fn loss_scale(&self) -> Result<f32> {
        let m = &self.step_artifact.manifest;
        let range = m.input_group("scaling");
        for (i, spec) in m.inputs[range.clone()].iter().enumerate() {
            if spec.dtype == crate::pytree::DType::F32 {
                return read_scalar_f32(&self.state[range.start + i]);
            }
        }
        bail!("no f32 scaling leaf found")
    }

    /// Borrow the state leaves (checkpoint save).
    pub fn state(&self) -> &[Value] {
        &self.state
    }

    /// Replace the state leaves (checkpoint restore).
    pub fn set_state(&mut self, state: Vec<Value>) -> Result<()> {
        if state.len() != self.n_state {
            bail!(
                "restore: got {} leaves, trainer wants {}",
                state.len(),
                self.n_state
            );
        }
        self.state = state;
        Ok(())
    }

    /// Train `steps` steps over `dataset`, logging into `metrics`.
    ///
    /// Batch generation runs on a background prefetch thread
    /// ([`crate::data::Prefetcher`]): while XLA executes step *k* the
    /// batch for *k+1* is already being produced — the Rust analogue
    /// of the paper excluding data-loading time from its measurements
    /// (§Perf L3-1 records the before/after).
    pub fn run(
        &mut self,
        dataset: &SyntheticDataset,
        steps: u64,
        metrics: &mut RunMetrics,
    ) -> Result<()> {
        let log_every = self.config.log_every.max(1);
        let prefetcher = Prefetcher::with_start(
            dataset.clone(),
            self.config.batch,
            self.config.seed,
            2,
            self.step_index,
        );
        for _ in 0..steps {
            let batch = prefetcher.next();
            let rec = self.step(&batch)?;
            // Hand the batch buffers back for the prefetcher's next
            // generation — the loop allocates nothing in steady state.
            batch.recycle();
            if rec.step % log_every == 0 || rec.step == 1 {
                eprintln!(
                    "[train] step {:>5}  loss {:>8.4}  scale {:>9.0}  {}{}",
                    rec.step,
                    rec.loss,
                    rec.loss_scale,
                    crate::util::human_duration(rec.step_time),
                    if rec.grads_finite { "" } else { "  (overflow, skipped)" },
                );
            }
            metrics.record(rec)?;
        }
        Ok(())
    }
}
