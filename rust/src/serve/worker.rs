//! Executor pool: per-worker model replicas driving the shared
//! compiled executables.
//!
//! Each worker thread builds one [`BatchExecutor`] *per lane* inside
//! the thread, then loops on [`Scheduler::next_work`]: the scheduler
//! continuously refills free slots from whichever lane the
//! weighted-deficit picker selects, so a worker serves every (model,
//! precision) lane, not one queue.  Per-request latency lands in the
//! worker's own per-lane [`LatencyHistogram`]s (merged by the engine
//! afterwards), and completions are streamed through the scheduler's
//! callback the moment a batch finishes.
//!
//! The compiled executables themselves are shared across workers (the
//! runtime `Executable` trait is `Send + Sync` on either backend) —
//! one compile, N replicas of the (cheap) parameter leaves, exactly
//! the replication scheme `trainer::ddp` uses for shards.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::metrics::LatencyHistogram;
use crate::runtime::{lit_f32, lit_scalar_i32, read_f32, Artifact, Value};
use crate::serve::clock::Clock;
use crate::serve::sched::{Scheduler, Work};

/// A loaded model replica that can run one padded batch.
pub trait BatchExecutor {
    /// Run the forward on `images` (`f32[batch, image_elems]`, already
    /// padded to a supported bucket); returns the flat logits.
    fn execute(&mut self, images: &[f32], batch: usize) -> Result<Vec<f32>>;
}

/// One worker's accounting for one lane.
#[derive(Debug, Clone, Default)]
pub struct LaneTally {
    pub batches: u64,
    pub requests: u64,
    pub padded: u64,
    pub deadline_misses: u64,
    pub latency: LatencyHistogram,
}

/// Per-worker accounting, merged into the run report.
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub worker: usize,
    /// Exited via an autoscale [`Work::Retire`] grant.
    pub retired: bool,
    /// Wall time spent inside `execute` (utilisation numerator).
    pub busy: Duration,
    /// Indexed by lane.
    pub lanes: Vec<LaneTally>,
}

impl WorkerReport {
    fn new(worker: usize, lanes: usize) -> WorkerReport {
        WorkerReport {
            worker,
            retired: false,
            busy: Duration::ZERO,
            lanes: (0..lanes).map(|_| LaneTally::default()).collect(),
        }
    }

    pub fn batches(&self) -> u64 {
        self.lanes.iter().map(|l| l.batches).sum()
    }

    pub fn requests(&self) -> u64 {
        self.lanes.iter().map(|l| l.requests).sum()
    }

    pub fn padded(&self) -> u64 {
        self.lanes.iter().map(|l| l.padded).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.lanes.iter().map(|l| l.deadline_misses).sum()
    }

    /// All-lane latency merge for this worker.
    pub fn latency(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::new();
        for l in &self.lanes {
            h.merge(&l.latency);
        }
        h
    }
}

/// One worker's life: pull scheduler work until every lane drains (or
/// an autoscale retire grant arrives).
///
/// `execs` holds one executor per lane, in lane order.  Latency is
/// measured admission → batch completion, for *real* requests only —
/// padding rows are ballast and never recorded.  On executor failure
/// the worker frees its slot, closes all lanes (so peers drain what
/// is queued instead of waiting forever), and propagates the error.
pub fn worker_loop<E: BatchExecutor>(
    worker: usize,
    execs: &mut [E],
    sched: &Scheduler,
    clock: &dyn Clock,
) -> Result<WorkerReport> {
    debug_assert_eq!(execs.len(), sched.lanes());
    let mut rep = WorkerReport::new(worker, sched.lanes());
    // One pooled pack buffer per worker, cycled across batches — the
    // padding/pack path allocates nothing in steady state.
    let pool = crate::hostkernel::BufferPool::global();
    let mut images = pool.take_f32(0);
    loop {
        match sched.next_work() {
            Work::Shutdown => break,
            Work::Retire => {
                rep.retired = true;
                break;
            }
            Work::Batch { lane, batch } => {
                let pack_start = clock.now();
                batch.padded_images_into(&mut images);
                let t0 = clock.now();
                if let Some(t) = sched.tracer() {
                    // Worker-side pad/pack cost, distinct from the
                    // scheduler's dispatch→done execute span.
                    t.record(
                        crate::trace::SpanKind::Pack,
                        pack_start,
                        t0,
                        lane as u64,
                        batch.bucket as u64,
                        batch.requests.len() as u64,
                    );
                }
                let res = execs[lane].execute(&images, batch.bucket);
                let done = clock.now();
                let logits = match res {
                    Ok(logits) => logits,
                    Err(e) => {
                        sched.worker_failed();
                        sched.close_all();
                        pool.put_f32(images);
                        return Err(e).with_context(|| {
                            format!(
                                "worker {worker}: batch of {} on lane {}",
                                batch.bucket,
                                sched.lane_name(lane)
                            )
                        });
                    }
                };
                let misses = sched
                    .complete_streamed(worker, lane, &batch, done, &logits);
                let t = &mut rep.lanes[lane];
                t.batches += 1;
                t.padded += batch.padding() as u64;
                t.deadline_misses += misses;
                for r in &batch.requests {
                    t.latency.record(done.saturating_sub(r.enqueued));
                    t.requests += 1;
                }
                rep.busy += done.saturating_sub(t0);
            }
        }
    }
    pool.put_f32(images);
    Ok(rep)
}

/// [`BatchExecutor`] over the AOT forward artifacts: one compiled
/// executable per bucket size (all shared), one parameter replica per
/// worker per lane.
///
/// The replica is materialised by re-running the deterministic init
/// artifact with the worker-shared seed — identical weights on every
/// worker without moving literals across threads.
pub struct ArtifactExecutor {
    /// `(bucket, fwd artifact)`, ascending by bucket.
    fwd_by_bucket: Vec<(usize, Arc<Artifact>)>,
    /// Init-artifact outputs (this thread's replica).
    state: Vec<Value>,
    /// Slice of `state` holding the parameter leaves.
    prange: std::ops::Range<usize>,
}

impl ArtifactExecutor {
    /// Build inside the worker thread.
    pub fn new(
        init: &Artifact,
        fwd_by_bucket: Vec<(usize, Arc<Artifact>)>,
        seed: i32,
    ) -> Result<ArtifactExecutor> {
        if fwd_by_bucket.is_empty() {
            bail!("no forward artifacts to serve");
        }
        let state = init
            .execute(&[lit_scalar_i32(seed)])
            .context("replicate params via init artifact")?;
        let prange = init.manifest.output_group("params");
        if prange.is_empty() {
            bail!(
                "init artifact {} has no params output group",
                init.manifest.name
            );
        }
        Ok(ArtifactExecutor { fwd_by_bucket, state, prange })
    }
}

impl BatchExecutor for ArtifactExecutor {
    fn execute(&mut self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (_, fwd) = self
            .fwd_by_bucket
            .iter()
            .find(|(b, _)| *b == batch)
            .with_context(|| {
                format!("no forward artifact for batch {batch}")
            })?;
        let img_idx = fwd
            .manifest
            .input_group("images")
            .next_back()
            .context("forward artifact has no images input")?;
        let img_spec = &fwd.manifest.inputs[img_idx];
        if img_spec.elems() != images.len() {
            bail!(
                "batch {batch}: artifact wants {} image elems, got {}",
                img_spec.elems(),
                images.len()
            );
        }
        let images = lit_f32(&img_spec.shape, images)?;
        let mut inputs: Vec<&Value> =
            self.state[self.prange.clone()].iter().collect();
        inputs.push(&images);
        let out = fwd.execute(inputs)?;
        read_f32(&out[0])
    }
}
