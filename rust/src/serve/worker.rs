//! Executor pool: per-worker model replicas driving the shared
//! compiled executables.
//!
//! Each worker thread builds its own [`BatchExecutor`] *inside the
//! thread* (PJRT literals are not `Send`), pulls formed batches from
//! the shared queue, and accounts per-request latency into its own
//! [`LatencyHistogram`]; the server merges the histograms afterwards.
//! The compiled executables themselves are shared across workers via
//! [`SharedExecutable`](crate::runtime::SharedExecutable) — one
//! compile, N replicas of the (cheap) parameter literals, exactly the
//! replication scheme `trainer::ddp` uses for shards.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::LatencyHistogram;
use crate::runtime::{lit_f32, lit_scalar_i32, read_f32, Artifact};
use crate::serve::batcher::BatcherConfig;
use crate::serve::queue::RequestQueue;

/// A loaded model replica that can run one padded batch.
pub trait BatchExecutor {
    /// Run the forward on `images` (`f32[batch, image_elems]`, already
    /// padded to a supported bucket); returns the flat logits.
    fn execute(&mut self, images: &[f32], batch: usize) -> Result<Vec<f32>>;
}

/// Per-worker accounting, merged into the run report.
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub requests: u64,
    pub padded: u64,
    pub deadline_misses: u64,
    /// Wall time spent inside `execute` (utilisation numerator).
    pub busy: Duration,
    pub latency: LatencyHistogram,
}

impl WorkerReport {
    fn new(worker: usize) -> WorkerReport {
        WorkerReport {
            worker,
            batches: 0,
            requests: 0,
            padded: 0,
            deadline_misses: 0,
            busy: Duration::ZERO,
            latency: LatencyHistogram::new(),
        }
    }
}

/// One worker's life: pull batches until the queue closes and drains.
///
/// Latency is measured admission → batch completion, for *real*
/// requests only — padding rows are ballast and never recorded (the
/// padded-batch accounting the tests pin down).
pub fn worker_loop<E: BatchExecutor>(
    worker: usize,
    exec: &mut E,
    queue: &RequestQueue,
    cfg: &BatcherConfig,
) -> Result<WorkerReport> {
    let mut rep = WorkerReport::new(worker);
    // One pooled pack buffer per worker, cycled across batches — the
    // padding/pack path allocates nothing in steady state.
    let pool = crate::hostkernel::BufferPool::global();
    let mut images = pool.take_f32(0);
    while let Some(batch) = queue.next_batch(cfg) {
        batch.padded_images_into(&mut images);
        let t0 = Instant::now();
        exec.execute(&images, batch.bucket).with_context(|| {
            format!("worker {worker}: batch of {}", batch.bucket)
        })?;
        let done = Instant::now();
        rep.busy += done - t0;
        rep.batches += 1;
        rep.padded += batch.padding() as u64;
        for r in &batch.requests {
            rep.latency.record(done.duration_since(r.enqueued));
            if r.missed_deadline(done) {
                rep.deadline_misses += 1;
            }
            rep.requests += 1;
        }
    }
    pool.put_f32(images);
    Ok(rep)
}

/// [`BatchExecutor`] over the AOT forward artifacts: one compiled
/// executable per bucket size (all shared), one parameter replica per
/// worker.
///
/// The replica is materialised by re-running the deterministic init
/// artifact with the worker-shared seed — identical weights on every
/// worker without moving literals across threads.
pub struct ArtifactExecutor {
    /// `(bucket, fwd artifact)`, ascending by bucket.
    fwd_by_bucket: Vec<(usize, Arc<Artifact>)>,
    /// Init-artifact outputs (this thread's literals).
    state: Vec<xla::Literal>,
    /// Slice of `state` holding the parameter leaves.
    prange: std::ops::Range<usize>,
}

impl ArtifactExecutor {
    /// Build inside the worker thread.
    pub fn new(
        init: &Artifact,
        fwd_by_bucket: Vec<(usize, Arc<Artifact>)>,
        seed: i32,
    ) -> Result<ArtifactExecutor> {
        if fwd_by_bucket.is_empty() {
            bail!("no forward artifacts to serve");
        }
        let state = init
            .execute(&[lit_scalar_i32(seed)])
            .context("replicate params via init artifact")?;
        let prange = init.manifest.output_group("params");
        if prange.is_empty() {
            bail!(
                "init artifact {} has no params output group",
                init.manifest.name
            );
        }
        Ok(ArtifactExecutor { fwd_by_bucket, state, prange })
    }
}

impl BatchExecutor for ArtifactExecutor {
    fn execute(&mut self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (_, fwd) = self
            .fwd_by_bucket
            .iter()
            .find(|(b, _)| *b == batch)
            .with_context(|| {
                format!("no forward artifact for batch {batch}")
            })?;
        let img_idx = fwd
            .manifest
            .input_group("images")
            .next_back()
            .context("forward artifact has no images input")?;
        let img_spec = &fwd.manifest.inputs[img_idx];
        if img_spec.elems() != images.len() {
            bail!(
                "batch {batch}: artifact wants {} image elems, got {}",
                img_spec.elems(),
                images.len()
            );
        }
        let images = lit_f32(&img_spec.shape, images)?;
        let mut inputs: Vec<&xla::Literal> =
            self.state[self.prange.clone()].iter().collect();
        inputs.push(&images);
        let out = fwd.execute(&inputs)?;
        read_f32(&out[0])
    }
}
